//! CI bench regression gate (DESIGN.md §2.8): compares the serve-workload
//! throughput of freshly-produced `BENCH_*.json` files against the
//! committed baselines under `benches/baselines/`, failing the job on a
//! >15% regression, and asserts the baseline-free invariants:
//!  * `BENCH_pr4.json`: the dataflow drain must beat the barrier drain's
//!    makespan per workload without inflating slot idle time,
//!  * `BENCH_pr5.json`: the co-scheduled virtual makespan must beat the
//!    serialized baseline,
//!  * `BENCH_pr6.json`: the warm-started serve must perform zero cold
//!    profile builds, spend strictly less cold-build time than the cold
//!    run, and report order-independent snapshot merges (DESIGN.md §2.9),
//!  * `BENCH_pr7.json`: batched serve must beat unbatched virtual
//!    throughput by >= 1.3x with bit-identical per-request execution
//!    totals (DESIGN.md §2.10),
//!  * `BENCH_pr9.json` (`--prefetch`): prefetch-on dataflow makespan
//!    must not exceed prefetch-off on any workload and must strictly
//!    beat it on the transfer-heavy pipeline with overlap% > 0, and the
//!    native depth-0 vs depth-k outputs must be bit-identical
//!    (DESIGN.md §2.12),
//!  * `--native BENCH_pr8.json` (opt-in: only meaningful on a runner
//!    that produced the file with the compiled CPU backend): every
//!    kernel's native output stays within 1e-5 relative error of the
//!    single-thread-scalar reference, and the compute-bound
//!    `nbody_accel` family shows >= 2x multi-core-vs-scalar throughput
//!    (DESIGN.md §2.11),
//!  * `BENCH_pr10.json` (`--irregular`): the per-class KB estimate must
//!    show strictly lower relative error than the size-only
//!    nearest-profile path on the sparse family and no worse on every
//!    other irregular class, and two replays of the same recorded trace
//!    must report bit-identical virtual makespans with equal batch
//!    counts (DESIGN.md §2.13).
//! Also emits the merged markdown table the CI `bench-summary` artifact
//! ships.
//!
//! Usage:
//!   bench_gate [--fresh BENCH_pr5.json] [--warmstart BENCH_pr6.json]
//!              [--dataflow BENCH_pr4.json] [--batch BENCH_pr7.json]
//!              [--prefetch BENCH_pr9.json] [--native BENCH_pr8.json]
//!              [--irregular BENCH_pr10.json]
//!              [--baselines benches/baselines]
//!              [--summary bench-summary.md] [--tolerance 0.15]
//!   bench_gate --native-only [--native BENCH_pr8.json]   # CI native job
//!
//! Baselines are plain copies of previous runs' bench JSON. A baseline
//! file without the compared keys (the committed bootstrap state) gates
//! nothing — the gate prints the fresh values so a maintainer can pin
//! them from the `bench-summary` artifact of a trusted run.

use std::collections::BTreeMap;
use std::path::Path;

use marrow::cli::Args;
use marrow::util::json::Json;

/// Benches whose throughput the gate enforces: the serve workloads.
const SERVE_BENCHES: [&str; 5] = [
    "serve_throughput",
    "coschedule_serve",
    "kb_warmstart",
    "locality_residency",
    "batch_fusion",
];

fn main() {
    let args = Args::from_env();
    match run(&args) {
        Ok(()) => println!("bench gate: OK"),
        Err(e) => {
            eprintln!("bench gate: FAIL — {e}");
            std::process::exit(1);
        }
    }
}

fn run(args: &Args) -> Result<(), String> {
    let fresh_path = args.get_or("fresh", "BENCH_pr5.json");
    let baseline_dir = args.get_or("baselines", "benches/baselines");
    let tolerance = args
        .get("tolerance")
        .map(|t| t.parse::<f64>().map_err(|e| format!("--tolerance: {e}")))
        .transpose()?
        .unwrap_or(0.15);

    // Native-only mode (the CI native job): gate the hardware measurement
    // alone — that job runs no serve benches, so the serve-invariant
    // files it would otherwise require are never produced there.
    if args.has("native-only") {
        return check_native_invariant(&args.get_or("native", "BENCH_pr8.json"));
    }

    // Summary first: the failing runs are exactly the ones whose numbers
    // a maintainer needs to inspect (and possibly pin as new baselines).
    if let Some(summary) = args.get("summary") {
        write_summary(summary)?;
    }
    check_dataflow_invariant(&args.get_or("dataflow", "BENCH_pr4.json"))?;
    check_coschedule_invariant(&fresh_path)?;
    check_warmstart_invariant(&args.get_or("warmstart", "BENCH_pr6.json"))?;
    check_batch_invariant(&args.get_or("batch", "BENCH_pr7.json"))?;
    // Opt-in like --native: BENCH_pr9 exists only after the
    // transfer_overlap bench has run in the same job.
    if let Some(prefetch) = args.get("prefetch") {
        check_prefetch_invariant(prefetch)?;
    }
    // Opt-in like --prefetch: BENCH_pr10 exists only after the
    // irregular_replay bench has run in the same job.
    if let Some(irregular) = args.get("irregular") {
        check_irregular_invariant(irregular)?;
    }
    // Opt-in: BENCH_pr8 is a hardware measurement, so the gate runs only
    // where the caller says the file was produced on this runner.
    if let Some(native) = args.get("native") {
        check_native_invariant(native)?;
    }
    check_baselines(&baseline_dir, tolerance)?;
    Ok(())
}

/// The native-backend gate (DESIGN.md §2.11): BENCH_pr8.json's per-kernel
/// parity against the single-thread-scalar reference must stay within
/// 1e-5 relative error (the ported kernels vectorize only across
/// independent elements, so the measured value is expected to be exactly
/// 0.0 — the tolerance absorbs nothing but a future reassociating
/// kernel), and `nbody_accel` — compute-bound, SIMD-friendly — must show
/// >= 2x multi-core-vectorized throughput over the scalar leg.
fn check_native_invariant(path: &str) -> Result<(), String> {
    let v = parse_file(Path::new(path))?;
    let results = v
        .get("results")
        .ok()
        .and_then(|r| r.as_arr())
        .ok_or_else(|| format!("{path}: missing results"))?;
    if results.is_empty() {
        return Err(format!("{path}: empty results"));
    }
    let mut nbody_speedup = None;
    for r in results {
        let kernel = r
            .get("kernel")
            .ok()
            .and_then(|k| k.as_str())
            .unwrap_or("?")
            .to_string();
        let parity = r
            .get("parity_max_rel_err")
            .ok()
            .and_then(|x| x.as_f64())
            .ok_or_else(|| format!("{path}: {kernel} missing parity_max_rel_err"))?;
        let speedup = r
            .get("speedup")
            .ok()
            .and_then(|x| x.as_f64())
            .ok_or_else(|| format!("{path}: {kernel} missing speedup"))?;
        if parity > 1e-5 {
            return Err(format!(
                "{path}: {kernel} native output drifted {parity:.3e} from \
                 the scalar reference (limit 1e-5)"
            ));
        }
        println!("native invariant: {kernel} {speedup:.2}x, parity {parity:.2e} (OK)");
        if kernel == "nbody_accel" {
            nbody_speedup = Some(speedup);
        }
    }
    let s = nbody_speedup.ok_or_else(|| format!("{path}: no nbody_accel result"))?;
    if s < 2.0 {
        return Err(format!(
            "{path}: nbody_accel multi-core native throughput {s:.2}x is \
             below the required 2x over single-thread scalar"
        ));
    }
    Ok(())
}

/// The prefetch-overlap gate (DESIGN.md §2.12), baseline-free and
/// deterministic (seed-paired sim arms): per workload in BENCH_pr9.json,
/// the prefetch-on makespan must not exceed prefetch-off; the
/// transfer-heavy `pipeline_3stage` must improve *strictly* and report
/// overlap% > 0 (something actually hid); and the native depth-0 vs
/// depth-k drain must have produced bit-identical outputs — prefetch is
/// a scheduling change, never a numerics change.
fn check_prefetch_invariant(path: &str) -> Result<(), String> {
    let v = parse_file(Path::new(path))?;
    let identical = v
        .get("outputs_identical")
        .ok()
        .and_then(|x| x.as_bool())
        .ok_or_else(|| format!("{path}: missing outputs_identical"))?;
    if !identical {
        return Err(format!(
            "{path}: prefetch drain outputs drifted from the depth-0 drain \
             (correctness, not a perf tradeoff)"
        ));
    }
    let points = v
        .get("points")
        .ok()
        .and_then(|p| p.as_arr())
        .ok_or_else(|| format!("{path}: missing points"))?;
    // (workload, arm) -> (makespan_ms, overlap_pct)
    let mut arms: BTreeMap<(String, String), (f64, f64)> = BTreeMap::new();
    for p in points {
        let workload = p.get("workload").ok().and_then(|x| x.as_str());
        let arm = p.get("prefetch").ok().and_then(|x| x.as_str());
        let makespan = p.get("makespan_ms").ok().and_then(|x| x.as_f64());
        let overlap = p.get("overlap_pct").ok().and_then(|x| x.as_f64());
        if let (Some(w), Some(a), Some(m), Some(o)) = (workload, arm, makespan, overlap) {
            arms.insert((w.to_string(), a.to_string()), (m, o));
        }
    }
    let workloads: Vec<String> = arms
        .keys()
        .map(|(w, _)| w.clone())
        .collect::<std::collections::BTreeSet<_>>()
        .into_iter()
        .collect();
    if workloads.is_empty() {
        return Err(format!("{path}: no (workload, prefetch) points"));
    }
    for w in &workloads {
        let off = arms
            .get(&(w.clone(), "off".to_string()))
            .ok_or_else(|| format!("{path}: {w} has no prefetch-off point"))?;
        let on = arms
            .get(&(w.clone(), "on".to_string()))
            .ok_or_else(|| format!("{path}: {w} has no prefetch-on point"))?;
        if on.0 > off.0 {
            return Err(format!(
                "{path}: {w} prefetch-on makespan {:.3}ms exceeds \
                 prefetch-off {:.3}ms",
                on.0, off.0
            ));
        }
        if w == "pipeline_3stage" {
            if on.0 >= off.0 {
                return Err(format!(
                    "{path}: {w} prefetch-on makespan {:.3}ms does not \
                     strictly beat prefetch-off {:.3}ms",
                    on.0, off.0
                ));
            }
            if on.1 <= 0.0 {
                return Err(format!(
                    "{path}: {w} reports no overlapped upload bytes \
                     (overlap {:.2}%)",
                    on.1
                ));
            }
        }
        println!(
            "prefetch invariant: {w} {:.2}ms vs off {:.2}ms, overlap \
             {:.1}% (OK)",
            on.0, off.0, on.1
        );
    }
    println!("prefetch invariant: depth-0 vs depth-k outputs bit-identical (OK)");
    Ok(())
}

/// The irregular-tier gate (DESIGN.md §2.13), baseline-free and
/// deterministic: per class in BENCH_pr10.json, the per-class KB estimate
/// error must not exceed the size-only nearest-profile error — and must
/// beat it *strictly* on the sparse family, where per-size interpolation
/// has no way to see data-dependent cost. The replay block must report
/// two bit-identical virtual makespans and equal batch counts for the
/// same recorded trace: replay is a contract, not a best effort.
fn check_irregular_invariant(path: &str) -> Result<(), String> {
    let v = parse_file(Path::new(path))?;
    let classes = v
        .get("classes")
        .ok()
        .and_then(|c| c.as_arr())
        .ok_or_else(|| format!("{path}: missing classes"))?;
    let mut saw_sparse = false;
    for c in classes {
        let class = c
            .get("class")
            .ok()
            .and_then(|x| x.as_str())
            .unwrap_or("?")
            .to_string();
        let class_err = c
            .get("class_rel_err")
            .ok()
            .and_then(|x| x.as_f64())
            .ok_or_else(|| format!("{path}: {class} missing class_rel_err"))?;
        let size_err = c
            .get("size_only_rel_err")
            .ok()
            .and_then(|x| x.as_f64())
            .ok_or_else(|| format!("{path}: {class} missing size_only_rel_err"))?;
        if class == "sparse" {
            saw_sparse = true;
            if class_err >= size_err {
                return Err(format!(
                    "{path}: sparse class estimate error {:.2}% does not \
                     strictly beat size-only {:.2}%",
                    class_err * 100.0,
                    size_err * 100.0
                ));
            }
        } else if class_err > size_err {
            return Err(format!(
                "{path}: {class} class estimate error {:.2}% exceeds \
                 size-only {:.2}%",
                class_err * 100.0,
                size_err * 100.0
            ));
        }
        println!(
            "irregular invariant: {class} estimate err {:.2}% vs size-only \
             {:.2}% (OK)",
            class_err * 100.0,
            size_err * 100.0
        );
    }
    if !saw_sparse {
        return Err(format!("{path}: no sparse class point"));
    }
    let replay = v
        .get("replay")
        .map_err(|_| format!("{path}: missing replay block"))?;
    let identical = replay
        .get("identical")
        .ok()
        .and_then(|x| x.as_bool())
        .ok_or_else(|| format!("{path}: replay missing identical"))?;
    let ms_a = replay.get("makespan_a").ok().and_then(|x| x.as_f64());
    let ms_b = replay.get("makespan_b").ok().and_then(|x| x.as_f64());
    let (ms_a, ms_b) = match (ms_a, ms_b) {
        (Some(a), Some(b)) => (a, b),
        _ => return Err(format!("{path}: replay missing makespan_a/makespan_b")),
    };
    let batches_a = replay.get("batches_a").ok().and_then(|x| x.as_u64());
    let batches_b = replay.get("batches_b").ok().and_then(|x| x.as_u64());
    if !identical || ms_a.to_bits() != ms_b.to_bits() || batches_a != batches_b {
        return Err(format!(
            "{path}: replaying the same trace diverged — makespan {ms_a:.6e} \
             vs {ms_b:.6e}, batches {batches_a:?} vs {batches_b:?} \
             (replay must be deterministic in virtual time)"
        ));
    }
    println!(
        "irregular invariant: replay makespan {ms_a:.6}s bit-identical \
         across two runs, {} batches (OK)",
        batches_a.unwrap_or(0)
    );
    Ok(())
}

/// The dataflow-drain gate (DESIGN.md §2.7), baseline-free: per workload
/// in BENCH_pr4.json, the dataflow drain's makespan must strictly beat
/// the barrier drain's, without inflating mean slot idle time (small
/// absolute tolerance: idle is a percentage with bench-level jitter).
fn check_dataflow_invariant(path: &str) -> Result<(), String> {
    let v = parse_file(Path::new(path))?;
    let points = v
        .get("points")
        .ok()
        .and_then(|p| p.as_arr())
        .ok_or_else(|| format!("{path}: missing points"))?;
    // (workload, drain) -> (makespan_ms, idle_pct)
    let mut modes: BTreeMap<(String, String), (f64, f64)> = BTreeMap::new();
    for p in points {
        let workload = p.get("workload").ok().and_then(|x| x.as_str());
        let drain = p.get("drain").ok().and_then(|x| x.as_str());
        let makespan = p.get("makespan_ms").ok().and_then(|x| x.as_f64());
        let idle = p.get("idle_pct").ok().and_then(|x| x.as_f64());
        if let (Some(w), Some(d), Some(m), Some(i)) = (workload, drain, makespan, idle) {
            modes.insert((w.to_string(), d.to_string()), (m, i));
        }
    }
    let workloads: Vec<String> = modes
        .keys()
        .map(|(w, _)| w.clone())
        .collect::<std::collections::BTreeSet<_>>()
        .into_iter()
        .collect();
    if workloads.is_empty() {
        return Err(format!("{path}: no (workload, drain) points"));
    }
    for w in &workloads {
        let barrier = modes
            .get(&(w.clone(), "barrier".to_string()))
            .ok_or_else(|| format!("{path}: {w} has no barrier point"))?;
        let dataflow = modes
            .get(&(w.clone(), "dataflow".to_string()))
            .ok_or_else(|| format!("{path}: {w} has no dataflow point"))?;
        if dataflow.0 >= barrier.0 {
            return Err(format!(
                "{path}: {w} dataflow makespan {:.3}ms does not beat \
                 barrier {:.3}ms",
                dataflow.0, barrier.0
            ));
        }
        if dataflow.1 > barrier.1 + 1.0 {
            return Err(format!(
                "{path}: {w} dataflow idle {:.2}% exceeds barrier {:.2}% + 1",
                dataflow.1, barrier.1
            ));
        }
        println!(
            "dataflow invariant: {w} {:.2}ms vs barrier {:.2}ms, idle \
             {:.1}% vs {:.1}% (OK)",
            dataflow.0, barrier.0, dataflow.1, barrier.1
        );
    }
    Ok(())
}

/// The batching gate (DESIGN.md §2.10), baseline-free and deterministic:
/// BENCH_pr7.json's batched serve must beat the unbatched run by >= 1.3x
/// on virtual (device-time) throughput at concurrency >> slot count, with
/// zero correctness drift (bit-identical sorted per-request execution
/// totals across the two modes).
fn check_batch_invariant(path: &str) -> Result<(), String> {
    let v = parse_file(Path::new(path))?;
    let speedup = v
        .get("speedup_virtual")
        .ok()
        .and_then(|s| s.as_f64())
        .ok_or_else(|| format!("{path}: missing speedup_virtual"))?;
    let identical = v
        .get("exec_totals_identical")
        .ok()
        .and_then(|x| x.as_bool())
        .ok_or_else(|| format!("{path}: missing exec_totals_identical"))?;
    if !identical {
        return Err(format!(
            "{path}: batched execution totals drifted from unbatched \
             (correctness, not a perf tradeoff)"
        ));
    }
    if speedup < 1.3 {
        return Err(format!(
            "{path}: batched virtual throughput {speedup:.3}x does not \
             reach the required 1.3x over unbatched"
        ));
    }
    println!(
        "batching invariant: {speedup:.2}x over unbatched, exec totals \
         bit-identical (OK)"
    );
    Ok(())
}

/// The feature's own regression gate, baseline-free and deterministic:
/// BENCH_pr5.json's co-scheduled run must beat the serialized run on the
/// virtual (device-time) makespan.
fn check_coschedule_invariant(fresh_path: &str) -> Result<(), String> {
    let v = parse_file(Path::new(fresh_path))?;
    let speedup = v
        .get("co_speedup_virtual")
        .ok()
        .and_then(|s| s.as_f64())
        .ok_or_else(|| format!("{fresh_path}: missing co_speedup_virtual"))?;
    if speedup <= 1.0 {
        return Err(format!(
            "{fresh_path}: co-scheduling virtual speedup {speedup:.3}x does \
             not beat the serialized whole-pool baseline"
        ));
    }
    println!("co-scheduling invariant: {speedup:.2}x over serialized (OK)");
    Ok(())
}

/// The KB-store warm-start gate (DESIGN.md §2.9), baseline-free and
/// deterministic: a serve warm-started from an exported snapshot must run
/// zero cold profile builds and spend strictly less wall time building
/// than the cold run it was exported from, and merging two stores in
/// either order must export byte-identical snapshots.
fn check_warmstart_invariant(path: &str) -> Result<(), String> {
    let v = parse_file(Path::new(path))?;
    let num = |key: &str| {
        v.get(key)
            .ok()
            .and_then(|x| x.as_f64())
            .ok_or_else(|| format!("{path}: missing {key}"))
    };
    let warm_builds = num("warm_cold_builds")?;
    let cold_secs = num("cold_build_secs_cold")?;
    let warm_secs = num("cold_build_secs_warm")?;
    let merge_ok = v
        .get("merge_deterministic")
        .ok()
        .and_then(|x| x.as_bool())
        .ok_or_else(|| format!("{path}: missing merge_deterministic"))?;
    if warm_builds != 0.0 {
        return Err(format!(
            "{path}: warm-started serve ran {warm_builds} cold builds (want 0)"
        ));
    }
    if warm_secs.partial_cmp(&cold_secs) != Some(std::cmp::Ordering::Less) {
        return Err(format!(
            "{path}: warm cold-build time {warm_secs:.4}s is not strictly \
             below the cold run's {cold_secs:.4}s"
        ));
    }
    if !merge_ok {
        return Err(format!("{path}: snapshot merge is order-dependent"));
    }
    println!(
        "kb warm-start invariant: 0 cold builds, {warm_secs:.4}s vs \
         {cold_secs:.4}s building, merge order-independent (OK)"
    );
    Ok(())
}

/// Compare every serve-workload throughput key present in both a fresh
/// `BENCH_*.json` (cwd) and its committed baseline.
fn check_baselines(baseline_dir: &str, tolerance: f64) -> Result<(), String> {
    let fresh = serve_metrics_in_dir(Path::new("."))?;
    let baseline = match std::fs::metadata(baseline_dir) {
        Ok(_) => serve_metrics_in_dir(Path::new(baseline_dir))?,
        Err(_) => {
            println!("no baseline dir {baseline_dir} — recording only");
            BTreeMap::new()
        }
    };
    let mut regressions = Vec::new();
    let mut compared = 0usize;
    for (key, fresh_val) in &fresh {
        match baseline.get(key) {
            Some(base_val) if *base_val > 0.0 => {
                compared += 1;
                let floor = base_val * (1.0 - tolerance);
                let verdict = if *fresh_val < floor {
                    regressions.push(format!(
                        "{key}: {fresh_val:.2} req/s < {floor:.2} \
                         (baseline {base_val:.2} - {:.0}%)",
                        tolerance * 100.0
                    ));
                    "REGRESSED"
                } else {
                    "ok"
                };
                println!("{key}: fresh {fresh_val:.2} vs baseline {base_val:.2} [{verdict}]");
            }
            _ => println!("{key}: fresh {fresh_val:.2} (no baseline — recording only)"),
        }
    }
    // A pinned metric the fresh run no longer produces is itself a
    // regression: a renamed workload or dropped point must not turn the
    // gate green by vanishing.
    for (key, base_val) in &baseline {
        if *base_val > 0.0 && !fresh.contains_key(key) {
            regressions.push(format!(
                "{key}: pinned baseline {base_val:.2} has no fresh measurement"
            ));
        }
    }
    println!(
        "baseline comparison: {compared} gated, {} recorded",
        fresh.len() - compared
    );
    if regressions.is_empty() {
        Ok(())
    } else {
        Err(format!(
            ">{:.0}% serve-throughput regression:\n  {}",
            tolerance * 100.0,
            regressions.join("\n  ")
        ))
    }
}

/// Serve-workload throughput keys of every `BENCH_*.json` in `dir`:
/// `bench:workload:metric -> req/s`. Deterministic virtual throughput is
/// preferred over wall throughput when a workload reports both.
fn serve_metrics_in_dir(dir: &Path) -> Result<BTreeMap<String, f64>, String> {
    let mut out = BTreeMap::new();
    for path in bench_files(dir)? {
        let v = parse_file(&path)?;
        let bench = match v.get("bench").ok().and_then(|b| b.as_str()) {
            Some(b) if SERVE_BENCHES.contains(&b) => b.to_string(),
            _ => continue,
        };
        if let Ok(ws) = v.get("workloads") {
            for w in ws.as_arr().unwrap_or(&[]) {
                let name = w.get("name").ok().and_then(|n| n.as_str()).unwrap_or("?");
                if let Some(r) = w.get("virtual_req_per_sec").ok().and_then(|x| x.as_f64()) {
                    out.insert(format!("{bench}:{name}:virtual_req_per_sec"), r);
                } else if let Some(r) =
                    w.get("requests_per_sec").ok().and_then(|x| x.as_f64())
                {
                    out.insert(format!("{bench}:{name}:requests_per_sec"), r);
                }
            }
        }
        if let Ok(ps) = v.get("points") {
            for p in ps.as_arr().unwrap_or(&[]) {
                let c = p.get("concurrency").ok().and_then(|x| x.as_u64());
                let r = p.get("requests_per_sec").ok().and_then(|x| x.as_f64());
                if let (Some(c), Some(r)) = (c, r) {
                    out.insert(format!("{bench}:c{c}:requests_per_sec"), r);
                }
                // Per-workload points (BENCH_pr3 style): keyed by workload
                // name plus the residency toggle when the point carries one.
                let w = p.get("workload").ok().and_then(|x| x.as_str());
                let r = p.get("req_per_sec").ok().and_then(|x| x.as_f64());
                if let (Some(w), Some(r)) = (w, r) {
                    let res = match p.get("residency").ok().and_then(|x| x.as_bool()) {
                        Some(true) => ":res_on",
                        Some(false) => ":res_off",
                        None => "",
                    };
                    out.insert(format!("{bench}:{w}{res}:req_per_sec"), r);
                }
            }
        }
    }
    Ok(out)
}

/// `BENCH_*.json` files directly under `dir`, sorted for stable output.
fn bench_files(dir: &Path) -> Result<Vec<std::path::PathBuf>, String> {
    let mut files: Vec<_> = std::fs::read_dir(dir)
        .map_err(|e| format!("{}: {e}", dir.display()))?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .map(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
                .unwrap_or(false)
        })
        .collect();
    files.sort();
    Ok(files)
}

fn parse_file(path: &Path) -> Result<Json, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
    Json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))
}

/// The merged markdown table shipped in the `bench-summary` artifact: one
/// row per numeric leaf of every `BENCH_*.json` in the cwd.
fn write_summary(out_path: &str) -> Result<(), String> {
    let mut rows = Vec::new();
    for path in bench_files(Path::new("."))? {
        let v = parse_file(&path)?;
        let file = path
            .file_name()
            .and_then(|n| n.to_str())
            .unwrap_or("?")
            .to_string();
        flatten(&v, "", &mut |metric, value| {
            rows.push((file.clone(), metric.to_string(), value));
        });
    }
    let mut md = String::from(
        "# Bench summary\n\n| file | metric | value |\n|---|---|---:|\n",
    );
    for (file, metric, value) in &rows {
        md.push_str(&format!("| {file} | {metric} | {value:.4} |\n"));
    }
    std::fs::write(out_path, &md).map_err(|e| format!("{out_path}: {e}"))?;
    println!("wrote {out_path} ({} rows)", rows.len());
    Ok(())
}

/// Depth-first numeric leaves with dotted paths; array elements are keyed
/// by their `name`/`workload`/`concurrency` field when present, else index.
fn flatten(v: &Json, prefix: &str, emit: &mut dyn FnMut(&str, f64)) {
    match v {
        Json::Num(n) => emit(prefix, *n),
        Json::Obj(map) => {
            for (k, val) in map {
                let p = if prefix.is_empty() {
                    k.clone()
                } else {
                    format!("{prefix}.{k}")
                };
                flatten(val, &p, emit);
            }
        }
        Json::Arr(items) => {
            for (i, item) in items.iter().enumerate() {
                let tag = item
                    .get("name")
                    .ok()
                    .and_then(|n| n.as_str().map(str::to_string))
                    .or_else(|| {
                        item.get("workload")
                            .ok()
                            .and_then(|n| n.as_str().map(str::to_string))
                    })
                    .or_else(|| {
                        item.get("concurrency")
                            .ok()
                            .and_then(|c| c.as_u64().map(|c| format!("c{c}")))
                    })
                    .unwrap_or_else(|| i.to_string());
                flatten(item, &format!("{prefix}[{tag}]"), emit);
            }
        }
        _ => {}
    }
}
