"""3-D gray-scale segmentation Pallas kernel.

Paper mapping (Section 4, "Segmentation"): transform a 3-D gray-scale image,
mapping every voxel to white, gray or black. No algorithmic dependencies
between voxels, but the elementary partitioning unit is one full XY plane so
partitioning happens along the depth dimension only.

Storage adaptation: the paper partitions "over the last dimension"; we store
the volume depth-major — f32[d, h, w] — so one epu unit (an XY plane) is a
contiguous slab and the Rust runtime can slice partitions without gathers.

Voxels are f32 in [0, 255]; thresholds t_low/t_high are partition-invariant
values in a COPY-mode f32[2] vector: v < t_low -> 0 (black),
v > t_high -> 255 (white), else 128 (gray).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEPTH_BLOCK = 8  # XY planes per grid step; one plane is the epu


def _segmentation_kernel(t_ref, x_ref, o_ref):
    x = x_ref[...]
    lo, hi = t_ref[0], t_ref[1]
    o_ref[...] = jnp.where(x < lo, 0.0, jnp.where(x > hi, 255.0, 128.0))


@jax.jit
def segmentation(vol, thresholds):
    """vol: f32[d, h, w]; thresholds: f32[2] = (t_low, t_high)."""
    d, h, w = vol.shape
    db = min(DEPTH_BLOCK, d)
    grid = (d + db - 1) // db
    return pl.pallas_call(
        _segmentation_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((db, h, w), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((db, h, w), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((d, h, w), vol.dtype),
        interpret=True,
    )(thresholds, vol)
