"""L1 Pallas kernels for the Marrow benchmark suite.

Each module exposes the Pallas (interpret=True) implementation of one of the
paper's five benchmark kernels; `ref.py` holds the pure-jnp oracles the
pytest suite checks them against.
"""
