"""SAXPY Pallas kernel: out = alpha * x + y  (BLAS level-1).

Paper mapping (Section 4, "Saxpy"): embarrassingly parallel Map benchmark,
one element per thread, no partitioning restrictions (epu = 1).

TPU adaptation: the OpenCL work-group over a 1-D range becomes a Pallas grid
over VMEM-resident blocks; BLOCK elements per grid step keeps the block well
under VMEM while remaining vector-unit friendly.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 1-D block: 2048 f32 = 8 KiB per operand block in VMEM — small enough that
# double buffering of (x, y, out) blocks is trivially resident.
BLOCK = 2048


def _saxpy_kernel(alpha_ref, x_ref, y_ref, o_ref):
    o_ref[...] = alpha_ref[0] * x_ref[...] + y_ref[...]


@functools.partial(jax.jit, static_argnames=())
def saxpy(alpha, x, y):
    """alpha: f32[1]; x, y: f32[n] with n % BLOCK == 0 or n < BLOCK."""
    n = x.shape[0]
    block = min(BLOCK, n)
    grid = (n + block - 1) // block
    return pl.pallas_call(
        _saxpy_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),  # alpha broadcast to all steps
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=True,
    )(alpha, x, y)
