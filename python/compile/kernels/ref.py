"""Pure-jnp oracles for every Pallas kernel — the correctness ground truth.

Each `ref_*` mirrors the semantics of the corresponding Pallas kernel using
only jax.numpy (no pallas), so pytest can assert_allclose(kernel, ref).
The gaussian-noise oracle reimplements the same counter-based hash so the
two are bit-comparable (the RNG is part of the kernel's contract).
"""

import numpy as np
import jax
import jax.numpy as jnp

_TWO_PI = 6.283185307179586


# --- saxpy -----------------------------------------------------------------

def ref_saxpy(alpha, x, y):
    return alpha[0] * x + y


# --- filters ---------------------------------------------------------------

def _hash_u32(x):
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def _uniform01(bits):
    return (bits >> 8).astype(jnp.float32) / jnp.float32(1 << 24) + jnp.float32(
        1.0 / (1 << 25)
    )


def ref_gaussian_noise(img, seed, row_offset=0, sigma=8.0):
    h, w = img.shape
    off = jnp.asarray(row_offset).reshape(-1)[0] if hasattr(row_offset, "shape") else row_offset
    row_ids = (
        jax.lax.broadcasted_iota(jnp.uint32, (h, w), 0)
        + jnp.uint32(off)
    )
    col_ids = jax.lax.broadcasted_iota(jnp.uint32, (h, w), 1)
    pix = row_ids * jnp.uint32(65521) + col_ids
    s = seed[0].astype(jnp.uint32)
    u1 = _uniform01(_hash_u32(pix ^ s))
    u2 = _uniform01(_hash_u32(pix + s * jnp.uint32(2654435761)))
    noise = jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(jnp.float32(_TWO_PI) * u2)
    return jnp.clip(img + noise * jnp.float32(sigma), 0.0, 255.0)


def ref_solarize(img, thresh):
    return jnp.where(img > thresh[0], 255.0 - img, img)


def ref_mirror(img):
    return img[:, ::-1]


def ref_filter_pipeline(img, seed, thresh, row_offset=0, sigma=8.0):
    return ref_mirror(
        ref_solarize(ref_gaussian_noise(img, seed, row_offset, sigma), thresh)
    )


# --- fft -------------------------------------------------------------------

def ref_fft(re, im):
    z = jnp.fft.fft(re.astype(jnp.complex64) + 1j * im.astype(jnp.complex64))
    return jnp.real(z).astype(jnp.float32), jnp.imag(z).astype(jnp.float32)


def ref_ifft(re, im):
    z = jnp.fft.ifft(re.astype(jnp.complex64) + 1j * im.astype(jnp.complex64))
    return jnp.real(z).astype(jnp.float32), jnp.imag(z).astype(jnp.float32)


# --- nbody -----------------------------------------------------------------

def ref_nbody_accel(pos, offset, chunk, eps=1e-3):
    start = int(np.asarray(offset)[0])
    mine = pos[start : start + chunk]
    d = pos[None, :, :3] - mine[:, None, :3]
    r2 = jnp.sum(d * d, axis=-1) + jnp.float32(eps * eps)
    inv_r3 = r2 ** jnp.float32(-1.5)
    w = pos[None, :, 3] * inv_r3
    return jnp.sum(d * w[..., None], axis=1)


# --- segmentation ----------------------------------------------------------

def ref_segmentation(vol, thresholds):
    lo, hi = thresholds[0], thresholds[1]
    return jnp.where(vol < lo, 0.0, jnp.where(vol > hi, 255.0, 128.0))
