"""Image filter Pallas kernels: Gaussian Noise, Solarize, Mirror.

Paper mapping (Section 4, "Filter Pipeline"): three filters composed in a
Pipeline skeleton. Each filter is independently applicable to distinct image
lines, so the *image line is the elementary partitioning unit* and each
OpenCL thread processes two pixels (work_per_thread = 2).

TPU adaptation: the per-line OpenCL work-group becomes a Pallas grid over
row-blocks; a (ROWS_BLOCK, width) f32 tile lives in VMEM. The paper's
work_per_thread=2 becomes irrelevant at the ISA level (the VPU is fully
vectorized across the row) but is preserved in the kernel metadata because
the L3 decomposer uses it in the divisibility constraints of Section 3.1.

Gaussian noise uses a counter-based PRNG (threefry-light / xorshift hash of
the pixel coordinate and a seed scalar) + Box-Muller so that the kernel is a
pure function of (image, seed) — same trick GPU OpenCL kernels use, no state.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS_BLOCK = 8  # rows per grid step; one image line is the epu

_TWO_PI = 6.283185307179586


def _hash_u32(x):
    """xorshift-mult avalanche hash on uint32 (counter-based RNG core)."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def _uniform01(bits):
    """uint32 -> f32 uniform in (0, 1): use top 24 bits, never exactly 0."""
    return (bits >> 8).astype(jnp.float32) / jnp.float32(1 << 24) + jnp.float32(
        1.0 / (1 << 25)
    )


def _gaussian_noise_kernel(seed_ref, rowoff_ref, x_ref, o_ref, *, sigma):
    i = pl.program_id(0)
    x = x_ref[...]
    rows, cols = x.shape
    # Global pixel coordinate -> two independent uniforms -> Box-Muller.
    # The row offset is a *dynamic* input so any chunking of the image
    # reproduces the same noise field (partition-safety, Section 3.1).
    row_ids = (
        jax.lax.broadcasted_iota(jnp.uint32, (rows, cols), 0)
        + (jnp.uint32(i) * jnp.uint32(rows) + rowoff_ref[0].astype(jnp.uint32))
    )
    col_ids = jax.lax.broadcasted_iota(jnp.uint32, (rows, cols), 1)
    pix = row_ids * jnp.uint32(65521) + col_ids
    seed = seed_ref[0].astype(jnp.uint32)
    u1 = _uniform01(_hash_u32(pix ^ seed))
    u2 = _uniform01(_hash_u32(pix + seed * jnp.uint32(2654435761)))
    mag = jnp.sqrt(-2.0 * jnp.log(u1))
    noise = mag * jnp.cos(jnp.float32(_TWO_PI) * u2) * jnp.float32(sigma)
    o_ref[...] = jnp.clip(x + noise, 0.0, 255.0)


def _solarize_kernel(thresh_ref, x_ref, o_ref):
    x = x_ref[...]
    t = thresh_ref[0]
    o_ref[...] = jnp.where(x > t, 255.0 - x, x)


def _mirror_kernel(x_ref, o_ref):
    # Horizontal flip; operates within a row, so row-partitioning is safe.
    o_ref[...] = x_ref[...][:, ::-1]


def _row_call(kernel, img, scalars, rows_block):
    h, w = img.shape
    rb = min(rows_block, h)
    grid = (h + rb - 1) // rb
    in_specs = [pl.BlockSpec(memory_space=pl.ANY) for _ in scalars]
    in_specs.append(pl.BlockSpec((rb, w), lambda i: (i, 0)))
    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((rb, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w), img.dtype),
        interpret=True,
    )(*scalars, img)


@functools.partial(jax.jit, static_argnames=("sigma",))
def gaussian_noise(img, seed, row_offset=None, sigma=8.0):
    """img: f32[h, w] in [0,255]; seed: i32[1]; row_offset: i32[1].

    `row_offset` is the global row index of the chunk's first line (the
    paper's partition-bound `Offset` trait), passed as a dynamic input so a
    line-partitioned execution reproduces the whole-image noise field for
    *any* chunk size the runtime picks.
    """
    if row_offset is None:
        row_offset = jnp.zeros((1,), jnp.int32)
    kern = functools.partial(_gaussian_noise_kernel, sigma=float(sigma))
    return _row_call(kern, img, [seed, row_offset], ROWS_BLOCK)


@jax.jit
def solarize(img, thresh):
    """img: f32[h, w]; thresh: f32[1]. Invert pixels brighter than thresh."""
    return _row_call(_solarize_kernel, img, [thresh], ROWS_BLOCK)


@jax.jit
def mirror(img):
    """img: f32[h, w]. Horizontal mirror."""
    return _row_call(_mirror_kernel, img, [], ROWS_BLOCK)
