"""Direct-sum N-Body acceleration Pallas kernel.

Paper mapping (Section 4, "NBody"): iterative simulation under the Loop
skeleton. The kernel implements the direct-sum algorithm: every body
interacts with all the others, so the *whole* body set is replicated to every
device (COPY transfer mode) while the distribution is performed at body
level — each partition computes accelerations for its slice of bodies.

The position/mass array is f32[n, 4] = (x, y, z, m). The kernel computes
f32[chunk, 3] accelerations for the `chunk` bodies starting at `offset`
(a partition-bound scalar, the paper's `Offset` trait). Softened gravity:
a_i = sum_j m_j * (r_j - r_i) / (|r_j - r_i|^2 + eps^2)^{3/2}.

TPU adaptation: the OpenCL version tiles bodies through local memory; here
the full body set sits in VMEM (n <= 4096 -> 64 KiB) and the (chunk, n)
interaction matrix is produced by broadcasting over the VPU; for larger n the
BlockSpec would tile the j-axis, accumulating partial sums per grid step.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SOFTENING = 1e-3
CHUNK_BLOCK = 128  # bodies per grid step


def _nbody_kernel(offset_ref, pos_ref, acc_ref, *, eps2):
    i = pl.program_id(0)
    chunk = acc_ref.shape[0]
    start = offset_ref[0] + i * chunk
    all_pos = pos_ref[...]  # (n, 4), COPY-mode full snapshot
    mine = jax.lax.dynamic_slice(all_pos, (start, 0), (chunk, 4))
    d = all_pos[None, :, :3] - mine[:, None, :3]  # (chunk, n, 3)
    r2 = jnp.sum(d * d, axis=-1) + jnp.float32(eps2)  # (chunk, n)
    inv_r3 = jax.lax.rsqrt(r2) / r2
    w = all_pos[None, :, 3] * inv_r3  # (chunk, n)
    acc_ref[...] = jnp.sum(d * w[..., None], axis=1)


@functools.partial(jax.jit, static_argnames=("chunk",))
def nbody_accel(pos, offset, chunk):
    """pos: f32[n, 4]; offset: i32[1]; returns f32[chunk, 3] accelerations.

    Computes accelerations for bodies [offset, offset + chunk). The pallas
    grid walks CHUNK_BLOCK-body blocks inside the chunk; the full position
    array is broadcast (un-blocked) to every step.
    """
    cb = min(CHUNK_BLOCK, chunk)
    grid = (chunk + cb - 1) // cb
    kern = functools.partial(_nbody_kernel, eps2=SOFTENING * SOFTENING)
    return pl.pallas_call(
        kern,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),  # offset scalar
            pl.BlockSpec(memory_space=pl.ANY),  # full body set, every step
        ],
        out_specs=pl.BlockSpec((cb, 3), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((chunk, 3), jnp.float32),
        interpret=True,
    )(offset, pos)
