"""Batched radix-2 FFT Pallas kernel (iterative Cooley-Tukey).

Paper mapping (Section 4, "FFT"): a set of fixed-size FFTs pipelined with
their inversion, adapted from the SHOC benchmark suite. The elementary
partitioning unit is one whole FFT, so devices are assigned whole FFTs and
the batch dimension is the partition axis.

TPU adaptation: the paper's OpenCL FFT uses local memory for the butterfly
exchanges within a work-group. In Pallas the whole (batch-block, n) tile is
VMEM-resident and the butterflies are expressed as static reshape/concat
vector ops over the tile; for small n the MXU-native alternative is
DFT-as-matmul against a precomputed (n, n) twiddle matrix in bfloat16 — we
keep the O(n log n) ladder since n = 512 keeps the f32 tile tiny and the
reference numerics exact.

Complex values travel as separate re/im f32 planes (the PJRT literal bridge
on the Rust side is f32-only), shape (batch, n).
"""

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

FFT_N = 512  # points per FFT; one FFT is the epu
BATCH_BLOCK = 4  # FFTs per grid step


def _bit_reverse_perm(n):
    """Bit-reversal permutation as a traced jnp array (no captured consts:
    Pallas kernels must not close over ndarray constants, so the permutation
    is rebuilt from iota with a static loop over the bit count)."""
    bits = n.bit_length() - 1
    i = jax.lax.iota(jnp.int32, n)
    r = jnp.zeros((n,), jnp.int32)
    for b in range(bits):
        r = r | (((i >> b) & 1) << (bits - 1 - b))
    return r


def _fft_stages(re, im, n, inverse):
    """Iterative radix-2 DIT over the last axis (static length n)."""
    perm = _bit_reverse_perm(n)
    re = jnp.take(re, perm, axis=-1)
    im = jnp.take(im, perm, axis=-1)
    sign = 1.0 if inverse else -1.0
    m = 2
    while m <= n:
        half = m // 2
        k = jax.lax.iota(jnp.float32, half)
        ang = jnp.float32(sign * 2.0 * np.pi / m) * k
        wr = jnp.cos(ang)
        wi = jnp.sin(ang)
        shape = re.shape[:-1] + (n // m, m)
        re2 = re.reshape(shape)
        im2 = im.reshape(shape)
        er, ei = re2[..., :half], im2[..., :half]
        orr, oi = re2[..., half:], im2[..., half:]
        tr = orr * wr - oi * wi
        ti = orr * wi + oi * wr
        re = jnp.concatenate([er + tr, er - tr], axis=-1).reshape(re.shape)
        im = jnp.concatenate([ei + ti, ei - ti], axis=-1).reshape(im.shape)
        m *= 2
    if inverse:
        re = re / n
        im = im / n
    return re, im


def _fft_kernel(re_ref, im_ref, or_ref, oi_ref, *, n, inverse):
    re, im = _fft_stages(re_ref[...], im_ref[...], n, inverse)
    or_ref[...] = re
    oi_ref[...] = im


def _batched_call(re, im, inverse):
    b, n = re.shape
    bb = min(BATCH_BLOCK, b)
    grid = (b + bb - 1) // bb
    kern = functools.partial(_fft_kernel, n=n, inverse=inverse)
    return pl.pallas_call(
        kern,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((bb, n), lambda i: (i, 0)),
            pl.BlockSpec((bb, n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, n), lambda i: (i, 0)),
            pl.BlockSpec((bb, n), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n), jnp.float32),
            jax.ShapeDtypeStruct((b, n), jnp.float32),
        ],
        interpret=True,
    )(re, im)


@jax.jit
def fft(re, im):
    """Forward FFT over the last axis. re, im: f32[batch, n], n power of 2."""
    return _batched_call(re, im, inverse=False)


@jax.jit
def ifft(re, im):
    """Inverse FFT (normalized by 1/n)."""
    return _batched_call(re, im, inverse=True)
