"""L2 — JAX compositions of the L1 Pallas kernels (the paper's SCTs).

Each entry point here corresponds to the compute body of one Marrow skeleton
computational tree, expressed over one *chunk* (the static-shaped unit the
Rust L3 coordinator launches). aot.py lowers every (entry, chunk shape) pair
to an HLO-text artifact; the Rust runtime executes a partition as a sequence
of chunk launches (Section 3.1's SPMD extension with the chunk playing the
role of the work-group).

The filter pipeline is deliberately composed *inside one jit* so the three
kernels lower into a single fused HLO module — that is the locality-aware
domain decomposition of Section 3.1: intermediate images persist in device
memory between consecutive kernels, with zero host round-trips. aot.py also
lowers the three filters separately for the `ablation_locality` bench, which
measures the cost of re-partitioning between kernels.
"""

import jax
import jax.numpy as jnp

from compile.kernels import fft as fft_k
from compile.kernels import filters, nbody, saxpy, segmentation


# --- Map: SAXPY -------------------------------------------------------------

@jax.jit
def saxpy_chunk(alpha, x, y):
    """alpha: f32[1]; x, y: f32[n] -> f32[n]."""
    return saxpy.saxpy(alpha, x, y)


# --- Pipeline: Gaussian Noise -> Solarize -> Mirror -------------------------

@jax.jit
def filter_pipeline_chunk(img, seed, row_off, thresh):
    """img: f32[rows, w]; seed, row_off: i32[1]; thresh: f32[1]."""
    x = filters.gaussian_noise(img, seed, row_off)
    x = filters.solarize(x, thresh)
    return filters.mirror(x)


@jax.jit
def gaussian_noise_chunk(img, seed, row_off):
    return filters.gaussian_noise(img, seed, row_off)


@jax.jit
def solarize_chunk(img, thresh):
    return filters.solarize(img, thresh)


@jax.jit
def mirror_chunk(img):
    return filters.mirror(img)


# --- Pipeline: FFT -> IFFT ---------------------------------------------------

@jax.jit
def fft_roundtrip_chunk(re, im):
    """re, im: f32[batch, n] -> (f32[batch, n], f32[batch, n]).

    The paper pipelines FFT with its inversion; the roundtrip output should
    reproduce the input (the pytest suite checks both the forward stage and
    the roundtrip identity).
    """
    fr, fi = fft_k.fft(re, im)
    return fft_k.ifft(fr, fi)


@jax.jit
def fft_forward_chunk(re, im):
    return fft_k.fft(re, im)


# --- Loop body: N-Body -------------------------------------------------------

def nbody_accel_chunk(pos, offset, chunk):
    """pos: f32[n, 4]; offset: i32[1] -> f32[chunk, 3]. chunk is static."""
    return nbody.nbody_accel(pos, offset, chunk)


# --- Map: Segmentation -------------------------------------------------------

@jax.jit
def segmentation_chunk(vol, thresholds):
    """vol: f32[h, w, d]; thresholds: f32[2] -> f32[h, w, d]."""
    return segmentation.segmentation(vol, thresholds)
