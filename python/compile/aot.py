"""AOT pipeline: lower every (L2 entry, chunk shape) pair to HLO text.

Emits HLO *text* (NOT `lowered.compiler_ir("hlo")` protos and NOT
`.serialize()`): jax >= 0.5 emits HloModuleProtos with 64-bit instruction
ids that the Rust side's xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Also emits `artifacts/manifest.json`, the contract between the Python
compile path and the Rust runtime: for each artifact its input/output
specs, the chunk size in elementary partitioning units, and the analytic
flop/byte counts the L3 cost model uses.

Python runs ONLY here (build time); the Rust binary is self-contained once
`make artifacts` has run.
"""

import argparse
import hashlib
import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels import nbody

FFT_N = 512


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype="f32"):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32 if dtype == "f32" else jnp.int32)


def _io(name, shape, dtype="f32"):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def flops_filter(rows, w):
    # hash (2x ~12 ops) + log/sqrt/cos (~30) + solarize (2) + mirror (0 flops)
    return int(60 * rows * w)


def artifact_entries():
    """Yield (artifact_name, fn, example_args, manifest_entry)."""
    entries = []

    # --- saxpy: 1-D map, epu = 1 element -----------------------------------
    for n in (4096, 32768, 262144):
        name = f"saxpy_n{n}"
        entries.append(
            (
                name,
                model.saxpy_chunk,
                (spec((1,)), spec((n,)), spec((n,))),
                {
                    "family": "saxpy",
                    "inputs": [_io("alpha", (1,)), _io("x", (n,)), _io("y", (n,))],
                    "outputs": [_io("out", (n,))],
                    "chunk_units": n,  # epu = 1 element
                    "flops": 2 * n,
                    "bytes": 12 * n,
                },
            )
        )

    # --- filter pipeline: 2-D rows, epu = 1 image line ---------------------
    for rows in (8, 64):
        for w in (256, 512, 1024):
            name = f"filter_pipeline_r{rows}_w{w}"
            entries.append(
                (
                    name,
                    model.filter_pipeline_chunk,
                    (spec((rows, w)), spec((1,), "i32"), spec((1,), "i32"), spec((1,))),
                    {
                        "family": "filter_pipeline",
                        "inputs": [
                            _io("img", (rows, w)),
                            _io("seed", (1,), "i32"),
                            _io("row_off", (1,), "i32"),
                            _io("thresh", (1,)),
                        ],
                        "outputs": [_io("out", (rows, w))],
                        "chunk_units": rows,  # epu = 1 line
                        "flops": flops_filter(rows, w),
                        "bytes": 8 * rows * w,
                    },
                )
            )

    # --- individual filters (locality ablation + unit composition tests) ---
    rows, w = 8, 512
    entries.append(
        (
            f"gaussian_noise_r{rows}_w{w}",
            model.gaussian_noise_chunk,
            (spec((rows, w)), spec((1,), "i32"), spec((1,), "i32")),
            {
                "family": "gaussian_noise",
                "inputs": [
                    _io("img", (rows, w)),
                    _io("seed", (1,), "i32"),
                    _io("row_off", (1,), "i32"),
                ],
                "outputs": [_io("out", (rows, w))],
                "chunk_units": rows,
                "flops": int(44 * rows * w),
                "bytes": 8 * rows * w,
            },
        )
    )
    entries.append(
        (
            f"solarize_r{rows}_w{w}",
            model.solarize_chunk,
            (spec((rows, w)), spec((1,))),
            {
                "family": "solarize",
                "inputs": [_io("img", (rows, w)), _io("thresh", (1,))],
                "outputs": [_io("out", (rows, w))],
                "chunk_units": rows,
                "flops": 2 * rows * w,
                "bytes": 8 * rows * w,
            },
        )
    )
    entries.append(
        (
            f"mirror_r{rows}_w{w}",
            model.mirror_chunk,
            (spec((rows, w)),),
            {
                "family": "mirror",
                "inputs": [_io("img", (rows, w))],
                "outputs": [_io("out", (rows, w))],
                "chunk_units": rows,
                "flops": 0,
                "bytes": 8 * rows * w,
            },
        )
    )

    # --- fft roundtrip: epu = 1 whole FFT -----------------------------------
    n = FFT_N
    lg = n.bit_length() - 1
    for batch in (4, 32):
        name = f"fft_roundtrip_b{batch}_n{n}"
        entries.append(
            (
                name,
                model.fft_roundtrip_chunk,
                (spec((batch, n)), spec((batch, n))),
                {
                    "family": "fft_roundtrip",
                    "inputs": [_io("re", (batch, n)), _io("im", (batch, n))],
                    "outputs": [_io("re", (batch, n)), _io("im", (batch, n))],
                    "chunk_units": batch,  # epu = 1 FFT
                    "flops": 2 * batch * 5 * n * lg,  # fwd + inv
                    "bytes": 16 * batch * n,
                },
            )
        )

    # --- nbody: COPY-mode full set + per-partition chunk --------------------
    for total, chunk in ((512, 128), (2048, 256)):
        name = f"nbody_accel_N{total}_c{chunk}"

        def make_fn(c):
            def fn(pos, offset):
                return nbody.nbody_accel(pos, offset, c)

            return fn

        entries.append(
            (
                name,
                jax.jit(make_fn(chunk)),
                (spec((total, 4)), spec((1,), "i32")),
                {
                    "family": "nbody_accel",
                    "inputs": [_io("pos", (total, 4)), _io("offset", (1,), "i32")],
                    "outputs": [_io("acc", (chunk, 3))],
                    "chunk_units": chunk,  # epu = 1 body
                    "flops": 20 * chunk * total,
                    "bytes": 16 * total + 12 * chunk,
                },
            )
        )

    # --- segmentation: epu = 1 XY plane (depth-major storage) ----------------
    h, w2 = 32, 32
    for d in (8, 64):
        name = f"segmentation_d{d}_h{h}_w{w2}"
        entries.append(
            (
                name,
                model.segmentation_chunk,
                (spec((d, h, w2)), spec((2,))),
                {
                    "family": "segmentation",
                    "inputs": [_io("vol", (d, h, w2)), _io("thresholds", (2,))],
                    "outputs": [_io("out", (d, h, w2))],
                    "chunk_units": d,  # epu = 1 plane
                    "flops": 2 * h * w2 * d,
                    "bytes": 8 * h * w2 * d,
                },
            )
        )

    return entries


def main():
    ap = argparse.ArgumentParser(description="AOT-lower Marrow kernels to HLO text")
    ap.add_argument("--out-dir", default=None, help="artifacts directory")
    ap.add_argument("--only", default=None, help="substring filter on artifact names")
    args = ap.parse_args()

    repo_root = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    out_dir = args.out_dir or os.path.join(repo_root, "artifacts")
    os.makedirs(out_dir, exist_ok=True)

    manifest = {"format": 1, "artifacts": []}
    for name, fn, example_args, meta in artifact_entries():
        if args.only and args.only not in name:
            continue
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        entry = dict(meta)
        entry["name"] = name
        entry["file"] = fname
        entry["sha256"] = hashlib.sha256(text.encode()).hexdigest()
        manifest["artifacts"].append(entry)
        print(f"  lowered {name:34s} -> {fname} ({len(text)} chars)")

    man_path = os.path.join(out_dir, "manifest.json")
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {man_path} ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
