"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

hypothesis sweeps shapes/values; every kernel is asserted allclose against
its ref.py oracle. Tolerances: exact elementwise kernels are compared at
float32 ulp scale; fft/nbody accumulate rounding and get wider (but still
tight) bounds.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import fft, filters, nbody, ref, saxpy, segmentation

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def f32(x):
    return jnp.asarray(x, jnp.float32)


def rand_img(rng, h, w):
    return f32(rng.uniform(0.0, 255.0, size=(h, w)))


# --- saxpy ------------------------------------------------------------------


class TestSaxpy:
    @given(
        n=st.sampled_from([1, 7, 128, 2048, 4096, 6144]),
        alpha=st.floats(-10, 10, allow_nan=False, width=32),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, n, alpha, seed):
        rng = np.random.default_rng(seed)
        a = f32([alpha])
        x = f32(rng.normal(size=n))
        y = f32(rng.normal(size=n))
        got = saxpy.saxpy(a, x, y)
        want = ref.ref_saxpy(a, x, y)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-5)

    def test_zero_alpha_is_identity_on_y(self):
        rng = np.random.default_rng(0)
        y = f32(rng.normal(size=2048))
        x = f32(rng.normal(size=2048))
        np.testing.assert_array_equal(saxpy.saxpy(f32([0.0]), x, y), y)

    def test_block_boundary_sizes(self):
        rng = np.random.default_rng(1)
        for n in (saxpy.BLOCK, 2 * saxpy.BLOCK, 3 * saxpy.BLOCK):
            x = f32(rng.normal(size=n))
            y = f32(rng.normal(size=n))
            np.testing.assert_allclose(
                saxpy.saxpy(f32([1.5]), x, y),
                ref.ref_saxpy(f32([1.5]), x, y),
                rtol=1e-5,
                atol=1e-4,
            )


# --- filters ----------------------------------------------------------------


class TestFilters:
    @given(
        h=st.sampled_from([8, 16, 24, 64]),
        w=st.sampled_from([32, 256, 512]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_gaussian_noise_matches_ref(self, h, w, seed):
        rng = np.random.default_rng(seed)
        img = rand_img(rng, h, w)
        s = jnp.asarray([seed % 65536], jnp.int32)
        got = filters.gaussian_noise(img, s)
        want = ref.ref_gaussian_noise(img, s)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)

    def test_gaussian_noise_deterministic(self):
        rng = np.random.default_rng(3)
        img = rand_img(rng, 16, 64)
        s = jnp.asarray([42], jnp.int32)
        a = filters.gaussian_noise(img, s)
        b = filters.gaussian_noise(img, s)
        np.testing.assert_array_equal(a, b)

    def test_gaussian_noise_seed_sensitivity(self):
        rng = np.random.default_rng(4)
        img = rand_img(rng, 16, 64)
        a = filters.gaussian_noise(img, jnp.asarray([1], jnp.int32))
        b = filters.gaussian_noise(img, jnp.asarray([2], jnp.int32))
        assert not np.allclose(a, b)

    def test_gaussian_noise_stays_in_range(self):
        rng = np.random.default_rng(5)
        img = rand_img(rng, 32, 128)
        out = np.asarray(filters.gaussian_noise(img, jnp.asarray([9], jnp.int32)))
        assert out.min() >= 0.0 and out.max() <= 255.0

    def test_gaussian_noise_row_offset_partition_consistency(self):
        """Computing rows [8:16) as a standalone chunk with row_offset=8 must
        equal rows [8:16) of the full-image run — the property that makes the
        kernel safe under the paper's line-partitioned decomposition."""
        rng = np.random.default_rng(6)
        img = rand_img(rng, 16, 64)
        s = jnp.asarray([11], jnp.int32)
        whole = np.asarray(filters.gaussian_noise(img, s))
        part = np.asarray(filters.gaussian_noise(img[8:16], s, jnp.asarray([8], jnp.int32)))
        np.testing.assert_array_equal(whole[8:16], part)

    @given(
        h=st.sampled_from([8, 16]),
        w=st.sampled_from([64, 512]),
        t=st.floats(0, 255, width=32),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_solarize_matches_ref(self, h, w, t, seed):
        rng = np.random.default_rng(seed)
        img = rand_img(rng, h, w)
        th = f32([t])
        np.testing.assert_array_equal(
            filters.solarize(img, th), ref.ref_solarize(img, th)
        )

    def test_solarize_involution_above_threshold(self):
        # solarize(solarize(x)) == x when 255-x stays above the threshold
        img = f32(np.full((8, 64), 200.0))
        th = f32([100.0])
        once = filters.solarize(img, th)  # -> 55, below threshold
        np.testing.assert_array_equal(np.asarray(once), np.full((8, 64), 55.0))

    @given(h=st.sampled_from([8, 16, 32]), w=st.sampled_from([31, 64, 512]))
    def test_mirror_matches_ref(self, h, w):
        rng = np.random.default_rng(h * 1000 + w)
        img = rand_img(rng, h, w)
        np.testing.assert_array_equal(filters.mirror(img), ref.ref_mirror(img))

    def test_mirror_is_involution(self):
        rng = np.random.default_rng(7)
        img = rand_img(rng, 16, 128)
        np.testing.assert_array_equal(filters.mirror(filters.mirror(img)), img)


# --- fft ---------------------------------------------------------------------


class TestFFT:
    @given(
        batch=st.sampled_from([1, 2, 4, 8]),
        n=st.sampled_from([8, 64, 256, 512]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_forward_matches_ref(self, batch, n, seed):
        rng = np.random.default_rng(seed)
        re = f32(rng.normal(size=(batch, n)))
        im = f32(rng.normal(size=(batch, n)))
        fr, fi = fft.fft(re, im)
        rr, ri = ref.ref_fft(re, im)
        np.testing.assert_allclose(fr, rr, atol=n * 2e-6 + 1e-4)
        np.testing.assert_allclose(fi, ri, atol=n * 2e-6 + 1e-4)

    @given(
        batch=st.sampled_from([1, 4]),
        n=st.sampled_from([64, 512]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_roundtrip_identity(self, batch, n, seed):
        rng = np.random.default_rng(seed)
        re = f32(rng.normal(size=(batch, n)))
        im = f32(rng.normal(size=(batch, n)))
        fr, fi = fft.fft(re, im)
        ir, ii = fft.ifft(fr, fi)
        np.testing.assert_allclose(ir, re, atol=1e-4)
        np.testing.assert_allclose(ii, im, atol=1e-4)

    def test_impulse_is_flat_spectrum(self):
        re = np.zeros((1, 64), np.float32)
        re[0, 0] = 1.0
        fr, fi = fft.fft(f32(re), f32(np.zeros((1, 64))))
        np.testing.assert_allclose(fr, np.ones((1, 64)), atol=1e-5)
        np.testing.assert_allclose(fi, np.zeros((1, 64)), atol=1e-5)

    def test_linearity(self):
        rng = np.random.default_rng(8)
        a = f32(rng.normal(size=(2, 128)))
        b = f32(rng.normal(size=(2, 128)))
        z = f32(np.zeros((2, 128)))
        fa, _ = fft.fft(a, z)
        fb, _ = fft.fft(b, z)
        fab, _ = fft.fft(a + b, z)
        np.testing.assert_allclose(fab, fa + fb, atol=1e-3)

    def test_parseval(self):
        rng = np.random.default_rng(9)
        re = f32(rng.normal(size=(1, 512)))
        im = f32(rng.normal(size=(1, 512)))
        fr, fi = fft.fft(re, im)
        t = float(np.sum(np.square(re) + np.square(im)))
        s = float(np.sum(np.square(np.asarray(fr)) + np.square(np.asarray(fi)))) / 512
        assert abs(t - s) / t < 1e-4


# --- nbody -------------------------------------------------------------------


class TestNBody:
    @given(
        n=st.sampled_from([128, 256, 512]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_full(self, n, seed):
        rng = np.random.default_rng(seed)
        pos = f32(rng.uniform(-1, 1, size=(n, 4)))
        pos = pos.at[:, 3].set(f32(rng.uniform(0.5, 2.0, size=n)))
        off = jnp.asarray([0], jnp.int32)
        got = nbody.nbody_accel(pos, off, n)
        want = ref.ref_nbody_accel(pos, off, n)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-2)

    def test_partition_chunks_tile_the_full_result(self):
        """Union of per-chunk results == whole-set result (Section 3.1)."""
        rng = np.random.default_rng(10)
        n, c = 512, 128
        pos = f32(rng.uniform(-1, 1, size=(n, 4))).at[:, 3].set(1.0)
        whole = np.asarray(nbody.nbody_accel(pos, jnp.asarray([0], jnp.int32), n))
        for k in range(n // c):
            part = np.asarray(
                nbody.nbody_accel(pos, jnp.asarray([k * c], jnp.int32), c)
            )
            np.testing.assert_allclose(part, whole[k * c : (k + 1) * c], rtol=1e-5)

    def test_two_body_symmetry(self):
        pos = f32([[1.0, 0, 0, 1.0], [-1.0, 0, 0, 1.0]])
        acc = np.asarray(nbody.nbody_accel(pos, jnp.asarray([0], jnp.int32), 2))
        np.testing.assert_allclose(acc[0], -acc[1], atol=1e-6)
        assert acc[0][0] < 0  # attracted towards the other body

    def test_far_body_negligible(self):
        pos = f32([[0, 0, 0, 1.0], [1e3, 0, 0, 1e-6]])
        acc = np.asarray(nbody.nbody_accel(pos, jnp.asarray([0], jnp.int32), 1))
        assert np.abs(acc).max() < 1e-9


# --- segmentation -------------------------------------------------------------


class TestSegmentation:
    @given(
        d=st.sampled_from([1, 4, 8, 16, 64]),
        lo=st.floats(1, 120, width=32),
        hi=st.floats(130, 254, width=32),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, d, lo, hi, seed):
        rng = np.random.default_rng(seed)
        vol = f32(rng.uniform(0, 255, size=(d, 16, 16)))
        th = f32([lo, hi])
        np.testing.assert_array_equal(
            segmentation.segmentation(vol, th), ref.ref_segmentation(vol, th)
        )

    def test_output_alphabet(self):
        rng = np.random.default_rng(11)
        vol = f32(rng.uniform(0, 255, size=(8, 32, 32)))
        out = np.unique(np.asarray(segmentation.segmentation(vol, f32([85, 170]))))
        assert set(out.tolist()) <= {0.0, 128.0, 255.0}

    def test_idempotent(self):
        rng = np.random.default_rng(12)
        vol = f32(rng.uniform(0, 255, size=(8, 16, 16)))
        th = f32([85, 170])
        once = segmentation.segmentation(vol, th)
        twice = segmentation.segmentation(once, th)
        np.testing.assert_array_equal(once, twice)
