"""L2 composition tests: model entry points, shapes, and pipeline fusion."""

import numpy as np
import pytest
import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def f32(x):
    return jnp.asarray(x, jnp.float32)


class TestFilterPipeline:
    def test_composition_matches_staged_oracle(self):
        rng = np.random.default_rng(0)
        img = f32(rng.uniform(0, 255, size=(16, 256)))
        seed = jnp.asarray([5], jnp.int32)
        th = f32([128.0])
        off = jnp.asarray([0], jnp.int32)
        got = model.filter_pipeline_chunk(img, seed, off, th)
        want = ref.ref_filter_pipeline(img, seed, th)
        np.testing.assert_allclose(got, want, atol=1e-3)

    def test_fused_equals_staged_kernels(self):
        """One fused HLO (locality-aware path) == three separate launches
        (the ablation path). This is the correctness side of Section 3.1."""
        rng = np.random.default_rng(1)
        img = f32(rng.uniform(0, 255, size=(8, 512)))
        seed = jnp.asarray([9], jnp.int32)
        th = f32([100.0])
        off = jnp.asarray([0], jnp.int32)
        fused = model.filter_pipeline_chunk(img, seed, off, th)
        staged = model.mirror_chunk(
            model.solarize_chunk(model.gaussian_noise_chunk(img, seed, off), th)
        )
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(staged))

    def test_shape_preserved(self):
        img = f32(np.zeros((24, 128)))
        out = model.filter_pipeline_chunk(
            img,
            jnp.asarray([0], jnp.int32),
            jnp.asarray([0], jnp.int32),
            f32([128.0]),
        )
        assert out.shape == (24, 128) and out.dtype == jnp.float32


class TestFFTRoundtrip:
    def test_roundtrip_recovers_input(self):
        rng = np.random.default_rng(2)
        re = f32(rng.normal(size=(4, 512)))
        im = f32(rng.normal(size=(4, 512)))
        rr, ri = model.fft_roundtrip_chunk(re, im)
        np.testing.assert_allclose(rr, re, atol=1e-4)
        np.testing.assert_allclose(ri, im, atol=1e-4)

    def test_forward_stage(self):
        rng = np.random.default_rng(3)
        re = f32(rng.normal(size=(2, 512)))
        im = f32(rng.normal(size=(2, 512)))
        fr, fi = model.fft_forward_chunk(re, im)
        rr, ri = ref.ref_fft(re, im)
        np.testing.assert_allclose(fr, rr, atol=3e-3)
        np.testing.assert_allclose(fi, ri, atol=3e-3)


class TestNBodyChunk:
    def test_chunked_equals_ref(self):
        rng = np.random.default_rng(4)
        pos = f32(rng.uniform(-1, 1, size=(512, 4))).at[:, 3].set(1.0)
        off = jnp.asarray([256], jnp.int32)
        got = model.nbody_accel_chunk(pos, off, 128)
        want = ref.ref_nbody_accel(pos, off, 128)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-2)


class TestSegmentationChunk:
    def test_matches_ref(self):
        rng = np.random.default_rng(5)
        vol = f32(rng.uniform(0, 255, size=(8, 32, 32)))
        th = f32([85.0, 170.0])
        np.testing.assert_array_equal(
            model.segmentation_chunk(vol, th), ref.ref_segmentation(vol, th)
        )


class TestSaxpyChunk:
    def test_matches_ref(self):
        rng = np.random.default_rng(6)
        x = f32(rng.normal(size=4096))
        y = f32(rng.normal(size=4096))
        a = f32([3.25])
        np.testing.assert_allclose(
            model.saxpy_chunk(a, x, y), ref.ref_saxpy(a, x, y), rtol=1e-5, atol=1e-4
        )
