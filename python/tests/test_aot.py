"""AOT pipeline tests: manifest consistency and HLO-text well-formedness.

These run the actual lowering for a small subset (fast) and, when
`artifacts/manifest.json` already exists (after `make artifacts`), validate
the full manifest against the generator's declared entries.
"""

import json
import os

import pytest

from compile import aot

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
ARTIFACTS = os.path.join(REPO_ROOT, "artifacts")


class TestEntries:
    def test_entry_names_unique(self):
        names = [name for name, *_ in aot.artifact_entries()]
        assert len(names) == len(set(names))

    def test_every_entry_has_cost_model_fields(self):
        for name, _, _, meta in aot.artifact_entries():
            assert meta["flops"] >= 0, name
            assert meta["bytes"] > 0, name
            assert meta["chunk_units"] > 0, name
            assert meta["family"], name

    def test_input_specs_match_example_args(self):
        for name, _, example_args, meta in aot.artifact_entries():
            assert len(example_args) == len(meta["inputs"]), name
            for arg, decl in zip(example_args, meta["inputs"]):
                assert tuple(decl["shape"]) == arg.shape, name

    def test_families_cover_all_five_benchmarks(self):
        fams = {meta["family"] for _, _, _, meta in aot.artifact_entries()}
        assert {
            "saxpy",
            "filter_pipeline",
            "fft_roundtrip",
            "nbody_accel",
            "segmentation",
        } <= fams


class TestLowering:
    def test_lower_saxpy_to_hlo_text(self):
        import jax

        for name, fn, example_args, _ in aot.artifact_entries():
            if name == "saxpy_n4096":
                text = aot.to_hlo_text(jax.jit(fn).lower(*example_args))
                assert "HloModule" in text
                assert "ROOT" in text
                return
        pytest.fail("saxpy_n4096 entry missing")


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestManifest:
    def setup_method(self):
        with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
            self.manifest = json.load(f)

    def test_format_version(self):
        assert self.manifest["format"] == 1

    def test_all_files_exist_and_hash(self):
        import hashlib

        for a in self.manifest["artifacts"]:
            path = os.path.join(ARTIFACTS, a["file"])
            assert os.path.exists(path), a["name"]
            with open(path) as f:
                text = f.read()
            assert hashlib.sha256(text.encode()).hexdigest() == a["sha256"], a["name"]

    def test_manifest_covers_generator_entries(self):
        declared = {name for name, *_ in aot.artifact_entries()}
        built = {a["name"] for a in self.manifest["artifacts"]}
        assert declared == built
