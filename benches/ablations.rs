//! Ablation benches (DESIGN.md §5): discard-ordering, locality-aware
//! decomposition, RBF vs NN derivation.
use marrow::bench::eval::ablations;
use marrow::bench::harness::Timer;

fn main() {
    let r = Timer::new(0, 1).time("ablations", || {
        println!("{}", ablations::discard_ordering().expect("ablation 1"));
        println!("{}", ablations::locality().expect("ablation 2"));
        println!("{}", ablations::interpolation().expect("ablation 3"));
    });
    println!("[bench] {}", r.row());
}
