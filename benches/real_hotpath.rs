//! Real-mode hot-path microbenchmarks (the perf-pass instrument):
//! PJRT executable-cache behaviour, per-launch overhead across chunk sizes,
//! and end-to-end request throughput vs a direct single-executable loop.
//!
//! Requires `make artifacts`. Results feed EXPERIMENTS.md §Perf.

use marrow::bench::harness::{fmt_time, BenchResult, Timer};
use marrow::bench::workloads;
use marrow::data::image::randn_vec;
use marrow::data::vector::VectorArg;
use marrow::platform::device::i7_hd7950;
use marrow::runtime::artifacts::Manifest;
use marrow::runtime::client::{literal_f32, RtClient};
use marrow::runtime::exec::RequestArgs;
use marrow::session::{Computation, ConfigOverride, Session};

fn main() {
    let manifest = match Manifest::load_default() {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping real_hotpath: {e}");
            return;
        }
    };
    let client = RtClient::cpu().expect("pjrt client");
    let mut results: Vec<BenchResult> = Vec::new();
    let timer = Timer::new(2, 10);

    // 1. Compile cost (cold) vs cache hit (warm) for the saxpy artifact.
    let info = &manifest.family("saxpy").unwrap()[0];
    let cold = Timer::new(0, 3).time("compile saxpy_n4096 (uncached)", || {
        let _ = client.compile_file(&info.file).unwrap();
    });
    results.push(cold);
    let _ = client.executable(info).unwrap();
    results.push(timer.time("executable cache hit", || {
        let _ = client.executable(info).unwrap();
    }));

    // 2. Per-launch overhead across the chunk menu: same 262,144 elements
    //    as 64 x 4k, 8 x 32k, 1 x 262k launches.
    let n: usize = 262_144;
    let x = randn_vec(1, n);
    let y = randn_vec(2, n);
    for info in manifest.family("saxpy").unwrap() {
        let chunk = info.chunk_units as usize;
        let launches = n / chunk;
        let exe = client.executable(info).unwrap();
        results.push(timer.time(
            &format!("saxpy 262k via {launches} x {chunk}-elem launches"),
            || {
                for c in 0..launches {
                    let xs =
                        literal_f32(&x[c * chunk..(c + 1) * chunk], &[chunk as u64]).unwrap();
                    let ys =
                        literal_f32(&y[c * chunk..(c + 1) * chunk], &[chunk as u64]).unwrap();
                    let al = literal_f32(&[2.0], &[1]).unwrap();
                    let _ = client.run(&exe, &[al, xs, ys]).unwrap();
                }
            },
        ));
    }

    // 3. End-to-end request through the full stack, driven by the Session
    //    facade under a pinned hybrid split (deterministic A/B with the raw
    //    launch loops above).
    let comp = Computation::from(workloads::saxpy(n as u64));
    let args = RequestArgs {
        vectors: vec![
            VectorArg::partitioned_f32("x", x.clone(), 1),
            VectorArg::partitioned_f32("y", y.clone(), 1),
        ],
        scalars: vec![2.0],
    };
    let session = Session::real(i7_hd7950(1), &client, &manifest);
    results.push(timer.time("saxpy 262k full session request", || {
        let _ = session
            .run_with(&comp, &args, ConfigOverride::new().cpu_share(0.25))
            .unwrap();
    }));

    println!("\n{}", BenchResult::header());
    println!("{}", "-".repeat(94));
    for r in &results {
        println!("{}", r.row());
    }
    println!(
        "\nthroughput (median, full request): {:.1} Melem/s",
        n as f64 / results.last().unwrap().median_s / 1e6
    );
    println!(
        "compile-once amortization: cold compile {} vs cache hit {}",
        fmt_time(results[0].median_s),
        fmt_time(results[1].median_s)
    );
}
