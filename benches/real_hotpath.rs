//! Native-backend hot-path benchmark (BENCH_pr8.json, DESIGN.md §2.11):
//! the first BENCH file in the repo that measures *hardware*, not
//! orchestration over a stub. Four ported kernel families run end-to-end
//! through the `Session` facade on the compiled CPU backend, twice each:
//!
//!  * `scalar` leg — `NativeEngine::scalar_reference()` pinned to
//!    `NoFission` (one slot, one worker thread, lanes=1/block=1): the
//!    single-thread-scalar baseline.
//!  * `native` leg — the production engine under the machine baseline
//!    (L2 fission = one slot per core, wgs 256 -> lanes=8 specialization,
//!    per-slot core affinity): the multi-core vectorized hot path.
//!
//! Both legs use `run_with` (pinned configs, KB and balancer bypassed),
//! so the A/B is deterministic in everything but wall time. Outputs are
//! compared element-wise: the kernels vectorize only across independent
//! elements, so `parity_max_rel_err` is expected to be exactly 0.0 —
//! any nonzero value is drift, and `tools/bench_gate.rs --native` fails
//! the gate above 1e-5.
//!
//! The gate also enforces the scaling invariant on the compute-bound
//! family: `nbody_accel` native throughput >= 2x the scalar leg
//! (SIMD alone buys ~4x there; multi-core multiplies it).

use marrow::bench::harness::{BenchResult, Timer};
use marrow::bench::workloads;
use marrow::data::image::{bodies, image, randn_vec};
use marrow::data::vector::VectorArg;
use marrow::platform::cpu::FissionLevel;
use marrow::platform::device::host_cpu;
use marrow::runtime::exec::RequestArgs;
use marrow::runtime::native::NativeEngine;
use marrow::scheduler::real::RealScheduler;
use marrow::session::{Computation, ConfigOverride, Session};
use std::sync::Arc;

struct Case {
    name: &'static str,
    comp: Computation,
    args: RequestArgs,
    /// f32 FLOPs per request (the workload's analytic count).
    flops: f64,
}

fn cases() -> Vec<Case> {
    let n_saxpy = 1usize << 20;
    let (h, w) = (512usize, 512usize);
    let fft_mib = 1u64; // 256 transforms of 512 points
    let n_ffts = 256usize;
    let (n_bodies, iters) = (2048usize, 2u32);
    vec![
        Case {
            name: "saxpy",
            comp: Computation::from(workloads::saxpy(n_saxpy as u64)),
            args: RequestArgs {
                vectors: vec![
                    VectorArg::partitioned_f32("x", randn_vec(1, n_saxpy), 1),
                    VectorArg::partitioned_f32("y", randn_vec(2, n_saxpy), 1),
                ],
                scalars: vec![2.0],
            },
            flops: 2.0 * n_saxpy as f64,
        },
        Case {
            name: "filter_pipeline",
            comp: Computation::from(workloads::filter_pipeline(h as u64, w as u64, true)),
            args: RequestArgs {
                vectors: vec![VectorArg::partitioned_f32("img", image(3, h, w), w as u64)],
                scalars: vec![12_345.0, 0.0, 96.0],
            },
            flops: 60.0 * (h * w) as f64,
        },
        Case {
            name: "fft_roundtrip",
            comp: Computation::from(workloads::fft(fft_mib)),
            args: RequestArgs {
                vectors: vec![
                    VectorArg::partitioned_f32("re", randn_vec(5, n_ffts * 512), 512),
                    VectorArg::partitioned_f32("im", randn_vec(6, n_ffts * 512), 512),
                ],
                scalars: vec![],
            },
            flops: 2.0 * 5.0 * 512.0 * 9.0 * n_ffts as f64,
        },
        Case {
            name: "nbody_accel",
            comp: Computation::from(workloads::nbody(n_bodies as u64, iters)),
            args: RequestArgs {
                vectors: vec![VectorArg::copied_f32("pos", bodies(9, n_bodies))],
                scalars: vec![0.0],
            },
            flops: 20.0 * (n_bodies * n_bodies) as f64 * iters as f64,
        },
    ]
}

type NativeSession = Session<RealScheduler<'static>>;

/// Largest |a-b| / max(|a|, |b|) over every output element. Expected
/// 0.0: both engines run the identical per-element operation sequence.
fn max_rel_err(a: &[Vec<f32>], b: &[Vec<f32>]) -> f64 {
    let mut worst = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.len(), y.len(), "output shape drift between engines");
        for (u, v) in x.iter().zip(y) {
            let denom = u.abs().max(v.abs()).max(1e-30) as f64;
            worst = worst.max((u - v).abs() as f64 / denom);
        }
    }
    worst
}

fn run_outputs(s: &NativeSession, case: &Case, ovr: &ConfigOverride) -> Vec<Vec<f32>> {
    let out = s
        .run_with(&case.comp, &case.args, ovr.clone())
        .expect("native run");
    out.outputs
        .iter()
        .map(|o| o.as_f32().expect("f32 output").to_vec())
        .collect()
}

struct Row {
    name: &'static str,
    scalar: BenchResult,
    native: BenchResult,
    gflops: f64,
    parity: f64,
}

impl Row {
    fn scalar_rps(&self) -> f64 {
        1.0 / self.scalar.median_s.max(1e-12)
    }
    fn native_rps(&self) -> f64 {
        1.0 / self.native.median_s.max(1e-12)
    }
    fn speedup(&self) -> f64 {
        self.native_rps() / self.scalar_rps().max(1e-12)
    }
}

fn main() {
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let scalar_session: NativeSession =
        Session::native_with_engine(host_cpu(), Arc::new(NativeEngine::scalar_reference()))
            .expect("scalar native session");
    let native_session: NativeSession = Session::native(host_cpu()).expect("native session");
    // One slot, one worker, lanes=1 — vs — one slot per core, lanes=8.
    let scalar_cfg = ConfigOverride::new().fission(FissionLevel::NoFission);
    let native_cfg = ConfigOverride::new();

    println!(
        "native hot path: compiled CPU kernels, hardware measurement \
         ({cores} cores)\n"
    );
    let timer = Timer::new(1, 5);
    let mut rows: Vec<Row> = Vec::new();
    for case in cases() {
        let ref_out = run_outputs(&scalar_session, &case, &scalar_cfg);
        let nat_out = run_outputs(&native_session, &case, &native_cfg);
        let parity = max_rel_err(&ref_out, &nat_out);
        let scalar = timer.time(&format!("{} scalar", case.name), || {
            let _ = scalar_session
                .run_with(&case.comp, &case.args, scalar_cfg.clone())
                .expect("scalar run");
        });
        let native = timer.time(&format!("{} native", case.name), || {
            let _ = native_session
                .run_with(&case.comp, &case.args, native_cfg.clone())
                .expect("native run");
        });
        rows.push(Row {
            name: case.name,
            gflops: case.flops / native.median_s.max(1e-12) / 1e9,
            scalar,
            native,
            parity,
        });
    }

    println!(
        "{:>16} {:>14} {:>14} {:>9} {:>9} {:>14}",
        "kernel", "scalar req/s", "native req/s", "speedup", "GFLOP/s", "parity rel err"
    );
    for r in &rows {
        println!(
            "{:>16} {:>14.2} {:>14.2} {:>8.2}x {:>9.2} {:>14.2e}",
            r.name,
            r.scalar_rps(),
            r.native_rps(),
            r.speedup(),
            r.gflops,
            r.parity,
        );
    }
    let best = rows.iter().map(Row::speedup).fold(0.0f64, f64::max);
    println!("\nbest multi-core-vs-scalar speedup: {best:.2}x");

    let results_json: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"kernel\": \"{}\", \"scalar_req_per_sec\": {:.4}, \
                 \"native_req_per_sec\": {:.4}, \"speedup\": {:.4}, \
                 \"gflops\": {:.4}, \"parity_max_rel_err\": {:.3e}}}",
                r.name,
                r.scalar_rps(),
                r.native_rps(),
                r.speedup(),
                r.gflops,
                r.parity,
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"real_hotpath\",\n  \"pr\": 8,\n  \
         \"backend\": \"native\",\n  \"hardware\": true,\n  \
         \"cores\": {cores},\n  \"results\": [\n{}\n  ],\n  \
         \"speedup_best\": {best:.4}\n}}\n",
        results_json.join(",\n")
    );
    let path = "BENCH_pr8.json";
    match std::fs::write(path, &json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
