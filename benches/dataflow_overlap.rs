//! Dataflow-overlap bench: barrier vs dataflow drains on multi-stage work
//! (BENCH_pr4.json, the PR-4 perf-trajectory point).
//!
//! Two workloads, both stage-structured: the 3-stage staged filter
//! pipeline and a global-sync Loop over a 2-stage body. Each is priced by
//! the simulated backend under both drain modes (DESIGN.md §2.7): Barrier
//! sums per-stage maxima plus a sync-priced gate per stage boundary;
//! Dataflow overlaps stages, so the makespan is the slowest slot's total
//! work. Reported per (workload, mode): makespan and mean slot idle% —
//! the acceptance numbers (dataflow strictly lower on both) that
//! `rust/tests/dataflow_integration.rs` asserts.

use marrow::bench::workloads;
use marrow::platform::cpu::FissionLevel;
use marrow::platform::device::i7_hd7950;
use marrow::runtime::exec::RequestArgs;
use marrow::scheduler::{DrainMode, ExecEnv, SimEnv};
use marrow::sct::Sct;
use marrow::sim::machine::SimMachine;
use marrow::tuner::profile::FrameworkConfig;

const RUNS: usize = 16;

struct Point {
    workload: &'static str,
    mode: &'static str,
    makespan_ms: f64,
    idle_pct: f64,
}

fn cfg() -> FrameworkConfig {
    FrameworkConfig {
        fission: FissionLevel::L2,
        overlap: vec![2],
        wgs: 256,
        cpu_share: 0.25,
    }
}

fn price(name: &'static str, sct: &Sct, units: u64, mode: DrainMode) -> Point {
    let mut env = SimEnv::new(SimMachine::new(i7_hd7950(1), 42));
    env.set_drain_mode(mode);
    let (mut makespan, mut idle) = (0.0f64, 0.0f64);
    for _ in 0..RUNS {
        let out = env
            .run_request(sct, &RequestArgs::default(), units, &cfg())
            .expect("sim request")
            .exec;
        makespan += out.total;
        idle += out.mean_idle_frac();
    }
    Point {
        workload: name,
        mode: mode.label(),
        makespan_ms: makespan / RUNS as f64 * 1e3,
        idle_pct: idle / RUNS as f64 * 100.0,
    }
}

fn main() {
    let pipeline = workloads::filter_pipeline(2048, 2048, false);
    let loop_body = Sct::pipeline(vec![
        Sct::kernel(pipeline.sct.kernels()[0].clone()),
        Sct::kernel(pipeline.sct.kernels()[1].clone()),
    ]);
    let looped = Sct::for_loop(loop_body, 5, true);

    println!(
        "dataflow overlap: {RUNS} runs per case, i7+HD7950, simulated clock\n"
    );
    println!(
        "{:<18} {:>9} {:>13} {:>8}",
        "workload", "drain", "makespan ms", "idle%"
    );

    let mut points = Vec::new();
    for (name, sct, units) in [
        ("pipeline_3stage", &pipeline.sct, pipeline.total_units),
        ("loop_2stage_x5", &looped, 1024u64),
    ] {
        for mode in [DrainMode::Barrier, DrainMode::Dataflow] {
            let p = price(name, sct, units, mode);
            println!(
                "{:<18} {:>9} {:>13.3} {:>7.1}%",
                p.workload, p.mode, p.makespan_ms, p.idle_pct
            );
            points.push(p);
        }
    }

    let speedup = |w: &str| {
        let get = |m: &str| {
            points
                .iter()
                .find(|p| p.workload == w && p.mode == m)
                .map(|p| p.makespan_ms)
                .unwrap_or(0.0)
        };
        let df = get("dataflow");
        if df > 0.0 {
            get("barrier") / df
        } else {
            f64::INFINITY
        }
    };
    println!(
        "\nbarrier/dataflow makespan ratio: pipeline_3stage {:.2}x, \
         loop_2stage_x5 {:.2}x",
        speedup("pipeline_3stage"),
        speedup("loop_2stage_x5")
    );

    let json_points: Vec<String> = points
        .iter()
        .map(|p| {
            format!(
                "    {{\"workload\": \"{}\", \"drain\": \"{}\", \
                 \"makespan_ms\": {:.4}, \"idle_pct\": {:.2}}}",
                p.workload, p.mode, p.makespan_ms, p.idle_pct
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"dataflow_overlap\",\n  \"pr\": 4,\n  \
         \"runs\": {RUNS},\n  \"points\": [\n{}\n  ]\n}}\n",
        json_points.join(",\n")
    );
    let path = "BENCH_pr4.json";
    match std::fs::write(path, &json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
