//! Co-scheduling serve bench (BENCH_pr5.json): a mixed stream of CPU- and
//! GPU-leaning requests served with the PR 2 whole-pool admission vs the
//! device-space co-scheduler (DESIGN.md §2.8).
//!
//! Two metrics per mode:
//!  * `requests_per_sec` — wall-clock driver throughput (pool, admission,
//!    reservation gating); noisy on loaded CI runners, reported for trend.
//!  * `virtual_req_per_sec` — request count over the virtual-timeline
//!    makespan, where conflicting reservations stack and disjoint ones
//!    overlap. Noise-free on the quiet simulated machine, so the CI bench
//!    gate (`tools/bench_gate.rs`) compares exactly this number.

use marrow::bench::workloads;
use marrow::kb::mk_profile;
use marrow::platform::cpu::FissionLevel;
use marrow::platform::device::i7_hd7950;
use marrow::scheduler::SimEnv;
use marrow::session::serve::{ServeOpts, ServeRequest, SessionPool};
use marrow::session::{Computation, Session};
use marrow::sim::cost::CostParams;
use marrow::sim::machine::SimMachine;

const REQUESTS: usize = 32;
const CONCURRENCY: usize = 4;
const PACE_MS: f64 = 0.5;

fn quiet_session(seed: u64) -> Session<SimEnv> {
    let quiet = CostParams {
        cpu_noise: 0.0,
        gpu_noise: 0.0,
        straggler_p: 0.0,
        ..CostParams::default()
    };
    Session::sim(SimMachine::new(i7_hd7950(1), seed).with_params(quiet))
}

/// The mixed stream: alternating CPU-leaning and GPU-leaning requests
/// (same kernel, different sizes, so they hold distinct KB entries), with
/// profiles pre-seeded so admission prices a warm KB and the run is
/// deterministic end to end.
fn build_pool_and_stream() -> (SessionPool<SimEnv>, Vec<ServeRequest>) {
    let pool = SessionPool::build(CONCURRENCY, |i| quiet_session(500 + i as u64));
    let cpu_comp = Computation::from(workloads::saxpy(1 << 20));
    let gpu_comp = Computation::from(workloads::saxpy(1 << 21));
    for (comp, share) in [(&cpu_comp, 0.9), (&gpu_comp, 0.1)] {
        let (sct, w, _) = comp.spec().unwrap();
        pool.shared_kb().write().unwrap().store(mk_profile(
            &sct.id(),
            w.clone(),
            FissionLevel::L2,
            vec![4],
            share,
            1e-3,
        ));
    }
    let requests = (0..REQUESTS)
        .map(|i| {
            ServeRequest::from(if i % 2 == 0 {
                cpu_comp.clone()
            } else {
                gpu_comp.clone()
            })
        })
        .collect();
    (pool, requests)
}

struct Point {
    name: &'static str,
    wall_rps: f64,
    virt_rps: f64,
    virt_makespan: f64,
}

fn run_mode(name: &'static str, co_schedule: bool) -> Point {
    let (pool, requests) = build_pool_and_stream();
    let report = pool
        .serve(
            &requests,
            &ServeOpts {
                concurrency: CONCURRENCY,
                pace: PACE_MS * 1e-3,
                co_schedule,
                ..Default::default()
            },
        )
        .expect("serve");
    Point {
        name,
        wall_rps: report.requests_per_sec,
        virt_rps: report.virtual_req_per_sec(),
        virt_makespan: report.virtual_makespan,
    }
}

fn main() {
    println!(
        "co-scheduling serve: {REQUESTS} mixed requests (cpu-/gpu-leaning), \
         concurrency {CONCURRENCY}, pace floor {PACE_MS} ms\n"
    );
    println!(
        "{:>26} {:>12} {:>14} {:>16}",
        "mode", "wall req/s", "virtual req/s", "virt makespan s"
    );
    let serialized = run_mode("mixed_serve_serialized", false);
    let coscheduled = run_mode("mixed_serve_coscheduled", true);
    for p in [&serialized, &coscheduled] {
        println!(
            "{:>26} {:>12.1} {:>14.1} {:>16.4}",
            p.name, p.wall_rps, p.virt_rps, p.virt_makespan
        );
    }
    let speedup = if coscheduled.virt_makespan > 0.0 {
        serialized.virt_makespan / coscheduled.virt_makespan
    } else {
        0.0
    };
    println!("\nco-scheduling virtual speedup: {speedup:.2}x (device-time makespan)");

    let workloads_json: Vec<String> = [&serialized, &coscheduled]
        .iter()
        .map(|p| {
            format!(
                "    {{\"name\": \"{}\", \"requests_per_sec\": {:.2}, \
                 \"virtual_req_per_sec\": {:.2}, \"virtual_makespan_s\": {:.6}}}",
                p.name, p.wall_rps, p.virt_rps, p.virt_makespan
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"coschedule_serve\",\n  \"pr\": 5,\n  \
         \"requests\": {REQUESTS},\n  \"concurrency\": {CONCURRENCY},\n  \
         \"pace_ms\": {PACE_MS},\n  \"workloads\": [\n{}\n  ],\n  \
         \"co_speedup_virtual\": {speedup:.3}\n}}\n",
        workloads_json.join(",\n")
    );
    let path = "BENCH_pr5.json";
    match std::fs::write(path, &json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
