//! Regenerates Fig 11 (FFT 128 MB under CPU load fluctuations).
use marrow::bench::eval::fig11;
use marrow::bench::harness::Timer;

fn main() {
    let r = Timer::new(0, 1).time("fig11 regeneration", || {
        let report = fig11::report().expect("fig11");
        println!("{report}");
    });
    println!("[bench] {}", r.row());
}
