//! Serve-path throughput bench: requests/sec and p50/p99 latency of the
//! multi-request driver across admission caps — the first point of the
//! repo's performance trajectory (BENCH_pr2.json).
//!
//! The pool runs simulated backends; every request carries the serve
//! path's fixed pace floor standing in for device occupancy, so the
//! numbers measure admission-cap scaling of the *driver* (session pool,
//! shared-KB resolution, balance bookkeeping), not the analytic clock.

use marrow::bench::workloads;
use marrow::platform::device::i7_hd7950;
use marrow::session::serve::{serve_simulated, ServeOpts, ServeRequest};
use marrow::session::Computation;

const REQUESTS: usize = 64;
const PACE_MS: f64 = 2.0;

fn main() {
    let machine = i7_hd7950(1);
    let requests: Vec<ServeRequest> = (0..REQUESTS)
        .map(|_| ServeRequest::from(Computation::from(workloads::saxpy(1 << 20))))
        .collect();

    println!(
        "serve throughput: {REQUESTS} saxpy requests, pace floor {PACE_MS} ms \
         (simulated backends)\n"
    );
    println!(
        "{:>11} {:>10} {:>10} {:>10} {:>9} {:>9}",
        "concurrency", "req/s", "p50 ms", "p99 ms", "kb hits", "built"
    );

    let mut points = Vec::new();
    for concurrency in [1usize, 2, 4, 8] {
        let report = serve_simulated(
            &machine,
            42,
            &requests,
            &ServeOpts {
                concurrency,
                pace: PACE_MS * 1e-3,
                ..Default::default()
            },
        )
        .expect("serve");
        println!(
            "{:>11} {:>10.1} {:>10.2} {:>10.2} {:>9} {:>9}",
            report.concurrency,
            report.requests_per_sec,
            report.p50_latency * 1e3,
            report.p99_latency * 1e3,
            report.stats.kb_hits,
            report.stats.built
        );
        points.push((
            report.concurrency,
            report.requests_per_sec,
            report.p50_latency * 1e3,
            report.p99_latency * 1e3,
        ));
    }

    let rps_1 = points.iter().find(|p| p.0 == 1).map(|p| p.1).unwrap_or(0.0);
    let rps_4 = points.iter().find(|p| p.0 == 4).map(|p| p.1).unwrap_or(0.0);
    let speedup = if rps_1 > 0.0 { rps_4 / rps_1 } else { 0.0 };
    println!("\nspeedup concurrency 4 vs 1: {speedup:.2}x");

    let json_points: Vec<String> = points
        .iter()
        .map(|(c, rps, p50, p99)| {
            format!(
                "    {{\"concurrency\": {c}, \"requests_per_sec\": {rps:.2}, \
                 \"p50_ms\": {p50:.3}, \"p99_ms\": {p99:.3}}}"
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"serve_throughput\",\n  \"pr\": 2,\n  \
         \"requests\": {REQUESTS},\n  \"pace_ms\": {PACE_MS},\n  \
         \"points\": [\n{}\n  ],\n  \"speedup_c4_vs_c1\": {speedup:.2}\n}}\n",
        json_points.join(",\n")
    );
    let path = "BENCH_pr2.json";
    match std::fs::write(path, &json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
