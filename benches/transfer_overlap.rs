//! Transfer/compute-overlap bench: prefetch on vs off (BENCH_pr9.json,
//! the PR-9 perf-trajectory point).
//!
//! Two workloads on the simulated i7+HD7950: the transfer-heavy unfused
//! 3-stage filter pipeline (PCIe traffic comparable to compute) and the
//! compute-heavy n-body loop. Each cold request is priced with the
//! dataflow drain at prefetch depth 0 (uploads exposed, today's drain)
//! and depth 4 (lookahead uploads ride under compute, DESIGN.md §2.12).
//! Runs are seed-paired — both arms price the identical noise draw — so
//! the makespan delta is purely the hidden upload. Reported per
//! (workload, arm): virtual makespan, overlap% (hidden share of
//! link-crossing upload bytes) and uploaded MB; plus one native-backend
//! identity check (depth 0 vs 4, bitwise) feeding `outputs_identical`.
//! `tools/bench_gate.rs --prefetch` enforces: identical outputs, on-arm
//! makespan ≤ off everywhere and strictly below on the pipeline.

use marrow::bench::workloads;
use marrow::data::vector::VectorArg;
use marrow::platform::cpu::FissionLevel;
use marrow::platform::device::{host_cpu, i7_hd7950};
use marrow::runtime::exec::RequestArgs;
use marrow::scheduler::{DrainMode, ExecEnv, SimEnv};
use marrow::session::{Computation, ConfigOverride, Session};
use marrow::sim::machine::SimMachine;
use marrow::tuner::profile::FrameworkConfig;

const RUNS: usize = 8;
const DEPTH: u32 = 4;

struct Point {
    workload: &'static str,
    prefetch: &'static str,
    makespan_ms: f64,
    overlap_pct: f64,
    uploaded_mb: f64,
}

fn cfg() -> FrameworkConfig {
    FrameworkConfig {
        fission: FissionLevel::L2,
        overlap: vec![2],
        wgs: 256,
        cpu_share: 0.25,
    }
}

fn price(
    name: &'static str,
    b: &marrow::bench::workloads::Benchmark,
    depth: u32,
) -> Point {
    let (mut makespan, mut overlapped, mut uploaded) = (0.0f64, 0u64, 0u64);
    for i in 0..RUNS {
        // Fresh env per run: every request is cold (the residency
        // discount is PR 6's story, not this bench's), and the seed is
        // paired across the on/off arms.
        let mut env = SimEnv::new(SimMachine::new(i7_hd7950(1), 42 + i as u64));
        env.set_drain_mode(DrainMode::Dataflow);
        env.set_prefetch_depth(depth);
        env.set_copy_bytes(b.copy_bytes);
        let out = env
            .run_request(&b.sct, &RequestArgs::default(), b.total_units, &cfg())
            .expect("sim request")
            .exec;
        makespan += out.total;
        overlapped += out.transfers.uploads_overlapped_bytes;
        uploaded += out.transfers.bytes_uploaded;
    }
    let crossed = uploaded + overlapped;
    Point {
        workload: name,
        prefetch: if depth > 0 { "on" } else { "off" },
        makespan_ms: makespan / RUNS as f64 * 1e3,
        overlap_pct: if crossed > 0 {
            100.0 * overlapped as f64 / crossed as f64
        } else {
            0.0
        },
        uploaded_mb: uploaded as f64 / 1e6 / RUNS as f64,
    }
}

/// Native-backend identity check: the same request drained at prefetch
/// depth 0 and depth `DEPTH` must produce bitwise-equal outputs.
fn outputs_identical() -> bool {
    let (h, w) = (128u64, 64u64);
    let comp = Computation::from(workloads::filter_pipeline(h, w, false));
    let args = RequestArgs {
        vectors: vec![VectorArg::partitioned_f32(
            "img",
            marrow::data::image::image(3, h as usize, w as usize),
            w,
        )],
        scalars: vec![12_345.0, 0.0, 96.0],
    };
    let run = |depth: u32| -> Vec<Vec<f32>> {
        let s = Session::native(host_cpu())
            .expect("native session")
            .with_prefetch_depth(depth);
        s.set_drain_mode(DrainMode::Dataflow);
        s.run_with(&comp, &args, ConfigOverride::new())
            .expect("native run")
            .outputs
            .iter()
            .map(|o| o.as_f32().expect("f32 output").to_vec())
            .collect()
    };
    let (a, b) = (run(0), run(DEPTH));
    a.len() == b.len()
        && a.iter().zip(&b).all(|(x, y)| {
            x.len() == y.len()
                && x.iter().zip(y.iter()).all(|(u, v)| u.to_bits() == v.to_bits())
        })
}

fn main() {
    let pipeline = workloads::filter_pipeline(1 << 15, 1 << 15, false);
    let nbody = workloads::nbody(1 << 15, 20);

    println!(
        "transfer overlap: {RUNS} seed-paired cold runs per arm, \
         prefetch depth {DEPTH}, i7+HD7950, simulated clock\n"
    );
    println!(
        "{:<18} {:>9} {:>13} {:>9} {:>12}",
        "workload", "prefetch", "makespan ms", "overlap%", "uploaded MB"
    );

    let mut points = Vec::new();
    for (name, b) in [("pipeline_3stage", &pipeline), ("nbody_loop", &nbody)] {
        for depth in [0u32, DEPTH] {
            let p = price(name, b, depth);
            println!(
                "{:<18} {:>9} {:>13.3} {:>8.1}% {:>12.2}",
                p.workload, p.prefetch, p.makespan_ms, p.overlap_pct, p.uploaded_mb
            );
            points.push(p);
        }
    }

    let ratio = |w: &str| {
        let get = |arm: &str| {
            points
                .iter()
                .find(|p| p.workload == w && p.prefetch == arm)
                .map(|p| p.makespan_ms)
                .unwrap_or(0.0)
        };
        let on = get("on");
        if on > 0.0 {
            get("off") / on
        } else {
            f64::INFINITY
        }
    };
    let identical = outputs_identical();
    println!(
        "\noff/on makespan ratio: pipeline_3stage {:.3}x, nbody_loop {:.3}x; \
         native depth-0 vs depth-{DEPTH} outputs identical: {identical}",
        ratio("pipeline_3stage"),
        ratio("nbody_loop")
    );

    let json_points: Vec<String> = points
        .iter()
        .map(|p| {
            format!(
                "    {{\"workload\": \"{}\", \"prefetch\": \"{}\", \
                 \"makespan_ms\": {:.4}, \"overlap_pct\": {:.2}, \
                 \"uploaded_mb\": {:.3}}}",
                p.workload, p.prefetch, p.makespan_ms, p.overlap_pct, p.uploaded_mb
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"transfer_overlap\",\n  \"pr\": 9,\n  \
         \"runs\": {RUNS},\n  \"prefetch_depth\": {DEPTH},\n  \
         \"outputs_identical\": {identical},\n  \"points\": [\n{}\n  ]\n}}\n",
        json_points.join(",\n")
    );
    let path = "BENCH_pr9.json";
    match std::fs::write(path, &json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
