//! Batching / graph-fusion serve bench (BENCH_pr7.json, DESIGN.md §2.10):
//! the same mixed request stream drained unbatched (PR 5 behavior, one
//! graph per request) and batched (`batch_max` > 1: consecutive compatible
//! requests coalesce into one fused drain paying admission, pacing, and
//! the virtual-timeline booking once).
//!
//! The stream alternates a CPU-leaning and a GPU-leaning saxpy, so fused
//! batches pack opposite device leanings: the fused makespan is the
//! busiest device's summed load instead of the serialized per-request sum
//! ([`ExecOutcome::fused_total`]), which is where the virtual throughput
//! win comes from. Concurrency is 8 against the machine's 2 devices (CPU
//! package + 1 GPU) — the ISSUE's "concurrency ≥ 4x slot count" regime.
//!
//! The gate (`tools/bench_gate.rs --batch`) enforces two deterministic
//! invariants from the emitted JSON:
//!  * batched `virtual_req_per_sec` ≥ 1.3x unbatched,
//!  * zero correctness drift: the sorted per-request execution totals are
//!    bit-identical across the two modes (batching changes scheduling,
//!    never results).
//!
//! Sessions run the analytic simulator with zeroed noise and a frozen
//! balancer (`with_max_dev(10.0)`), so both runs resolve identical
//! configurations and the bit-identicality check is meaningful.

use marrow::bench::workloads;
use marrow::kb::mk_profile;
use marrow::platform::cpu::FissionLevel;
use marrow::platform::device::i7_hd7950;
use marrow::scheduler::SimEnv;
use marrow::session::serve::{ServeOpts, ServeReport, ServeRequest, SessionPool};
use marrow::session::{Computation, Session};
use marrow::sim::cost::CostParams;
use marrow::sim::machine::SimMachine;

const REQUESTS: usize = 32;
const CONCURRENCY: usize = 8;
const PACE_MS: f64 = 0.5;
const BATCH_MAX: usize = 8;
const BATCH_WINDOW_SECS: f64 = 0.02;
const DEADLINE_SECS: f64 = 0.05;
/// CPU-leaning / GPU-leaning workload pair (seeded tuned splits below).
const CPU_SIZE: u64 = 1 << 20;
const GPU_SIZE: u64 = 1 << 21;

fn quiet_session(seed: u64) -> Session<SimEnv> {
    let quiet = CostParams {
        cpu_noise: 0.0,
        gpu_noise: 0.0,
        straggler_p: 0.0,
        ..CostParams::default()
    };
    Session::sim(SimMachine::new(i7_hd7950(1), seed).with_params(quiet)).with_max_dev(10.0)
}

/// A pool whose shared KB is pre-seeded with opposite tuned splits, so
/// both modes resolve the same configurations from request one and the
/// claim-time batch-close estimates are warm.
fn pool(seed: u64) -> SessionPool<SimEnv> {
    let pool = SessionPool::build(CONCURRENCY, |i| quiet_session(seed + i as u64));
    for (size, cpu_share) in [(CPU_SIZE, 0.9), (GPU_SIZE, 0.1)] {
        let comp = Computation::from(workloads::saxpy(size));
        let (sct, w, _) = comp.spec().unwrap();
        pool.shared_kb().write().unwrap().store(mk_profile(
            &sct.id(),
            w.clone(),
            FissionLevel::L2,
            vec![4],
            cpu_share,
            1e-3,
        ));
    }
    pool
}

fn stream() -> Vec<ServeRequest> {
    (0..REQUESTS)
        .map(|i| {
            let size = if i % 2 == 0 { CPU_SIZE } else { GPU_SIZE };
            ServeRequest::from(Computation::from(workloads::saxpy(size)))
        })
        .collect()
}

fn run_serve(batch_max: usize, seed: u64) -> ServeReport {
    pool(seed)
        .serve(
            &stream(),
            &ServeOpts {
                concurrency: CONCURRENCY,
                pace: PACE_MS * 1e-3,
                batch_max,
                batch_window: BATCH_WINDOW_SECS,
                deadline_default: Some(DEADLINE_SECS),
                ..Default::default()
            },
        )
        .expect("serve")
}

/// Per-request execution totals in a mode-independent order.
fn sorted_exec_totals(r: &ServeReport) -> Vec<f64> {
    let mut t: Vec<f64> = r.traces.iter().map(|t| t.exec_total).collect();
    t.sort_by(|a, b| a.partial_cmp(b).unwrap());
    t
}

struct Point {
    name: &'static str,
    report: ServeReport,
}

impl Point {
    fn miss_rate(&self) -> f64 {
        self.report.deadline_misses as f64 / self.report.completed.max(1) as f64
    }
}

fn main() {
    println!(
        "batch fusion: {REQUESTS} alternating cpu/gpu-leaning requests, \
         concurrency {CONCURRENCY} over 2 devices, pace floor {PACE_MS} ms, \
         batch_max {BATCH_MAX}, window {:.0} ms, deadline {:.0} ms\n",
        BATCH_WINDOW_SECS * 1e3,
        DEADLINE_SECS * 1e3
    );
    println!(
        "{:>16} {:>12} {:>14} {:>8} {:>11} {:>13} {:>13}",
        "mode", "wall req/s", "virtual req/s", "batches", "miss rate", "p99 admit ms", "p99 drain ms"
    );

    let unbatched = Point {
        name: "unbatched_serve",
        report: run_serve(1, 700),
    };
    let batched = Point {
        name: "batched_serve",
        report: run_serve(BATCH_MAX, 700),
    };

    assert_eq!(unbatched.report.completed, REQUESTS);
    assert_eq!(batched.report.completed, REQUESTS);
    assert_eq!(
        unbatched.report.batches, REQUESTS,
        "unbatched serve must drain one batch per request"
    );
    assert!(
        batched.report.batches < REQUESTS / 2,
        "batched serve coalesced only {} batches",
        batched.report.batches
    );

    for p in [&unbatched, &batched] {
        println!(
            "{:>16} {:>12.1} {:>14.1} {:>8} {:>11.3} {:>13.3} {:>13.3}",
            p.name,
            p.report.requests_per_sec,
            p.report.virtual_req_per_sec(),
            p.report.batches,
            p.miss_rate(),
            p.report.p99_admit_wait * 1e3,
            p.report.p99_drain * 1e3,
        );
    }

    // Zero correctness drift: identical per-request executions, bit for
    // bit (sorted: mode changes which worker serves which request).
    let a = sorted_exec_totals(&unbatched.report);
    let b = sorted_exec_totals(&batched.report);
    let identical = a.len() == b.len()
        && a.iter().zip(b.iter()).all(|(x, y)| x.to_bits() == y.to_bits());
    assert!(identical, "batched execution drifted from unbatched");

    let speedup = batched.report.virtual_req_per_sec()
        / unbatched.report.virtual_req_per_sec().max(1e-12);
    println!(
        "\nvirtual speedup (batched / unbatched): {speedup:.2}x, \
         exec totals identical: {identical}"
    );
    assert!(
        speedup >= 1.3,
        "batched serve must beat unbatched by >= 1.3x virtual throughput, got {speedup:.2}x"
    );

    let workloads_json: Vec<String> = [&unbatched, &batched]
        .iter()
        .map(|p| {
            format!(
                "    {{\"name\": \"{}\", \"requests_per_sec\": {:.2}, \
                 \"virtual_req_per_sec\": {:.2}, \"batches\": {}, \
                 \"deadline_miss_rate\": {:.4}, \"p99_admit_wait_ms\": {:.4}, \
                 \"p99_drain_ms\": {:.4}}}",
                p.name,
                p.report.requests_per_sec,
                p.report.virtual_req_per_sec(),
                p.report.batches,
                p.miss_rate(),
                p.report.p99_admit_wait * 1e3,
                p.report.p99_drain * 1e3,
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"batch_fusion\",\n  \"pr\": 7,\n  \
         \"requests\": {REQUESTS},\n  \"concurrency\": {CONCURRENCY},\n  \
         \"pace_ms\": {PACE_MS},\n  \"batch_max\": {BATCH_MAX},\n  \
         \"batch_window_ms\": {:.1},\n  \"deadline_ms\": {:.1},\n  \
         \"workloads\": [\n{}\n  ],\n  \
         \"speedup_virtual\": {:.4},\n  \"exec_totals_identical\": {}\n}}\n",
        BATCH_WINDOW_SECS * 1e3,
        DEADLINE_SECS * 1e3,
        workloads_json.join(",\n"),
        speedup,
        identical
    );
    let path = "BENCH_pr7.json";
    match std::fs::write(path, &json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
