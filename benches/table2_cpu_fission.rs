//! Regenerates Table 2 + Fig 5 + Fig 6 (CPU-only fission study, Section 4.1).
use marrow::bench::eval::table2;
use marrow::bench::harness::Timer;

fn main() {
    let r = Timer::new(0, 1).time("table2 regeneration", || {
        let report = table2::report().expect("table2");
        println!("{report}");
    });
    println!("[bench] {}", r.row());
}
