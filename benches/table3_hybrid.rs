//! Regenerates Table 3 + Fig 7 + Fig 8 (hybrid CPU+GPU study, Section 4.2).
use marrow::bench::eval::table3;
use marrow::bench::harness::Timer;

fn main() {
    let r = Timer::new(0, 1).time("table3 regeneration", || {
        let report = table3::report().expect("table3");
        println!("{report}");
    });
    println!("[bench] {}", r.row());
}
