//! Regenerates Table 5 + Fig 9 + Fig 10 (profile construction vs KB
//! derivation over 8 images).
use marrow::bench::eval::table5;
use marrow::bench::harness::Timer;

fn main() {
    let r = Timer::new(0, 1).time("table5 regeneration", || {
        let report = table5::report().expect("table5");
        println!("{report}");
    });
    println!("[bench] {}", r.row());
}
