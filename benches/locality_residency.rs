//! Locality/residency bench: the buffer-residency layer's effect on the
//! serve path (BENCH_pr3.json, the PR-3 perf-trajectory point).
//!
//! Two workloads exercise the two reuse axes — a 3-stage filter Pipeline
//! (stage intermediates stay device-resident) and the NBody global-sync
//! Loop (iteration inputs stay resident, only COPY state re-ships) — each
//! served twice through a pool of simulated sessions: once with the
//! residency layer on, once disabled (the PR-2 baseline, every request
//! re-uploading). Reported: uploads avoided, MB uploaded, and requests/sec
//! of the driver under a fixed pace floor.

use marrow::bench::workloads;
use marrow::platform::device::i7_hd7950;
use marrow::session::serve::{ServeOpts, ServeRequest, SessionPool};
use marrow::session::{Computation, Session};

const REQUESTS: usize = 32;
const CONCURRENCY: usize = 2;
const PACE_MS: f64 = 1.0;

struct Point {
    workload: &'static str,
    residency: bool,
    uploads_avoided: u64,
    mb_uploaded: f64,
    req_per_sec: f64,
}

fn serve_case(name: &'static str, comp: &Computation, residency: bool) -> Point {
    let machine = i7_hd7950(1);
    let pool = SessionPool::build(CONCURRENCY, |i| {
        Session::simulated(machine.clone(), 42 + i as u64)
    });
    for s in pool.sessions() {
        s.set_residency_enabled(residency);
    }
    let requests: Vec<ServeRequest> = (0..REQUESTS)
        .map(|_| ServeRequest::from(comp.clone()))
        .collect();
    let report = pool
        .serve(
            &requests,
            &ServeOpts {
                concurrency: CONCURRENCY,
                pace: PACE_MS * 1e-3,
                ..Default::default()
            },
        )
        .expect("serve");
    Point {
        workload: name,
        residency,
        uploads_avoided: report.stats.uploads_avoided,
        mb_uploaded: report.stats.bytes_uploaded as f64 / 1e6,
        req_per_sec: report.requests_per_sec,
    }
}

fn main() {
    let pipeline = Computation::from(workloads::filter_pipeline(2048, 2048, false));
    let nbody = Computation::from(workloads::nbody(16384, 10));

    println!(
        "locality/residency: {REQUESTS} requests per case, concurrency \
         {CONCURRENCY}, pace floor {PACE_MS} ms (simulated backends)\n"
    );
    println!(
        "{:<22} {:>9} {:>15} {:>12} {:>9}",
        "workload", "residency", "uploads avoided", "MB uploaded", "req/s"
    );

    let mut points = Vec::new();
    for (name, comp) in [("filter_pipeline", &pipeline), ("nbody_loop", &nbody)] {
        for residency in [true, false] {
            let p = serve_case(name, comp, residency);
            println!(
                "{:<22} {:>9} {:>15} {:>12.1} {:>9.1}",
                p.workload,
                if p.residency { "on" } else { "off" },
                p.uploads_avoided,
                p.mb_uploaded,
                p.req_per_sec
            );
            points.push(p);
        }
    }

    let upload_ratio = |w: &str| {
        let on = points
            .iter()
            .find(|p| p.workload == w && p.residency)
            .map(|p| p.mb_uploaded)
            .unwrap_or(0.0);
        let off = points
            .iter()
            .find(|p| p.workload == w && !p.residency)
            .map(|p| p.mb_uploaded)
            .unwrap_or(0.0);
        if on > 0.0 {
            off / on
        } else {
            f64::INFINITY
        }
    };
    println!(
        "\nupload reduction (off/on): filter_pipeline {:.1}x, nbody_loop {:.1}x",
        upload_ratio("filter_pipeline"),
        upload_ratio("nbody_loop")
    );

    let json_points: Vec<String> = points
        .iter()
        .map(|p| {
            format!(
                "    {{\"workload\": \"{}\", \"residency\": {}, \
                 \"uploads_avoided\": {}, \"mb_uploaded\": {:.3}, \
                 \"req_per_sec\": {:.2}}}",
                p.workload, p.residency, p.uploads_avoided, p.mb_uploaded, p.req_per_sec
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"locality_residency\",\n  \"pr\": 3,\n  \
         \"requests\": {REQUESTS},\n  \"concurrency\": {CONCURRENCY},\n  \
         \"pace_ms\": {PACE_MS},\n  \"points\": [\n{}\n  ]\n}}\n",
        json_points.join(",\n")
    );
    let path = "BENCH_pr3.json";
    match std::fs::write(path, &json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
