//! KB-store warm-start bench (BENCH_pr6.json): a cold fleet member builds
//! its profiles from scratch into a durable KB store (DESIGN.md §2.9),
//! exports a snapshot, and a second member warm-started from that snapshot
//! serves the same stream without running Algorithm 1 at all.
//!
//! The gate (`tools/bench_gate.rs`) enforces three deterministic
//! invariants from the emitted JSON:
//!  * the warm-started serve performs ZERO cold profile builds,
//!  * its cold-build wall seconds are strictly below the cold run's,
//!  * merging two stores in either order exports byte-identical snapshots.

use std::path::{Path, PathBuf};

use marrow::bench::workloads;
use marrow::kb::store::snapshot::KbSnapshot;
use marrow::kb::store::{machine_digest, KbStore};
use marrow::kb::{mk_profile, KnowledgeBase};
use marrow::platform::cpu::FissionLevel;
use marrow::platform::device::i7_hd7950;
use marrow::scheduler::SimEnv;
use marrow::session::serve::{ServeOpts, ServeRequest, SessionPool};
use marrow::session::{Computation, Session};
use marrow::sim::cost::CostParams;
use marrow::sim::machine::SimMachine;

const REQUESTS: usize = 24;
const CONCURRENCY: usize = 4;
const PACE_MS: f64 = 0.5;
const STORE_SYNC_EVERY: usize = 8;
/// Distinct saxpy sizes, so the stream holds three separate KB entries.
const SIZES: [u64; 3] = [1 << 19, 1 << 20, 1 << 21];

fn quiet_session(seed: u64) -> Session<SimEnv> {
    let quiet = CostParams {
        cpu_noise: 0.0,
        gpu_noise: 0.0,
        straggler_p: 0.0,
        ..CostParams::default()
    };
    Session::sim(SimMachine::new(i7_hd7950(1), seed).with_params(quiet))
}

fn stream() -> Vec<ServeRequest> {
    (0..REQUESTS)
        .map(|i| {
            ServeRequest::from(Computation::from(workloads::saxpy(
                SIZES[i % SIZES.len()],
            )))
        })
        .collect()
}

struct Point {
    name: &'static str,
    wall_rps: f64,
    virt_rps: f64,
    built: u64,
    warm_hits: u64,
    build_secs: f64,
}

/// Serve the stream through a pool whose shared KB is backed by the store
/// at `dir`, optionally warm-started from `snapshot` first.
fn run_serve(
    name: &'static str,
    dir: &Path,
    digest: &str,
    snapshot: Option<&KbSnapshot>,
    seed: u64,
) -> Point {
    let pool = SessionPool::build(CONCURRENCY, |i| quiet_session(seed + i as u64));
    let mut kb = KnowledgeBase::open_store(dir, digest).expect("open store");
    if let Some(snap) = snapshot {
        let (exact, hints) = kb.import_snapshot(snap);
        assert!(
            exact >= SIZES.len(),
            "{name}: imported only {exact} exact profiles"
        );
        assert_eq!(hints, 0, "{name}: same-platform import produced hints");
    }
    *pool.shared_kb().write().unwrap() = kb;
    let report = pool
        .serve(
            &stream(),
            &ServeOpts {
                concurrency: CONCURRENCY,
                pace: PACE_MS * 1e-3,
                store_sync_every: STORE_SYNC_EVERY,
                ..Default::default()
            },
        )
        .expect("serve");
    assert_eq!(report.completed, REQUESTS);
    Point {
        name,
        wall_rps: report.requests_per_sec,
        virt_rps: report.virtual_req_per_sec(),
        built: report.stats.built,
        warm_hits: report.stats.warm_hits,
        build_secs: report.stats.build_secs,
    }
}

/// Merge snapshots `a` and `b` into a fresh store at `dir` in the given
/// order and export the result's canonical bytes.
fn merge_bytes(dir: &Path, digest: &str, a: &KbSnapshot, b: &KbSnapshot) -> String {
    let mut store = KbStore::open(dir, digest).expect("open merge store");
    a.merge_into(&mut store);
    b.merge_into(&mut store);
    store.flush().expect("flush merge store");
    KbSnapshot::from_store(&store).encode()
}

fn main() {
    let root = std::env::temp_dir().join(format!(
        "marrow_bench_kbwarm_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&root);
    let dir = |name: &str| -> PathBuf { root.join(name) };
    let digest = machine_digest("analytic", &i7_hd7950(1));

    println!(
        "kb warm-start: {REQUESTS} requests over {} workloads, concurrency \
         {CONCURRENCY}, pace floor {PACE_MS} ms, store sync every \
         {STORE_SYNC_EVERY}\n",
        SIZES.len()
    );
    println!(
        "{:>18} {:>12} {:>14} {:>7} {:>10} {:>12}",
        "mode", "wall req/s", "virtual req/s", "built", "warm hits", "build secs"
    );

    // Cold fleet member: every distinct workload runs Algorithm 1 once.
    let cold = run_serve("cold_kb_serve", &dir("store-a"), &digest, None, 900);
    assert!(
        cold.built >= SIZES.len() as u64,
        "cold serve built only {} profiles",
        cold.built
    );
    assert!(
        cold.build_secs > 0.0,
        "cold serve reports no Algorithm 1 wall time"
    );

    // Export the cold member's learning and warm-start a fresh one from it.
    let store_a = KbStore::open(&dir("store-a"), &digest).expect("reopen store");
    let snap = KbSnapshot::from_store(&store_a);
    assert!(snap.len() >= SIZES.len());
    let warm = run_serve(
        "warm_start_serve",
        &dir("store-b"),
        &digest,
        Some(&snap),
        950,
    );
    assert_eq!(warm.built, 0, "warm-started serve ran cold builds");
    assert!(warm.warm_hits > 0, "warm-started serve saw no warm hits");
    assert_eq!(
        warm.build_secs, 0.0,
        "warm-started serve spent time in Algorithm 1"
    );

    for p in [&cold, &warm] {
        println!(
            "{:>18} {:>12.1} {:>14.1} {:>7} {:>10} {:>12.4}",
            p.name, p.wall_rps, p.virt_rps, p.built, p.warm_hits, p.build_secs
        );
    }

    // Merge determinism: the cold member's snapshot folded against a
    // partially-overlapping hand-built store must export the same bytes in
    // either merge order (the keep-best fold is commutative).
    {
        let mut store_c = KbStore::open(&dir("store-c"), &digest).expect("open store");
        for (i, &size) in SIZES.iter().enumerate() {
            let comp = Computation::from(workloads::saxpy(size));
            let (sct, w, _) = comp.spec().unwrap();
            // Odd entries beat anything learned (tiny best_time), even ones
            // lose — so the merged result draws from both sides.
            let best = if i % 2 == 0 { 1e3 } else { 1e-9 };
            store_c.stage(
                mk_profile(&sct.id(), w.clone(), FissionLevel::L2, vec![4], 0.5, best),
                None,
            );
        }
        store_c.flush().expect("flush store-c");
        let snap_c = KbSnapshot::from_store(&store_c);
        let ab = merge_bytes(&dir("merge-ab"), &digest, &snap, &snap_c);
        let ba = merge_bytes(&dir("merge-ba"), &digest, &snap_c, &snap);
        assert_eq!(ab, ba, "snapshot merge is order-dependent");
        println!(
            "\nmerge determinism: {} + {} records -> identical {} byte \
             snapshots in both orders",
            snap.len(),
            snap_c.len(),
            ab.len()
        );
    }

    let workloads_json: Vec<String> = [&cold, &warm]
        .iter()
        .map(|p| {
            format!(
                "    {{\"name\": \"{}\", \"requests_per_sec\": {:.2}, \
                 \"virtual_req_per_sec\": {:.2}, \"built\": {}, \
                 \"warm_hits\": {}, \"build_secs\": {:.6}}}",
                p.name, p.wall_rps, p.virt_rps, p.built, p.warm_hits, p.build_secs
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"kb_warmstart\",\n  \"pr\": 6,\n  \
         \"requests\": {REQUESTS},\n  \"concurrency\": {CONCURRENCY},\n  \
         \"pace_ms\": {PACE_MS},\n  \"workloads\": [\n{}\n  ],\n  \
         \"cold_build_secs_cold\": {:.6},\n  \
         \"cold_build_secs_warm\": {:.6},\n  \
         \"warm_cold_builds\": {},\n  \"merge_deterministic\": true\n}}\n",
        workloads_json.join(",\n"),
        cold.build_secs,
        warm.build_secs,
        warm.built
    );
    let path = "BENCH_pr6.json";
    match std::fs::write(path, &json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
    let _ = std::fs::remove_dir_all(&root);
}
