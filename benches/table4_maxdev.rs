//! Regenerates Table 4 (maxDev calibration over 500 stable executions).
use marrow::bench::eval::table4;
use marrow::bench::harness::Timer;

fn main() {
    let r = Timer::new(0, 1).time("table4 regeneration", || {
        let report = table4::report(table4::RUNS).expect("table4");
        println!("{report}");
    });
    println!("[bench] {}", r.row());
}
