//! Irregular-workload + trace-replay bench (BENCH_pr10.json, the PR-10
//! perf-trajectory point).
//!
//! Two measurements on the simulated i7+HD7950:
//!
//! 1. **Per-class cost models vs size-only interpolation** (DESIGN.md
//!    §2.13): each irregular family (CSR SpMV / sparse, BFS frontier /
//!    traversal, Mandelbrot / divergent) trains the KB at two sizes, then
//!    estimates two *held-out* sizes before running them. The class path
//!    rescales the observed seconds-per-element mean by the request's
//!    element count; the size-only path returns the nearest trained
//!    profile's time unrescaled — which is exactly how the pre-class KB
//!    mis-priced irregular admission. Reported per family: mean relative
//!    error of both paths. `tools/bench_gate.rs --irregular` holds the
//!    class path strictly below size-only on the sparse family.
//!
//! 2. **Replay determinism**: a recorded trace (mixed request stream,
//!    arrival offsets, a fig11-style background-load step, the pinned
//!    ExecProfile) is serialized to JSON, parsed back, and replayed twice
//!    on fresh pools. The virtual makespans must be bit-identical and the
//!    batch count equal — the replay contract `marrow serve --replay`
//!    ships on.

use marrow::bench::workloads;
use marrow::platform::device::i7_hd7950;
use marrow::runtime::exec::RequestArgs;
use marrow::scheduler::DrainMode;
use marrow::session::serve::{
    RecordedRequest, ReplayTrace, ServeOpts, ServeReport, ServeRequest, SessionPool,
};
use marrow::session::{Computation, ExecProfile, Session};
use marrow::sim::{LoadProfile, SimMachine};

const TRAIN_SIZES: [u64; 2] = [4096, 8192];
const HELDOUT_SIZES: [u64; 2] = [16384, 32768];

struct ClassPoint {
    workload: &'static str,
    class: &'static str,
    class_rel_err: f64,
    size_only_rel_err: f64,
}

/// Train the KB on `TRAIN_SIZES`, then estimate each held-out size with
/// both paths *before* running it; mean relative error per path.
fn estimate_errors(
    workload: &'static str,
    class: &'static str,
    mk: &dyn Fn(u64) -> workloads::Benchmark,
    seed: u64,
) -> ClassPoint {
    let s = Session::simulated(i7_hd7950(1), seed);
    for &n in &TRAIN_SIZES {
        let comp = Computation::from(mk(n));
        for _ in 0..2 {
            s.run(&comp, &RequestArgs::default()).expect("train run");
        }
    }
    let (mut class_err, mut size_err) = (0.0f64, 0.0f64);
    for &n in &HELDOUT_SIZES {
        let comp = Computation::from(mk(n));
        let (sct, w, _) = comp.spec().expect("spec");
        let (class_est, size_est) = {
            let kb = s.kb();
            (
                kb.estimate_time(&sct.id(), w).expect("class estimate"),
                kb.estimate_time_size_only(&sct.id(), w)
                    .expect("size-only estimate"),
            )
        };
        let actual = s
            .run(&comp, &RequestArgs::default())
            .expect("held-out run")
            .exec
            .total;
        class_err += ((class_est - actual) / actual).abs();
        size_err += ((size_est - actual) / actual).abs();
    }
    let n = HELDOUT_SIZES.len() as f64;
    ClassPoint {
        workload,
        class,
        class_rel_err: class_err / n,
        size_only_rel_err: size_err / n,
    }
}

/// The CLI's bench-name resolution, as replay re-applies it.
fn mk_bench(bench: &str, size: u64) -> workloads::Benchmark {
    match bench {
        "saxpy" => workloads::saxpy(size),
        "spmv" => workloads::spmv(size),
        "bfs" => workloads::bfs(size),
        "mandelbrot" => workloads::mandelbrot(size, 256),
        other => panic!("unknown bench in trace: {other}"),
    }
}

/// One replay of a parsed trace on a fresh pool: same construction as
/// `marrow serve --replay` (pool at the trace's concurrency, per-session
/// seeds, the recorded background load injected into every machine).
fn replay(trace: &ReplayTrace) -> ServeReport {
    let load = LoadProfile::new(trace.load.clone());
    let machine = i7_hd7950(1);
    let pool = SessionPool::build(trace.opts.concurrency.max(1), |i| {
        Session::sim(SimMachine::new(machine.clone(), 11 + i as u64).with_load(load.clone()))
    });
    let reqs: Vec<ServeRequest> = trace
        .requests
        .iter()
        .map(|r| {
            let mut req = ServeRequest::from(Computation::from(mk_bench(&r.bench, r.size)))
                .with_arrival_offset(r.offset)
                .with_priority(r.priority);
            req.deadline = r.replay_deadline();
            req
        })
        .collect();
    pool.serve(&reqs, &trace.opts).expect("replay serve")
}

fn main() {
    println!(
        "irregular replay: per-class KB estimates on held-out sizes \
         {HELDOUT_SIZES:?} (trained on {TRAIN_SIZES:?}), i7+HD7950, \
         simulated clock\n"
    );
    println!(
        "{:<16} {:>10} {:>16} {:>20}",
        "workload", "class", "class rel err", "size-only rel err"
    );

    let points = [
        estimate_errors("spmv", "sparse", &workloads::spmv, 101),
        estimate_errors("bfs", "traversal", &workloads::bfs, 202),
        estimate_errors(
            "mandelbrot",
            "divergent",
            &|n| workloads::mandelbrot(n, 256),
            303,
        ),
    ];
    for p in &points {
        println!(
            "{:<16} {:>10} {:>15.1}% {:>19.1}%",
            p.workload,
            p.class,
            p.class_rel_err * 100.0,
            p.size_only_rel_err * 100.0
        );
    }

    // The recorded stream: a mixed regular/irregular request mix with
    // arrival gaps, two requests carrying explicit deadlines, and a
    // background-load step kicking in mid-stream (fig. 11).
    let mix: [&str; 4] = ["saxpy", "spmv", "bfs", "mandelbrot"];
    let trace = ReplayTrace {
        opts: ServeOpts {
            concurrency: 2,
            batch_max: 4,
            batch_window: 5e-3,
            deadline_default: Some(30.0),
            exec: ExecProfile::new()
                .tasks_per_slot(8)
                .drain_mode(DrainMode::Dataflow),
            ..Default::default()
        },
        load: vec![(0, 0), (8, 6)],
        requests: (0..16)
            .map(|i| RecordedRequest {
                bench: mix[i % mix.len()].to_string(),
                size: if mix[i % mix.len()] == "saxpy" {
                    1 << 20
                } else {
                    8192
                },
                offset: i as f64 * 1e-3,
                deadline: if i % 7 == 0 { Some(0.5) } else { None },
                deadline_explicit: i % 7 == 0,
                priority: (i % 3) as u32,
            })
            .collect(),
    };

    // Through the wire format both times: what replays is the parsed
    // trace, not the in-memory one.
    let text = trace.to_json().to_string_pretty();
    let parsed = ReplayTrace::parse(&text).expect("trace round-trip");
    assert_eq!(parsed, trace, "trace JSON round-trip drifted");
    let a = replay(&parsed);
    let b = replay(&parsed);
    let identical =
        a.virtual_makespan.to_bits() == b.virtual_makespan.to_bits() && a.batches == b.batches;
    println!(
        "\nreplay: {} requests, virtual makespan {:.6}s vs {:.6}s, \
         batches {} vs {}, identical: {identical}",
        trace.requests.len(),
        a.virtual_makespan,
        b.virtual_makespan,
        a.batches,
        b.batches
    );

    let class_json: Vec<String> = points
        .iter()
        .map(|p| {
            format!(
                "    {{\"workload\": \"{}\", \"class\": \"{}\", \
                 \"class_rel_err\": {:.6}, \"size_only_rel_err\": {:.6}}}",
                p.workload, p.class, p.class_rel_err, p.size_only_rel_err
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"irregular_replay\",\n  \"pr\": 10,\n  \
         \"classes\": [\n{}\n  ],\n  \"replay\": {{\n    \
         \"requests\": {},\n    \"makespan_a\": {:.17e},\n    \
         \"makespan_b\": {:.17e},\n    \"batches_a\": {},\n    \
         \"batches_b\": {},\n    \"identical\": {identical}\n  }}\n}}\n",
        class_json.join(",\n"),
        trace.requests.len(),
        a.virtual_makespan,
        b.virtual_makespan,
        a.batches,
        b.batches
    );
    let path = "BENCH_pr10.json";
    match std::fs::write(path, &json) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
