//! # marrow — cooperative multi-CPU/multi-GPU execution of compound
//! multi-kernel computations
//!
//! Rust implementation of the Marrow runtime described in *"Execution of
//! Compound Multi-Kernel OpenCL Computations in Multi-CPU/Multi-GPU
//! Environments"* (Soldado, Alexandre, Paulino — CCPE 2015), re-architected
//! on a three-layer Rust + JAX/Pallas + PJRT stack:
//!
//! * **L1/L2** (build time, Python): Pallas kernels + JAX compositions,
//!   AOT-lowered to HLO-text artifacts (`python/compile/`, `artifacts/`).
//! * **L3** (this crate): the paper's contribution — skeleton computational
//!   trees ([`sct`]), locality-aware domain decomposition ([`decompose`]),
//!   CPU-fission / GPU-overlap execution platforms ([`platform`]),
//!   profile-based workload distribution ([`tuner`]), a knowledge base with
//!   RBF-interpolated configuration derivation ([`kb`]) and dynamic load
//!   balancing with adaptive binary search ([`balance`]).
//!
//! The OpenCL devices of the paper are substituted by a calibrated
//! performance simulator ([`sim`]) for paper-scale benches, while real
//! numerics run through the PJRT CPU client ([`runtime`]). See DESIGN.md.
//!
//! The user-facing entry point is the [`session`] facade: a [`session::Session`]
//! owns a backend ([`scheduler::SimEnv`] or
//! [`scheduler::real::RealScheduler`]), the knowledge base and the balancing
//! state, and [`session::Session::run`] resolves configurations through the
//! lookup → derive → build chain, executes, and self-adapts across requests.
//! Examples, the CLI and the benches all go through it rather than wiring
//! the layers by hand.

pub mod balance;
pub mod bench;
pub mod cli;
pub mod data;
pub mod decompose;
pub mod error;
pub mod kb;
pub mod platform;
pub mod runtime;
pub mod scheduler;
pub mod sct;
pub mod session;
pub mod sim;
pub mod tuner;
pub mod util;

pub use error::{Error, Result};
pub use session::{Computation, Session};
