//! Skeleton computational trees (Section 2).
//!
//! A Marrow computation is a tree of skeleton constructions — `Pipeline`,
//! `Loop`, `Map`, `MapReduce` — whose leaves are [`KernelSpec`]s wrapping
//! AOT-compiled kernels. Execution requests traverse the tree depth-first
//! (Section 2: K1, then the loop iterations of K2, then K3).

pub mod kernel;
pub mod node;

pub use kernel::{KernelSpec, ParamSpec};
pub use node::{HostReduce, HostUpdate, LoopState, Reduction, Sct};
