//! The skeleton tree: Pipeline, Loop, Map, MapReduce over kernel leaves
//! (Section 2.1).

use std::sync::Arc;

use crate::data::vector::{ArgValue, Merge};
use crate::sct::kernel::KernelSpec;

/// Host-side loop-state update (Loop stage 3, Section 3.1): receives the
/// iteration index and the partial outputs written by the SCT body and
/// mutates the request arguments for the next iteration. Returns `false`
/// to stop the loop (the stoppage condition).
pub type HostUpdate =
    Arc<dyn Fn(u32, &mut Vec<ArgValue>, &[ArgValue]) -> bool + Send + Sync>;

/// Host-side reduction function for MapReduce (Section 3.1: "the skeleton
/// also accepts C++ functions that are executed on the host side").
pub type HostReduce = Arc<dyn Fn(&[ArgValue]) -> ArgValue + Send + Sync>;

/// Loop skeleton state (Section 2.1): stoppage condition, updated data
/// items, and whether the update requires global (all-device) sync.
#[derive(Clone)]
pub struct LoopState {
    /// Upper bound on iterations (stoppage condition fallback).
    pub max_iters: u32,
    /// Whether the state update requires a global synchronization point
    /// between iterations (true for NBody: positions feed all devices).
    pub global_sync: bool,
    /// Host update; `None` means a pure for-loop over the body.
    pub update: Option<HostUpdate>,
}

impl std::fmt::Debug for LoopState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LoopState")
            .field("max_iters", &self.max_iters)
            .field("global_sync", &self.global_sync)
            .field("update", &self.update.as_ref().map(|_| "<fn>"))
            .finish()
    }
}

/// Reduction stage of MapReduce: on-device kernel or host function.
#[derive(Clone)]
pub enum Reduction {
    /// On-device reduction kernel. Each partition folds its own partial on
    /// device; `combine` is the operator that merges per-partition partials
    /// on the host (it must match the kernel's semantics — a product-tree
    /// kernel combines with `Merge::Mul`, not the historic hard-coded Add).
    Device {
        kernel: KernelSpec,
        combine: Merge,
    },
    Host(Merge),
    HostFn(HostReduce),
}

impl Reduction {
    /// On-device reduction combining partition partials with `combine`.
    pub fn device(kernel: KernelSpec, combine: Merge) -> Reduction {
        Reduction::Device { kernel, combine }
    }
}

impl std::fmt::Debug for Reduction {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Reduction::Device { kernel, combine } => {
                write!(f, "Device({},{combine:?})", kernel.family)
            }
            Reduction::Host(m) => write!(f, "Host({m:?})"),
            Reduction::HostFn(_) => write!(f, "HostFn(<fn>)"),
        }
    }
}

/// A skeleton computational tree.
#[derive(Clone, Debug)]
pub enum Sct {
    Kernel(KernelSpec),
    Pipeline(Vec<Sct>),
    Loop {
        body: Box<Sct>,
        state: LoopState,
    },
    Map(Box<Sct>),
    MapReduce {
        map: Box<Sct>,
        reduce: Reduction,
    },
}

impl Sct {
    pub fn kernel(k: KernelSpec) -> Sct {
        Sct::Kernel(k)
    }

    pub fn pipeline(stages: Vec<Sct>) -> Sct {
        Sct::Pipeline(stages)
    }

    pub fn map(tree: Sct) -> Sct {
        Sct::Map(Box::new(tree))
    }

    pub fn for_loop(body: Sct, iters: u32, global_sync: bool) -> Sct {
        Sct::Loop {
            body: Box::new(body),
            state: LoopState {
                max_iters: iters,
                global_sync,
                update: None,
            },
        }
    }

    pub fn loop_with(body: Sct, state: LoopState) -> Sct {
        Sct::Loop {
            body: Box::new(body),
            state,
        }
    }

    pub fn map_reduce(map: Sct, reduce: Reduction) -> Sct {
        Sct::MapReduce {
            map: Box::new(map),
            reduce,
        }
    }

    /// Kernel leaves in depth-first (execution) order.
    pub fn kernels(&self) -> Vec<&KernelSpec> {
        let mut out = Vec::new();
        self.collect_kernels(&mut out);
        out
    }

    fn collect_kernels<'a>(&'a self, out: &mut Vec<&'a KernelSpec>) {
        match self {
            Sct::Kernel(k) => out.push(k),
            Sct::Pipeline(stages) => {
                for s in stages {
                    s.collect_kernels(out);
                }
            }
            Sct::Loop { body, .. } => body.collect_kernels(out),
            Sct::Map(t) => t.collect_kernels(out),
            Sct::MapReduce { map, reduce } => {
                map.collect_kernels(out);
                if let Reduction::Device { kernel, .. } = reduce {
                    out.push(kernel);
                }
            }
        }
    }

    /// Total loop-iteration multiplier applied to the body kernels (used by
    /// the cost model; 1 for loop-free trees).
    pub fn iteration_factor(&self) -> f64 {
        match self {
            Sct::Kernel(_) => 1.0,
            Sct::Pipeline(stages) => stages
                .iter()
                .map(|s| s.iteration_factor())
                .fold(1.0, f64::max),
            Sct::Loop { body, state } => state.max_iters as f64 * body.iteration_factor(),
            Sct::Map(t) => t.iteration_factor(),
            Sct::MapReduce { map, .. } => map.iteration_factor(),
        }
    }

    /// Number of global synchronization points per execution (Loop
    /// iterations whose state update is global).
    pub fn sync_points(&self) -> u32 {
        match self {
            Sct::Kernel(_) => 0,
            Sct::Pipeline(stages) => stages.iter().map(|s| s.sync_points()).sum(),
            Sct::Loop { body, state } => {
                let inner = body.sync_points();
                if state.global_sync {
                    state.max_iters * (inner + 1)
                } else {
                    state.max_iters * inner
                }
            }
            Sct::Map(t) => t.sync_points(),
            Sct::MapReduce { map, .. } => map.sync_points(),
        }
    }

    /// Structural identifier used as the SCT's unique id in the knowledge
    /// base (profile field (a), Section 3.2.1).
    pub fn id(&self) -> String {
        match self {
            Sct::Kernel(k) => k.family.clone(),
            Sct::Pipeline(stages) => {
                let inner: Vec<String> = stages.iter().map(|s| s.id()).collect();
                format!("pipeline({})", inner.join(","))
            }
            Sct::Loop { body, state } => {
                format!("loop({},n={})", body.id(), state.max_iters)
            }
            Sct::Map(t) => format!("map({})", t.id()),
            Sct::MapReduce { map, reduce } => {
                let r = match reduce {
                    Reduction::Device { kernel, .. } => kernel.family.clone(),
                    Reduction::Host(m) => format!("host:{m:?}"),
                    Reduction::HostFn(_) => "host:fn".to_string(),
                };
                format!("map_reduce({},{r})", map.id())
            }
        }
    }

    /// The quantum (in epu units) all partitions must respect: the least
    /// common multiple of every kernel's granularity constraint. This is the
    /// global-vision partitioning constraint of Section 3.1: consecutive
    /// kernels communicating through persisted device buffers must see
    /// identically-partitioned vectors.
    pub fn quantum_units(&self, wgs: u32) -> u64 {
        self.kernels()
            .iter()
            .map(|k| k.quantum_units(k.fixed_wgs.unwrap_or(wgs)))
            .fold(1, lcm)
    }
}

fn gcd(a: u64, b: u64) -> u64 {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

fn lcm(a: u64, b: u64) -> u64 {
    a / gcd(a, b) * b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sct::kernel::ParamSpec;

    fn k(name: &str, epu: u64) -> KernelSpec {
        KernelSpec::new(name, vec![ParamSpec::VecIn], epu)
    }

    #[test]
    fn depth_first_kernel_order() {
        // Fig. 1: pipeline(K1, loop(K2), K3) -> K1, K2, K3.
        let sct = Sct::pipeline(vec![
            Sct::kernel(k("k1", 1)),
            Sct::for_loop(Sct::kernel(k("k2", 1)), 5, true),
            Sct::kernel(k("k3", 1)),
        ]);
        let names: Vec<&str> = sct.kernels().iter().map(|k| k.family.as_str()).collect();
        assert_eq!(names, vec!["k1", "k2", "k3"]);
    }

    #[test]
    fn loop_multiplies_iteration_factor() {
        let sct = Sct::for_loop(Sct::kernel(k("body", 1)), 10, true);
        assert_eq!(sct.iteration_factor(), 10.0);
        assert_eq!(sct.sync_points(), 10);
    }

    #[test]
    fn non_sync_loop_has_no_sync_points() {
        let sct = Sct::for_loop(Sct::kernel(k("body", 1)), 10, false);
        assert_eq!(sct.sync_points(), 0);
    }

    #[test]
    fn id_encodes_structure() {
        let sct = Sct::pipeline(vec![
            Sct::kernel(k("a", 1)),
            Sct::for_loop(Sct::kernel(k("b", 1)), 3, false),
        ]);
        assert_eq!(sct.id(), "pipeline(a,loop(b,n=3))");
    }

    #[test]
    fn quantum_is_lcm_over_kernels() {
        // saxpy-like: epu 1 elem, wgs 256 -> 256 units; paired with a
        // line kernel needing 1 unit -> lcm 256.
        let sct = Sct::pipeline(vec![Sct::kernel(k("a", 1)), Sct::kernel(k("b", 2048))]);
        assert_eq!(sct.quantum_units(256), 256);
    }

    #[test]
    fn map_reduce_device_kernel_listed() {
        use crate::data::vector::Merge;
        let sct = Sct::map_reduce(
            Sct::kernel(k("m", 1)),
            Reduction::device(k("r", 1), Merge::Add),
        );
        let names: Vec<&str> = sct.kernels().iter().map(|k| k.family.as_str()).collect();
        assert_eq!(names, vec!["m", "r"]);
    }
}
