//! Kernel objects: the leaf computational units of an SCT (Section 2.1).
//!
//! A `KernelSpec` encloses the kernel's logic (by artifact family reference —
//! the actual compute lives in the AOT-compiled HLO artifact) and its
//! *interface*: parameter classification (vector/scalar, partitionable/COPY,
//! partition-sensitive traits), the elementary partitioning unit, the
//! user-bound work-group size and the per-thread work amount. Multi-device
//! support (Section 3.1) adds the partitionability declarations used by the
//! locality-aware domain decomposition.

use crate::data::vector::ScalarTrait;
use crate::platform::occupancy::KernelFootprint;

/// Declaration of one kernel parameter.
#[derive(Clone, Debug, PartialEq)]
pub enum ParamSpec {
    /// Input vector partitioned under the domain decomposition.
    VecIn,
    /// Input vector replicated integrally to every device (COPY mode).
    VecCopy,
    /// Scalar input, possibly partition-sensitive (Size / Offset traits).
    ScalarF32(ScalarTrait),
    ScalarI32(ScalarTrait),
}

/// A kernel leaf: interface specification + cost/resource metadata.
#[derive(Clone, Debug)]
pub struct KernelSpec {
    /// Human name; also the artifact family in `artifacts/manifest.json`.
    pub family: String,
    /// Parameter declarations, positionally matching the artifact inputs.
    pub params: Vec<ParamSpec>,
    /// Number of output vectors produced per chunk.
    pub outputs: usize,
    /// Elements of each partitioned vector spanned by one elementary
    /// partitioning unit (e.g. image width for line-partitioned filters).
    pub elems_per_unit: u64,
    /// Elements of the work space computed by each thread (`nu`, default 1).
    pub work_per_thread: u32,
    /// Kernel-bound work-group size, if the computation requires one.
    pub fixed_wgs: Option<u32>,
    /// GPU resource footprint for the occupancy calculator.
    pub footprint: KernelFootprint,
    /// Cost-model metadata: flops / bytes per epu unit, and how many times
    /// the kernel re-traverses its working set (cache-locality `passes`).
    pub flops_per_unit: f64,
    pub bytes_per_unit: f64,
    pub passes: f64,
    /// Coefficient of variation of the *per-chunk* cost (0 = uniform,
    /// the regular data-parallel default). Irregular kernels — sparse
    /// rows, frontier expansion, escape iteration — declare the spread of
    /// their data-dependent cost here so the simulator prices chunks
    /// non-uniformly and the stealing machinery sees genuine imbalance.
    pub chunk_cv: f64,
}

impl KernelSpec {
    /// A builder-lite constructor with the common defaults.
    pub fn new(family: &str, params: Vec<ParamSpec>, elems_per_unit: u64) -> KernelSpec {
        KernelSpec {
            family: family.to_string(),
            params,
            outputs: 1,
            elems_per_unit,
            work_per_thread: 1,
            fixed_wgs: None,
            footprint: KernelFootprint {
                local_mem_base: 0,
                local_mem_per_thread: 0,
                regs_per_thread: 24,
            },
            flops_per_unit: 1.0,
            bytes_per_unit: 8.0,
            passes: 1.0,
            chunk_cv: 0.0,
        }
    }

    /// Granularity constraint (Section 3.1): partition sizes (in units) must
    /// be divisible by `quantum_units(wgs)`, which accounts for the
    /// work-group size and the per-thread work amount mapped into epu units.
    ///
    ///   epu(V) mod nu(V,K) = 0       (validated at spec build)
    ///   #V_j mod (epu/nu) = 0  and  #V_j mod wgs_j(K) = 0
    ///
    /// In the unit domain: one work-group of size `wgs` with `nu` elements
    /// per thread consumes `wgs * nu / elems_per_unit` units (at least 1).
    pub fn quantum_units(&self, wgs: u32) -> u64 {
        let elems = wgs as u64 * self.work_per_thread as u64;
        elems.div_ceil(self.elems_per_unit).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantum_maps_threads_to_units() {
        // Filter kernels: one line = 2048 elems, 2 px/thread:
        // a 256-thread WG covers 512 px -> under one line -> quantum 1 unit.
        let mut k = KernelSpec::new("filter_pipeline", vec![ParamSpec::VecIn], 2048);
        k.work_per_thread = 2;
        assert_eq!(k.quantum_units(256), 1);
        // Saxpy: epu = 1 element -> a 256-thread WG needs 256 units.
        let s = KernelSpec::new("saxpy", vec![ParamSpec::VecIn], 1);
        assert_eq!(s.quantum_units(256), 256);
    }

    #[test]
    fn quantum_never_zero() {
        let k = KernelSpec::new("seg", vec![ParamSpec::VecIn], 1 << 20);
        assert_eq!(k.quantum_units(64), 1);
    }
}
