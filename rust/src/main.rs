//! marrow — CLI launcher for the Marrow reproduction.
//!
//! Subcommands:
//!   eval <table2|table3|table4|table5|fig11|ablations|all>
//!       regenerate the paper's tables/figures (simulated clock)
//!   profile --bench <name> --size <n> [--gpus <g>] [--kb <path>]
//!       run Algorithm 1 on one benchmark through a Session and print the
//!       profile (persisted when --kb is given)
//!   run --bench <name> --size <n> [--gpus <g>] [--runs <r>] [--kb <path>]
//!       [--concurrency <c>]
//!       repeated Session::run requests: KB lookup -> derive -> build chain,
//!       execution monitoring and adaptive rebalancing, per-run trace
//!       (with --concurrency > 1 the requests drain through a session pool)
//!   serve --bench <name> --size <n> [--requests <r>] [--concurrency <c>]
//!       [--pace-ms <m>] [--kb <path>] [--co-schedule] [--batch-max <n>]
//!       [--batch-window <ms>] [--deadline-default <ms>]
//!       multi-request serve path: a pool of sessions over one shared KB
//!       drains the request stream under the admission cap; reports
//!       requests/sec, p50/p99 latency, and the admit-wait/drain split.
//!       With --co-schedule each request is admitted onto the
//!       KB-cost-priced device subset minimizing its predicted completion
//!       (DESIGN.md 2.8) instead of time-sharing the whole pool. With
//!       --batch-max > 1, consecutive compatible requests coalesce into
//!       one fused drain (DESIGN.md 2.10): --batch-window <ms> bounds the
//!       fusion stretch the oldest member absorbs (default 2 ms, scaled
//!       down by request priority), and --deadline-default <ms> attaches
//!       an SLO to deadline-free requests — batches never stretch past any
//!       member's slack, and overruns are reported as deadline misses.
//!       --arrival-gap-ms spaces request arrivals, --load injects a
//!       fig11-style background CPU-load schedule (sim backend), --record
//!       writes a replayable trace of the run, and --replay <trace.json>
//!       re-drains a recorded mix deterministically in virtual time
//!       (DESIGN.md 2.13)
//!   graph --bench <name> --size <n> [--gpus <g>] [--tasks-per-slot <t>]
//!       dump the benchmark's dataflow TaskGraph as GraphViz DOT (nodes
//!       labelled stage/chunk/slot, sync nodes highlighted)
//!   kb <export|import|merge|stats|gc> --store <dir>
//!       operate on a durable content-addressed KB store (DESIGN.md 2.9)
//!       without running a session: export a snapshot, import/merge another
//!       store / snapshot / legacy KB file, print stats, compact segments
//!   shoc
//!       install-time calibration: host microbenchmarks + GPU ranking
//!   info
//!       machine descriptions and artifact inventory
//!
//! `run` and `serve` accept `--drain <barrier|dataflow>` to pin the drain
//! mode (default dataflow; barrier is the A/B baseline). `profile`, `run`
//! and `serve` accept `--kb-store <dir>` (mutually exclusive with `--kb`)
//! to back the knowledge base with the durable store; `serve` additionally
//! takes `--import <snapshot>` for warm-starting a fleet member and
//! `--store-sync-every <n>` for mid-stream durability.
//!
//! `profile`, `run` and `serve` accept `--backend <sim|native|pjrt>`
//! (default sim). `native` executes the compiled in-process CPU kernels on
//! the host machine (DESIGN.md §2.11): timings are real wall-clock
//! measurements, input buffers are synthesized deterministically, and
//! `--gpus` is ignored (the host has none). Native sizes are constrained
//! by the built-in artifact menu: filter needs --size 256|512|1024, nbody
//! needs --size 512|2048, and segmentation is sim-only. `pjrt` drives AOT
//! artifacts and needs the `pjrt` feature plus `make artifacts`.

use std::path::{Path, PathBuf};

use marrow::bench::eval::{ablations, fig11, table2, table3, table4, table5};
use marrow::bench::workloads::{self, Benchmark};
use marrow::cli::Args;
use marrow::kb::store::snapshot::KbSnapshot;
use marrow::kb::store::{machine_digest, KbStore};
use marrow::kb::KnowledgeBase;
use marrow::platform::device::{host_cpu, i7_hd7950, opteron_6272_quad, Machine};
use marrow::decompose::graph::{build_graph, flatten_stages};
use marrow::runtime::artifacts::Manifest;
use marrow::runtime::client::RtClient;
use marrow::runtime::exec::RequestArgs;
use marrow::scheduler::ExecEnv;
use marrow::session::serve::{
    RecordedRequest, ReplayTrace, ServeOpts, ServeRequest, SessionPool,
};
use marrow::session::{Backend, Computation, ExecProfile, Session};
use marrow::tuner::profile::Profile;
use marrow::sim::{shoc, LoadProfile, SimMachine};
use marrow::Result;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env();
    match args.command.as_deref() {
        Some("eval") => eval(&args),
        Some("profile") => profile(&args),
        Some("run") => run_cmd(&args),
        Some("serve") => serve_cmd(&args),
        Some("kb") => kb_cmd(&args),
        Some("graph") => graph_cmd(&args),
        Some("shoc") => shoc_cmd(),
        Some("info") => info(),
        _ => {
            println!("{}", USAGE);
            Ok(())
        }
    }
}

const USAGE: &str = "\
marrow — multi-CPU/multi-GPU execution of compound multi-kernel computations
usage:
  marrow eval <table2|table3|table4|table5|fig11|ablations|all>
  marrow profile --bench <saxpy|filter|fft|nbody|segmentation|spmv|bfs|mandelbrot> --size <n> [--backend <sim|native|pjrt>] [--gpus <g>] [--kb <path> | --kb-store <dir>]
  marrow run --bench <name> --size <n> [--backend <sim|native|pjrt>] [--gpus <g>] [--runs <r>] [--kb <path> | --kb-store <dir>] [--concurrency <c>] [--tasks-per-slot <t>] [--drain <barrier|dataflow>] [--prefetch-depth <k>] [--no-residency] [--max-dev <d>]
  marrow serve --bench <name> --size <n> [--backend <sim|native>] [--requests <r>] [--concurrency <c>] [--pace-ms <m>] [--kb <path> | --kb-store <dir> [--import <snapshot>] [--store-sync-every <n>]] [--tasks-per-slot <t>] [--drain <barrier|dataflow>] [--prefetch-depth <k>] [--co-schedule] [--batch-max <n>] [--batch-window <ms>] [--deadline-default <ms>] [--arrival-gap-ms <g>] [--load <from:threads,...>] [--record <trace.json>]
  marrow serve --replay <trace.json> [--gpus <g>] [--kb <path>]
  marrow kb <export|import|merge|stats|gc> --store <dir> [--from <store|snapshot|kb.json>] [--out <path>] [--gpus <g>]
  marrow graph --bench <name> --size <n> [--gpus <g>] [--tasks-per-slot <t>] [--prefetch-depth <k>] [--kb <path>]

benchmarks: saxpy|filter|fft|nbody|segmentation (regular) and
spmv|bfs|mandelbrot (irregular: data-dependent per-chunk cost; spmv/bfs
need --size % 256 == 0, mandelbrot --size % 4096 == 0 on native).

--prefetch-depth <k>: dataflow-drain lookahead (DESIGN.md §2.12) — parked
workers stage uploads for up to k not-yet-ready chunks under earlier
chunks' compute. 0 (default) disables prefetch; results are bit-identical
either way. `marrow graph` dashes the prefetch edges into the DOT dump.

--record/--replay (DESIGN.md §2.13): --record writes the served request
mix (arrival offsets, deadlines, priorities), the run's ExecProfile-bearing
options, and the --load schedule as a versioned JSON trace; --replay
re-drains it on the simulated backend — same trace + same starting KB give
a bit-identical virtual makespan and batch shapes.
  marrow shoc
  marrow info";

fn eval(args: &Args) -> Result<()> {
    let what = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let all = what == "all";
    if all || what == "table2" {
        println!("{}", table2::report()?);
    }
    if all || what == "table3" {
        println!("{}", table3::report()?);
    }
    if all || what == "table4" {
        println!("{}", table4::report(table4::RUNS)?);
    }
    if all || what == "table5" {
        println!("{}", table5::report()?);
    }
    if all || what == "fig11" {
        println!("{}", fig11::report()?);
    }
    if all || what == "ablations" {
        println!("{}", ablations::discard_ordering()?);
        println!("{}", ablations::locality()?);
        println!("{}", ablations::interpolation()?);
    }
    Ok(())
}

fn pick_benchmark(args: &Args) -> Result<Benchmark> {
    benchmark_by_name(&args.get_or("bench", "saxpy"), args.get_u64("size", 10_000_000)?)
}

/// Resolve a benchmark by name — the CLI's `--bench` flag and a replay
/// trace's recorded requests both go through here, so a trace stays a
/// small portable document (names and sizes, not buffers).
fn benchmark_by_name(bench: &str, size: u64) -> Result<Benchmark> {
    match bench {
        "saxpy" => Ok(workloads::saxpy(size)),
        "filter" => Ok(workloads::filter_pipeline(size, size, true)),
        "fft" => Ok(workloads::fft(size)),
        "nbody" => Ok(workloads::nbody(size, 20)),
        "segmentation" => Ok(workloads::segmentation(size)),
        // Irregular tier (ROADMAP item 4): data-dependent per-chunk cost.
        "spmv" => Ok(workloads::spmv(size)),
        "bfs" => Ok(workloads::bfs(size)),
        "mandelbrot" => Ok(workloads::mandelbrot(size, 256)),
        other => Err(marrow::Error::Usage(format!("unknown benchmark '{other}'"))),
    }
}

fn pick_machine(args: &Args) -> Result<Machine> {
    let gpus = args.get_u64("gpus", 1)? as usize;
    Ok(if gpus == 0 {
        opteron_6272_quad()
    } else {
        i7_hd7950(gpus)
    })
}

/// `--backend <sim|native|pjrt>` (default sim).
fn pick_backend(args: &Args) -> Result<Backend> {
    Backend::parse(&args.get_or("backend", "sim"))
}

/// Honour the optional `--kb <path>` (legacy single-file KB) or
/// `--kb-store <dir>` (durable content-addressed store, DESIGN.md §2.9)
/// flag on any backend's session.
fn apply_kb_flags<E: ExecEnv>(s: Session<E>, args: &Args) -> Result<Session<E>> {
    match (args.get("kb"), args.get("kb-store")) {
        (Some(_), Some(_)) => Err(marrow::Error::Usage(
            "--kb and --kb-store are mutually exclusive".into(),
        )),
        (Some(path), None) => s.with_kb_path(&PathBuf::from(path)),
        (None, Some(dir)) => s.with_kb_store(&PathBuf::from(dir)),
        (None, None) => Ok(s),
    }
}

/// Build a simulated session honouring the KB flags.
fn sim_session(
    args: &Args,
    machine: Machine,
    seed: u64,
) -> Result<Session<marrow::scheduler::SimEnv>> {
    apply_kb_flags(Session::simulated(machine, seed), args)
}

/// Deterministic real input buffers for the native (and pjrt) backends.
/// The simulator prices workloads analytically and ignores argument
/// content; these backends execute kernels over actual memory, so the CLI
/// synthesizes buffers shaped to the benchmark — validated against the
/// built-in artifact menu's shape constraints (widths, body counts).
fn native_request_args(args: &Args) -> Result<RequestArgs> {
    use marrow::data::image::{bodies, image, randn_vec};
    use marrow::data::vector::VectorArg;
    let bench = args.get_or("bench", "saxpy");
    let size = args.get_u64("size", 10_000_000)?;
    match bench.as_str() {
        "saxpy" => {
            let n = size as usize;
            Ok(RequestArgs {
                vectors: vec![
                    VectorArg::partitioned_f32("x", randn_vec(1, n), 1),
                    VectorArg::partitioned_f32("y", randn_vec(2, n), 1),
                ],
                scalars: vec![2.0],
            })
        }
        "filter" => {
            let (h, w) = (size, size);
            if ![256u64, 512, 1024].contains(&w) {
                return Err(marrow::Error::Usage(format!(
                    "native filter needs --size 256, 512 or 1024 (built-in \
                     artifact widths); got {size}"
                )));
            }
            Ok(RequestArgs {
                vectors: vec![VectorArg::partitioned_f32(
                    "img",
                    image(3, h as usize, w as usize),
                    w,
                )],
                // seed, row_off (Offset trait: per-chunk, base ignored), thresh
                scalars: vec![12345.0, 0.0, 96.0],
            })
        }
        "fft" => {
            // --size is MiB of 512-point complex FFTs (4 KiB per transform).
            let n_ffts = (size * 1024 * 1024 / (512 * 8)).max(1) as usize;
            Ok(RequestArgs {
                vectors: vec![
                    VectorArg::partitioned_f32("re", randn_vec(5, n_ffts * 512), 512),
                    VectorArg::partitioned_f32("im", randn_vec(6, n_ffts * 512), 512),
                ],
                scalars: vec![],
            })
        }
        "nbody" => {
            if size != 512 && size != 2048 {
                return Err(marrow::Error::Usage(format!(
                    "native nbody needs --size 512 or 2048 (built-in \
                     artifact body counts); got {size}"
                )));
            }
            Ok(RequestArgs {
                vectors: vec![VectorArg::copied_f32("pos", bodies(9, size as usize))],
                scalars: vec![0.0], // offset: per-chunk value, base ignored
            })
        }
        "segmentation" => Err(marrow::Error::Usage(
            "segmentation is not in the native artifact menu (its plane epu \
             has no built-in kernel shape); use --backend sim"
                .into(),
        )),
        "spmv" => {
            use marrow::data::irregular::spmv_inputs;
            if size % 256 != 0 {
                return Err(marrow::Error::Usage(format!(
                    "native spmv needs --size divisible by 256 (built-in \
                     artifact chunks); got {size}"
                )));
            }
            let (cols, vals, x) = spmv_inputs(17, size as usize, 16, 4096);
            Ok(RequestArgs {
                vectors: vec![
                    VectorArg::partitioned_f32("cols", cols, 16),
                    VectorArg::partitioned_f32("vals", vals, 16),
                    VectorArg::copied_f32("x", x),
                ],
                scalars: vec![],
            })
        }
        "bfs" => {
            use marrow::data::irregular::bfs_inputs;
            if size % 256 != 0 {
                return Err(marrow::Error::Usage(format!(
                    "native bfs needs --size divisible by 256 (built-in \
                     artifact chunks); got {size}"
                )));
            }
            let (adj, frontier) = bfs_inputs(19, size as usize, 8, 4096);
            Ok(RequestArgs {
                vectors: vec![
                    VectorArg::partitioned_f32("adj", adj, 8),
                    VectorArg::copied_f32("frontier", frontier),
                ],
                scalars: vec![],
            })
        }
        "mandelbrot" => {
            use marrow::data::irregular::mandelbrot_plane;
            if size % 4096 != 0 {
                return Err(marrow::Error::Usage(format!(
                    "native mandelbrot needs --size divisible by 4096 \
                     (built-in artifact chunks); got {size}"
                )));
            }
            let (re, im) = mandelbrot_plane(size as usize);
            Ok(RequestArgs {
                vectors: vec![
                    VectorArg::partitioned_f32("c_re", re, 1),
                    VectorArg::partitioned_f32("c_im", im, 1),
                ],
                scalars: vec![256.0], // max_iters
            })
        }
        other => Err(marrow::Error::Usage(format!("unknown benchmark '{other}'"))),
    }
}

/// Run Algorithm 1 on any backend's session and persist the KB.
fn profile_on<E: ExecEnv>(
    session: &Session<E>,
    comp: &Computation,
    rargs: &RequestArgs,
) -> Result<Profile> {
    let p = session.profile_with_args(comp, rargs)?;
    session.save_kb()?;
    Ok(p)
}

fn profile(args: &Args) -> Result<()> {
    let b = pick_benchmark(args)?;
    let name = b.name.clone();
    let comp = Computation::from(b);
    let (p, clock) = match pick_backend(args)? {
        Backend::Sim => {
            let session = sim_session(args, pick_machine(args)?, 7)?;
            (profile_on(&session, &comp, &RequestArgs::default())?, "sim")
        }
        Backend::Native => {
            let session = apply_kb_flags(Session::native(host_cpu())?, args)?;
            let rargs = native_request_args(args)?;
            (profile_on(&session, &comp, &rargs)?, "measured")
        }
        Backend::Pjrt => {
            let manifest = Manifest::load_default()?;
            let client = RtClient::cpu()?;
            let session =
                apply_kb_flags(Session::real(pick_machine(args)?, &client, &manifest), args)?;
            let rargs = native_request_args(args)?;
            (profile_on(&session, &comp, &rargs)?, "measured")
        }
    };
    println!("benchmark      : {}", name);
    println!("sct id         : {}", p.sct_id);
    println!("workload       : {}", p.workload.id());
    println!(
        "configuration  : fission={} overlap={:?} wgs={}",
        p.config.fission.label(),
        p.config.overlap,
        p.config.wgs
    );
    println!(
        "distribution   : GPU {:.1}% / CPU {:.1}%",
        100.0 * p.config.gpu_share(),
        100.0 * p.config.cpu_share
    );
    println!("best time ({clock}): {:.4} s", p.best_time);
    Ok(())
}

/// The seamless path, observable: repeated `Session::run` requests with the
/// per-run configuration origin and the balancer's refinements.
fn run_cmd(args: &Args) -> Result<()> {
    let runs = args.get_u64("runs", 8)?;
    let concurrency = args.get_u64("concurrency", 1)? as usize;
    if concurrency > 1 {
        // Concurrent requests drain through the serve path, keeping run's
        // own request-count default (8 runs, not serve's 32).
        return serve_requests(args, runs);
    }
    match pick_backend(args)? {
        Backend::Sim => {
            let session = sim_session(args, pick_machine(args)?, 11)?;
            run_loop(args, &session, &RequestArgs::default(), runs, "simulated clock")
        }
        Backend::Native => {
            let session = apply_kb_flags(Session::native(host_cpu())?, args)?;
            let rargs = native_request_args(args)?;
            run_loop(args, &session, &rargs, runs, "native measured")
        }
        Backend::Pjrt => {
            let manifest = Manifest::load_default()?;
            let client = RtClient::cpu()?;
            let session =
                apply_kb_flags(Session::real(pick_machine(args)?, &client, &manifest), args)?;
            let rargs = native_request_args(args)?;
            run_loop(args, &session, &rargs, runs, "pjrt measured")
        }
    }
}

/// The run-command loop, generic over the backend.
fn run_loop<E: ExecEnv>(
    args: &Args,
    session: &Session<E>,
    rargs: &RequestArgs,
    runs: u64,
    clock: &str,
) -> Result<()> {
    let b = pick_benchmark(args)?;
    let name = b.name.clone();
    let comp = Computation::from(b);
    // All execution knobs resolve through one ExecProfile (DESIGN.md
    // §2.13): parsed once, applied once, recorded as one value.
    let exec = ExecProfile::from_args(args)?;
    session.apply_exec(&exec);
    let drain = exec.drain_mode.unwrap_or_default();
    println!(
        "benchmark: {name} ({} runs, {clock}, {} drain)",
        runs,
        drain.label()
    );
    println!(" run | origin  | GPU share | exec time | idle% | balanced?");
    println!("-----+---------+-----------+-----------+-------+----------");
    for run in 0..runs {
        let out = session.run(&comp, rargs)?;
        println!(
            " {run:>3} | {:<7} |   {:>5.1}%  | {:>7.3}ms | {:>4.1}% | {}",
            out.origin.label(),
            100.0 * out.config.gpu_share(),
            out.exec.total * 1e3,
            100.0 * out.exec.mean_idle_frac(),
            if out.rebalanced {
                "rebalanced"
            } else if out.unbalanced {
                "no"
            } else {
                "yes"
            },
        );
    }
    let st = session.stats();
    println!(
        "\n{} runs: {} kb hits, {} derived, {} built, {} balance ops",
        st.runs, st.kb_hits, st.derived, st.built, st.balance_ops
    );
    println!(
        "transfers: {:.1} MB uploaded ({:.1}% overlapped), {:.1} MB \
         downloaded, {} uploads avoided, {} steal migrations; mean slot \
         idle {:.1}%",
        st.bytes_uploaded as f64 / 1e6,
        st.overlap_pct(),
        st.bytes_downloaded as f64 / 1e6,
        st.uploads_avoided,
        st.steal_migrations,
        st.mean_idle_pct()
    );
    session.save_kb()?;
    if args.get("kb").is_some() || args.get("kb-store").is_some() {
        println!("knowledge base persisted ({} profiles)", session.kb().len());
    }
    Ok(())
}

/// The multi-request serve path: drain a request stream through a pool of
/// simulated sessions sharing one knowledge base. `--replay <trace.json>`
/// re-drains a recorded request mix instead of synthesizing one.
fn serve_cmd(args: &Args) -> Result<()> {
    if let Some(path) = args.get("replay") {
        return replay_cmd(args, Path::new(path));
    }
    serve_requests(args, args.get_u64("runs", 32)?)
}

/// Parse `--load from:threads[,from:threads...]` — the fig11-style
/// background CPU-load schedule (interfering threads from a run index on).
fn parse_load_steps(spec: &str) -> Result<Vec<(u64, u32)>> {
    let mut steps = Vec::new();
    for part in spec.split(',').filter(|p| !p.is_empty()) {
        let bad = || {
            marrow::Error::Usage(format!(
                "--load expects 'from:threads[,from:threads...]', got '{part}'"
            ))
        };
        let (from, threads) = part.split_once(':').ok_or_else(bad)?;
        steps.push((
            from.trim().parse().map_err(|_| bad())?,
            threads.trim().parse().map_err(|_| bad())?,
        ));
    }
    Ok(steps)
}

/// Serve with an explicit request-count default (`marrow run --concurrency`
/// delegates here with run's default of 8). Builds the backend-specific
/// session pool, then drains through the generic path.
fn serve_requests(args: &Args, default_requests: u64) -> Result<()> {
    let concurrency = (args.get_u64("concurrency", 4)? as usize).max(1);
    let load_steps = match args.get("load") {
        Some(spec) => parse_load_steps(spec)?,
        None => Vec::new(),
    };
    match pick_backend(args)? {
        Backend::Sim => {
            let machine = pick_machine(args)?;
            let digest = machine_digest("analytic", &machine);
            let load = LoadProfile::new(load_steps.clone());
            let pool = SessionPool::build(concurrency, |i| {
                Session::sim(
                    SimMachine::new(machine.clone(), 11 + i as u64)
                        .with_load(load.clone()),
                )
            });
            serve_on_pool(
                args,
                default_requests,
                &pool,
                &digest,
                RequestArgs::default(),
                "simulated clock",
                &load_steps,
            )
        }
        Backend::Native => {
            if !load_steps.is_empty() {
                return Err(marrow::Error::Usage(
                    "--load models interfering CPU threads in the simulator; \
                     it needs --backend sim"
                        .into(),
                ));
            }
            let machine = host_cpu();
            let rargs = native_request_args(args)?;
            // The KB store is keyed by the backend's own digest so native
            // profiles stay separate from analytic/sim ones; probe it off
            // a throwaway session.
            let digest = Session::native(machine.clone())?.env().manifest_digest();
            let m = machine.clone();
            let pool = SessionPool::build(concurrency, move |_| {
                Session::native(m.clone())
                    .expect("native session construction succeeded for the probe")
            });
            serve_on_pool(
                args,
                default_requests,
                &pool,
                &digest,
                rargs,
                "native measured",
                &[],
            )
        }
        Backend::Pjrt => Err(marrow::Error::Usage(
            "serve supports --backend sim or native (pjrt sessions borrow \
             their runtime and cannot be pooled from the CLI)"
                .into(),
        )),
    }
}

/// `marrow serve --replay <trace.json>` (DESIGN.md §2.13): re-drain a
/// recorded request mix — arrival offsets, workload names/sizes, deadlines,
/// priorities, the run's ExecProfile-bearing ServeOpts, and the background
/// CPU-load schedule all come from the trace. Replays are deterministic in
/// virtual time: same trace + same starting KB → bit-identical virtual
/// makespan and batch shapes (wall-clock latencies still vary with the
/// host).
fn replay_cmd(args: &Args, path: &Path) -> Result<()> {
    match pick_backend(args)? {
        Backend::Sim => {}
        _ => {
            return Err(marrow::Error::Usage(
                "--replay drains on --backend sim (virtual-time determinism)"
                    .into(),
            ))
        }
    }
    let trace = ReplayTrace::parse(&std::fs::read_to_string(path)?)?;
    let machine = pick_machine(args)?;
    let load = LoadProfile::new(trace.load.clone());
    let concurrency = trace.opts.concurrency.max(1);
    let pool = SessionPool::build(concurrency, |i| {
        Session::sim(
            SimMachine::new(machine.clone(), 11 + i as u64).with_load(load.clone()),
        )
    });
    // A warm KB changes admission estimates, so the starting KB is part of
    // the replay contract: fresh by default, or pinned with --kb.
    if let Some(p) = args.get("kb") {
        *pool.shared_kb().write().unwrap() = KnowledgeBase::open(&PathBuf::from(p))?;
    }
    let requests: Vec<ServeRequest> = trace
        .requests
        .iter()
        .map(|r| {
            let b = benchmark_by_name(&r.bench, r.size)?;
            let mut req = ServeRequest::from(Computation::from(b))
                .with_arrival_offset(r.offset)
                .with_priority(r.priority);
            // Explicit deadlines travel with the request; defaulted ones
            // re-resolve from the recorded opts' deadline_default.
            req.deadline = r.replay_deadline();
            Ok(req)
        })
        .collect::<Result<Vec<_>>>()?;
    println!(
        "replaying {}: {} requests at concurrency {concurrency}, {} load \
         steps, exec profile {}",
        path.display(),
        requests.len(),
        trace.load.len(),
        trace.opts.exec.to_json().to_string()
    );
    let report = pool.serve(&requests, &trace.opts)?;
    println!("{}", report.summary());
    println!(
        "virtual makespan: {:.6} s (deterministic across replays of this \
         trace)",
        report.virtual_makespan
    );
    Ok(())
}

/// The serve path over an already-built pool, generic over the backend.
fn serve_on_pool<E: ExecEnv + Send>(
    args: &Args,
    default_requests: u64,
    pool: &SessionPool<E>,
    kb_digest: &str,
    rargs: RequestArgs,
    clock: &str,
    load: &[(u64, u32)],
) -> Result<()> {
    let b = pick_benchmark(args)?;
    let n_requests = args.get_u64("requests", default_requests)? as usize;
    let concurrency = (args.get_u64("concurrency", 4)? as usize).max(1);
    let pace = args.get_f64("pace-ms", 2.0)? * 1e-3;
    // All execution knobs resolve through one ExecProfile (DESIGN.md
    // §2.13), applied pool-wide via ServeOpts and recorded verbatim into
    // replay traces.
    let exec = ExecProfile::from_args(args)?;
    let co_schedule = args.has("co-schedule");
    // Batching & fusion knobs (DESIGN.md §2.10): --batch-max > 1 lets a
    // worker coalesce consecutive compatible requests into one fused
    // drain; --batch-window bounds the fusion-induced stretch the oldest
    // member absorbs; --deadline-default attaches an SLO to requests that
    // carry none (reported as deadline misses when overrun).
    let batch_max = (args.get_u64("batch-max", 1)? as usize).max(1);
    let batch_window = args.get_f64("batch-window", 2.0)? * 1e-3;
    let deadline_default = match args.get("deadline-default") {
        Some(_) => Some(args.get_f64("deadline-default", 0.0)? * 1e-3),
        None => None,
    };
    let name = b.name.clone();
    let comp = Computation::from(b);
    let kb_store_dir = args.get("kb-store").map(PathBuf::from);
    if args.get("kb").is_some() && kb_store_dir.is_some() {
        return Err(marrow::Error::Usage(
            "--kb and --kb-store are mutually exclusive".into(),
        ));
    }
    // Mid-stream store flushes only make sense with a store backing.
    let store_sync_every = if kb_store_dir.is_some() {
        args.get_u64("store-sync-every", 16)? as usize
    } else {
        0
    };

    if let Some(path) = args.get("kb") {
        *pool.shared_kb().write().unwrap() = KnowledgeBase::open(&PathBuf::from(path))?;
    }
    if let Some(dir) = &kb_store_dir {
        *pool.shared_kb().write().unwrap() = KnowledgeBase::open_store(dir, kb_digest)?;
    }
    if let Some(snap_path) = args.get("import") {
        // Warm-start a fleet member: records matching this platform's
        // digest become exact KB entries, the rest derivation hints.
        let snap = KbSnapshot::read(&PathBuf::from(snap_path))?;
        let kb = pool.shared_kb();
        let mut kb = kb.write().unwrap();
        kb.ensure_manifest_digest(kb_digest);
        let (exact, hints) = kb.import_snapshot(&snap);
        println!(
            "imported {snap_path}: {exact} exact profiles, {hints} derivation hints"
        );
    }

    // --arrival-gap-ms spaces request arrivals (offset i*gap from stream
    // start): batches close across gaps wider than the batch window, and
    // recorded traces replay the same spacing deterministically.
    let arrival_gap = args.get_f64("arrival-gap-ms", 0.0)? * 1e-3;
    let requests: Vec<ServeRequest> = (0..n_requests)
        .map(|i| {
            let mut r = ServeRequest::from(comp.clone())
                .with_arrival_offset(i as f64 * arrival_gap);
            r.args = rargs.clone();
            r
        })
        .collect();
    println!(
        "serving {n_requests} x {name} at concurrency {concurrency} \
         (pace floor {:.1} ms/request, {clock}, {} admission)",
        pace * 1e3,
        if co_schedule {
            "co-scheduled"
        } else {
            "whole-pool"
        }
    );
    if batch_max > 1 {
        println!(
            "batching: up to {batch_max} requests/batch, {:.1} ms window{}",
            batch_window * 1e3,
            match deadline_default {
                Some(d) => format!(", {:.1} ms default deadline", d * 1e3),
                None => String::new(),
            }
        );
    }
    let opts = ServeOpts {
        concurrency,
        pace,
        exec,
        co_schedule,
        store_sync_every,
        batch_max,
        batch_window,
        deadline_default,
        ..Default::default()
    };
    let report = pool.serve(&requests, &opts)?;
    println!("{}", report.summary());
    if let Some(out) = args.get("record") {
        // A replayable trace of this run: the request mix (names, sizes,
        // arrival offsets, deadlines, priorities), the serve options with
        // their ExecProfile, and the background load schedule.
        let bench_key = args.get_or("bench", "saxpy");
        let size = args.get_u64("size", 10_000_000)?;
        let trace = ReplayTrace {
            opts: opts.clone(),
            load: load.to_vec(),
            requests: requests
                .iter()
                .map(|r| RecordedRequest {
                    bench: bench_key.clone(),
                    size,
                    offset: r.arrival_offset,
                    deadline: r.deadline,
                    deadline_explicit: r.deadline.is_some(),
                    priority: r.priority,
                })
                .collect(),
        };
        std::fs::write(out, trace.to_json().to_string_pretty())?;
        println!(
            "recorded replay trace: {} requests -> {out} (marrow serve \
             --replay {out})",
            requests.len()
        );
    }
    println!(
        "kb provenance: {} exact hits ({} warm-started), {} derived, \
         {} cold-built ({:.2}s building)",
        report.stats.kb_hits,
        report.stats.warm_hits,
        report.stats.derived,
        report.stats.built,
        report.stats.build_secs
    );
    if co_schedule {
        let mut counts: std::collections::BTreeMap<String, usize> = Default::default();
        for t in &report.traces {
            if let Some(m) = &t.mask {
                *counts.entry(m.label()).or_default() += 1;
            }
        }
        let placements: Vec<String> =
            counts.into_iter().map(|(m, n)| format!("{m} x{n}")).collect();
        println!(
            "placements: {} (virtual device-time {:.1} req/s)",
            placements.join(", "),
            report.virtual_req_per_sec()
        );
    }
    if args.get("kb").is_some() || kb_store_dir.is_some() {
        let kb = pool.shared_kb();
        let mut kb = kb.write().unwrap();
        kb.save()?;
        if kb.store_backed() {
            println!(
                "kb store persisted: epoch {}, {} profiles, {} derivation hints",
                kb.store_epoch().unwrap_or(0),
                kb.len(),
                kb.hint_count()
            );
        } else {
            println!("knowledge base persisted ({} profiles)", kb.len());
        }
    }
    Ok(())
}

/// Load profile records from `path` for `kb import|merge`: a KB store
/// directory, a snapshot file, or a legacy single-file `KnowledgeBase`
/// JSON (whose entries are absorbed under `digest`, since the legacy
/// format predates platform provenance).
fn load_snapshot(path: &Path, digest: &str) -> Result<KbSnapshot> {
    if path.is_dir() {
        return Ok(KbSnapshot::from_store(&KbStore::open(path, digest)?));
    }
    let text = std::fs::read_to_string(path)?;
    if let Ok(snap) = KbSnapshot::parse(&text) {
        return Ok(snap);
    }
    let mut kb = KnowledgeBase::open(path)?;
    kb.ensure_manifest_digest(digest);
    Ok(kb.export_snapshot())
}

/// `marrow kb <export|import|merge|stats|gc>` — fleet-level operations on
/// a durable content-addressed KB store (DESIGN.md §2.9), no session
/// required. The platform digest for legacy imports and the stats
/// this-machine marker follows `--gpus` like every other subcommand.
fn kb_cmd(args: &Args) -> Result<()> {
    let action = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("stats");
    let store_dir = args.get("store").map(PathBuf::from).ok_or_else(|| {
        marrow::Error::Usage("kb commands need --store <dir>".into())
    })?;
    let digest = machine_digest("analytic", &pick_machine(args)?);
    match action {
        "export" => {
            let store = KbStore::open(&store_dir, &digest)?;
            let out = args
                .get("out")
                .map(PathBuf::from)
                .unwrap_or_else(|| PathBuf::from("kb-snapshot.json"));
            let snap = KbSnapshot::from_store(&store);
            snap.write(&out)?;
            println!(
                "exported {} profiles ({} platform digests) to {}",
                snap.len(),
                snap.manifest_digests().len(),
                out.display()
            );
        }
        "import" | "merge" => {
            let from = args.get("from").ok_or_else(|| {
                marrow::Error::Usage(format!(
                    "kb {action} needs --from <store dir|snapshot|legacy kb json>"
                ))
            })?;
            let snap = load_snapshot(&PathBuf::from(from), &digest)?;
            let mut store = KbStore::open(&store_dir, &digest)?;
            let folded = snap.merge_into(&mut store);
            store.flush()?;
            println!(
                "merged {folded} of {} records into {} (epoch {})",
                snap.len(),
                store_dir.display(),
                store.epoch()
            );
        }
        "stats" => {
            let store = KbStore::open(&store_dir, &digest)?;
            let st = store.stats();
            println!(
                "kb store {}: {} records in {} segments, epoch {}",
                store_dir.display(),
                st.records,
                st.segments,
                st.epoch
            );
            for (origin, n) in &st.origins {
                println!("  origin   {origin:<8} x{n}");
            }
            for (d, n) in &st.digests {
                let mark = if *d == digest { " (this machine)" } else { "" };
                println!("  platform {}..{mark} x{n}", &d[..12.min(d.len())]);
            }
        }
        "gc" => {
            let mut store = KbStore::open(&store_dir, &digest)?;
            let (live, removed) = store.gc()?;
            println!(
                "compacted to one segment: {live} live records, \
                 {removed} old segments removed"
            );
        }
        other => {
            return Err(marrow::Error::Usage(format!(
                "unknown kb action '{other}' (export|import|merge|stats|gc)"
            )))
        }
    }
    Ok(())
}

/// Dump the dataflow TaskGraph of a benchmark as GraphViz DOT (stderr gets
/// a shape summary; stdout is pipeable into `dot -Tsvg`). The framework
/// configuration is resolved through the same KB chain `marrow run` uses
/// (honouring `--kb`), so the dumped schedule is the one a run would
/// actually execute — not a hardcoded baseline.
fn graph_cmd(args: &Args) -> Result<()> {
    use marrow::decompose::graph::NodeKind;
    let b = pick_benchmark(args)?;
    let name = b.name.clone();
    let machine = pick_machine(args)?;
    let exec = ExecProfile::from_args(args)?;
    let tasks_per_slot = exec.tasks_per_slot.unwrap_or(4);
    let comp = Computation::from(b);
    let session = sim_session(args, machine.clone(), 11)?;
    let (cfg, origin) = session.resolve_config(&comp, &RequestArgs::default())?;
    let (sct, _, units) = comp.spec()?;
    let p = marrow::scheduler::plan(&machine, sct, units, &cfg, 1)?;
    let stages = flatten_stages(sct)?;
    let labels: Vec<String> = stages.iter().map(|s| s.label()).collect();
    let g = build_graph(&stages, &p, tasks_per_slot)?;
    eprintln!(
        "# {}: {} nodes ({} sync) over {} stages, {} chunks in stage 0 \
         (config {}: GPU {:.1}% / CPU {:.1}%)",
        name,
        g.n_nodes(),
        g.nodes.iter().filter(|n| n.kind == NodeKind::Sync).count(),
        g.n_stages,
        g.nodes.iter().filter(|n| n.stage == 0).count(),
        origin.label(),
        100.0 * cfg.gpu_share(),
        100.0 * cfg.cpu_share
    );
    let prefetch_depth = exec.prefetch_depth.unwrap_or(0);
    println!("{}", g.to_dot_with_prefetch(&labels, prefetch_depth));
    Ok(())
}

fn shoc_cmd() -> Result<()> {
    println!("host calibration (real measurements on this machine):");
    println!(
        "  f32 FMA throughput : {:.2} GFLOPS/core",
        shoc::host_flops_gflops()
    );
    println!(
        "  stream bandwidth   : {:.2} GB/s",
        shoc::host_stream_gbps()
    );
    let mut gpus = i7_hd7950(2).gpus;
    let w = shoc::rank_gpus(&mut gpus);
    println!("simulated GPU ranking (SHOC-score weights): {w:?}");
    Ok(())
}

fn info() -> Result<()> {
    for m in [opteron_6272_quad(), i7_hd7950(2)] {
        println!(
            "machine: {} — {} cores, {} GPUs",
            m.name,
            m.cpu.total_cores(),
            m.gpus.len()
        );
    }
    match Manifest::load_default() {
        Ok(man) => {
            println!("artifacts ({} families):", man.by_family.len());
            for (fam, arts) in &man.by_family {
                let chunks: Vec<u64> = arts.iter().map(|a| a.chunk_units).collect();
                println!("  {fam:<18} chunk menu {chunks:?}");
            }
        }
        Err(e) => println!("artifacts: not built ({e})"),
    }
    let native = marrow::runtime::native::builtin_manifest();
    println!("native kernels ({} families, built-in):", native.by_family.len());
    for (fam, arts) in &native.by_family {
        let chunks: Vec<u64> = arts.iter().map(|a| a.chunk_units).collect();
        println!("  {fam:<18} chunk menu {chunks:?}");
    }
    Ok(())
}
