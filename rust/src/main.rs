//! marrow — CLI launcher for the Marrow reproduction.
//!
//! Subcommands:
//!   eval <table2|table3|table4|table5|fig11|ablations|all>
//!       regenerate the paper's tables/figures (simulated clock)
//!   profile --bench <name> --size <n> [--gpus <g>] [--kb <path>]
//!       run Algorithm 1 on one benchmark through a Session and print the
//!       profile (persisted when --kb is given)
//!   run --bench <name> --size <n> [--gpus <g>] [--runs <r>] [--kb <path>]
//!       [--concurrency <c>]
//!       repeated Session::run requests: KB lookup -> derive -> build chain,
//!       execution monitoring and adaptive rebalancing, per-run trace
//!       (with --concurrency > 1 the requests drain through a session pool)
//!   serve --bench <name> --size <n> [--requests <r>] [--concurrency <c>]
//!       [--pace-ms <m>] [--kb <path>] [--co-schedule]
//!       multi-request serve path: a pool of sessions over one shared KB
//!       drains the request stream under the admission cap; reports
//!       requests/sec and p50/p99 latency. With --co-schedule each request
//!       is admitted onto the KB-cost-priced device subset minimizing its
//!       predicted completion (DESIGN.md 2.8) instead of time-sharing the
//!       whole pool
//!   graph --bench <name> --size <n> [--gpus <g>] [--tasks-per-slot <t>]
//!       dump the benchmark's dataflow TaskGraph as GraphViz DOT (nodes
//!       labelled stage/chunk/slot, sync nodes highlighted)
//!   shoc
//!       install-time calibration: host microbenchmarks + GPU ranking
//!   info
//!       machine descriptions and artifact inventory
//!
//! `run` and `serve` accept `--drain <barrier|dataflow>` to pin the drain
//! mode (default dataflow; barrier is the A/B baseline).

use std::path::PathBuf;

use marrow::bench::eval::{ablations, fig11, table2, table3, table4, table5};
use marrow::bench::workloads::{self, Benchmark};
use marrow::cli::Args;
use marrow::kb::KnowledgeBase;
use marrow::platform::device::{i7_hd7950, opteron_6272_quad, Machine};
use marrow::decompose::graph::{build_graph, flatten_stages};
use marrow::runtime::artifacts::Manifest;
use marrow::runtime::exec::RequestArgs;
use marrow::scheduler::DrainMode;
use marrow::session::serve::{ServeOpts, ServeRequest, SessionPool};
use marrow::session::{Computation, Session};
use marrow::sim::shoc;
use marrow::Result;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env();
    match args.command.as_deref() {
        Some("eval") => eval(&args),
        Some("profile") => profile(&args),
        Some("run") => run_cmd(&args),
        Some("serve") => serve_cmd(&args),
        Some("graph") => graph_cmd(&args),
        Some("shoc") => shoc_cmd(),
        Some("info") => info(),
        _ => {
            println!("{}", USAGE);
            Ok(())
        }
    }
}

const USAGE: &str = "\
marrow — multi-CPU/multi-GPU execution of compound multi-kernel computations
usage:
  marrow eval <table2|table3|table4|table5|fig11|ablations|all>
  marrow profile --bench <saxpy|filter|fft|nbody|segmentation> --size <n> [--gpus <g>] [--kb <path>]
  marrow run --bench <saxpy|filter|fft|nbody|segmentation> --size <n> [--gpus <g>] [--runs <r>] [--kb <path>] [--concurrency <c>] [--tasks-per-slot <t>] [--drain <barrier|dataflow>]
  marrow serve --bench <saxpy|filter|fft|nbody|segmentation> --size <n> [--requests <r>] [--concurrency <c>] [--pace-ms <m>] [--kb <path>] [--tasks-per-slot <t>] [--drain <barrier|dataflow>] [--co-schedule]
  marrow graph --bench <saxpy|filter|fft|nbody|segmentation> --size <n> [--gpus <g>] [--tasks-per-slot <t>] [--kb <path>]
  marrow shoc
  marrow info";

fn eval(args: &Args) -> Result<()> {
    let what = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let all = what == "all";
    if all || what == "table2" {
        println!("{}", table2::report()?);
    }
    if all || what == "table3" {
        println!("{}", table3::report()?);
    }
    if all || what == "table4" {
        println!("{}", table4::report(table4::RUNS)?);
    }
    if all || what == "table5" {
        println!("{}", table5::report()?);
    }
    if all || what == "fig11" {
        println!("{}", fig11::report()?);
    }
    if all || what == "ablations" {
        println!("{}", ablations::discard_ordering()?);
        println!("{}", ablations::locality()?);
        println!("{}", ablations::interpolation()?);
    }
    Ok(())
}

fn pick_benchmark(args: &Args) -> Result<Benchmark> {
    let bench = args.get_or("bench", "saxpy");
    let size = args.get_u64("size", 10_000_000)?;
    match bench.as_str() {
        "saxpy" => Ok(workloads::saxpy(size)),
        "filter" => Ok(workloads::filter_pipeline(size, size, true)),
        "fft" => Ok(workloads::fft(size)),
        "nbody" => Ok(workloads::nbody(size, 20)),
        "segmentation" => Ok(workloads::segmentation(size)),
        other => Err(marrow::Error::Usage(format!("unknown benchmark '{other}'"))),
    }
}

fn pick_machine(args: &Args) -> Result<Machine> {
    let gpus = args.get_u64("gpus", 1)? as usize;
    Ok(if gpus == 0 {
        opteron_6272_quad()
    } else {
        i7_hd7950(gpus)
    })
}

/// Optional `--tasks-per-slot` (steal-slack knob; backend default when
/// absent).
fn pick_tasks_per_slot(args: &Args) -> Result<Option<u32>> {
    Ok(match args.get("tasks-per-slot") {
        None => None,
        Some(_) => Some(args.get_u64("tasks-per-slot", 4)?.max(1) as u32),
    })
}

/// Optional `--drain <barrier|dataflow>` (backend default — dataflow —
/// when absent).
fn pick_drain_mode(args: &Args) -> Result<Option<DrainMode>> {
    match args.get("drain") {
        None => Ok(None),
        Some(s) => DrainMode::parse(s).map(Some).ok_or_else(|| {
            marrow::Error::Usage(format!(
                "--drain expects 'barrier' or 'dataflow', got '{s}'"
            ))
        }),
    }
}

/// Build a simulated session honouring the optional `--kb <path>` flag.
fn sim_session(
    args: &Args,
    machine: Machine,
    seed: u64,
) -> Result<Session<marrow::scheduler::SimEnv>> {
    let s = Session::simulated(machine, seed);
    match args.get("kb") {
        Some(path) => s.with_kb_path(&PathBuf::from(path)),
        None => Ok(s),
    }
}

fn profile(args: &Args) -> Result<()> {
    let b = pick_benchmark(args)?;
    let name = b.name.clone();
    let comp = Computation::from(b);
    let session = sim_session(args, pick_machine(args)?, 7)?;
    let p = session.profile(&comp)?;
    session.save_kb()?;
    println!("benchmark      : {}", name);
    println!("sct id         : {}", p.sct_id);
    println!("workload       : {}", p.workload.id());
    println!(
        "configuration  : fission={} overlap={:?} wgs={}",
        p.config.fission.label(),
        p.config.overlap,
        p.config.wgs
    );
    println!(
        "distribution   : GPU {:.1}% / CPU {:.1}%",
        100.0 * p.config.gpu_share(),
        100.0 * p.config.cpu_share
    );
    println!("best time (sim): {:.4} s", p.best_time);
    Ok(())
}

/// The seamless path, observable: repeated `Session::run` requests with the
/// per-run configuration origin and the balancer's refinements.
fn run_cmd(args: &Args) -> Result<()> {
    let b = pick_benchmark(args)?;
    let runs = args.get_u64("runs", 8)?;
    let concurrency = args.get_u64("concurrency", 1)? as usize;
    if concurrency > 1 {
        // Concurrent requests drain through the serve path, keeping run's
        // own request-count default (8 runs, not serve's 32).
        return serve_requests(args, runs);
    }
    let name = b.name.clone();
    let comp = Computation::from(b);
    let session = sim_session(args, pick_machine(args)?, 11)?;
    if let Some(t) = pick_tasks_per_slot(args)? {
        session.set_tasks_per_slot(t);
    }
    let drain = pick_drain_mode(args)?.unwrap_or_default();
    session.set_drain_mode(drain);
    println!(
        "benchmark: {name} ({} runs, simulated clock, {} drain)",
        runs,
        drain.label()
    );
    println!(" run | origin  | GPU share | exec time | idle% | balanced?");
    println!("-----+---------+-----------+-----------+-------+----------");
    for run in 0..runs {
        let out = session.run(&comp, &RequestArgs::default())?;
        println!(
            " {run:>3} | {:<7} |   {:>5.1}%  | {:>7.3}ms | {:>4.1}% | {}",
            out.origin.label(),
            100.0 * out.config.gpu_share(),
            out.exec.total * 1e3,
            100.0 * out.exec.mean_idle_frac(),
            if out.rebalanced {
                "rebalanced"
            } else if out.unbalanced {
                "no"
            } else {
                "yes"
            },
        );
    }
    let st = session.stats();
    println!(
        "\n{} runs: {} kb hits, {} derived, {} built, {} balance ops",
        st.runs, st.kb_hits, st.derived, st.built, st.balance_ops
    );
    println!(
        "transfers: {:.1} MB uploaded, {:.1} MB downloaded, {} uploads \
         avoided, {} steal migrations; mean slot idle {:.1}%",
        st.bytes_uploaded as f64 / 1e6,
        st.bytes_downloaded as f64 / 1e6,
        st.uploads_avoided,
        st.steal_migrations,
        st.mean_idle_pct()
    );
    session.save_kb()?;
    if args.get("kb").is_some() {
        println!("knowledge base persisted ({} profiles)", session.kb().len());
    }
    Ok(())
}

/// The multi-request serve path: drain a request stream through a pool of
/// simulated sessions sharing one knowledge base.
fn serve_cmd(args: &Args) -> Result<()> {
    serve_requests(args, args.get_u64("runs", 32)?)
}

/// Serve with an explicit request-count default (`marrow run --concurrency`
/// delegates here with run's default of 8).
fn serve_requests(args: &Args, default_requests: u64) -> Result<()> {
    let b = pick_benchmark(args)?;
    let n_requests = args.get_u64("requests", default_requests)? as usize;
    let concurrency = (args.get_u64("concurrency", 4)? as usize).max(1);
    let pace = args.get_f64("pace-ms", 2.0)? * 1e-3;
    let tasks_per_slot = pick_tasks_per_slot(args)?;
    let drain_mode = pick_drain_mode(args)?;
    let co_schedule = args.has("co-schedule");
    let name = b.name.clone();
    let comp = Computation::from(b);
    let machine = pick_machine(args)?;

    let pool = SessionPool::build(concurrency, |i| {
        Session::simulated(machine.clone(), 11 + i as u64)
    });
    if let Some(path) = args.get("kb") {
        *pool.shared_kb().write().unwrap() = KnowledgeBase::open(&PathBuf::from(path))?;
    }

    let requests: Vec<ServeRequest> = (0..n_requests)
        .map(|_| ServeRequest::from(comp.clone()))
        .collect();
    println!(
        "serving {n_requests} x {name} at concurrency {concurrency} \
         (pace floor {:.1} ms/request, simulated clock, {} admission)",
        pace * 1e3,
        if co_schedule {
            "co-scheduled"
        } else {
            "whole-pool"
        }
    );
    let report = pool.serve(
        &requests,
        &ServeOpts {
            concurrency,
            pace,
            tasks_per_slot,
            drain_mode,
            co_schedule,
        },
    )?;
    println!("{}", report.summary());
    if co_schedule {
        let mut counts: std::collections::BTreeMap<String, usize> = Default::default();
        for t in &report.traces {
            if let Some(m) = &t.mask {
                *counts.entry(m.label()).or_default() += 1;
            }
        }
        let placements: Vec<String> =
            counts.into_iter().map(|(m, n)| format!("{m} x{n}")).collect();
        println!(
            "placements: {} (virtual device-time {:.1} req/s)",
            placements.join(", "),
            report.virtual_req_per_sec()
        );
    }
    if args.get("kb").is_some() {
        let kb = pool.shared_kb();
        let kb = kb.read().unwrap();
        kb.save()?;
        println!("knowledge base persisted ({} profiles)", kb.len());
    }
    Ok(())
}

/// Dump the dataflow TaskGraph of a benchmark as GraphViz DOT (stderr gets
/// a shape summary; stdout is pipeable into `dot -Tsvg`). The framework
/// configuration is resolved through the same KB chain `marrow run` uses
/// (honouring `--kb`), so the dumped schedule is the one a run would
/// actually execute — not a hardcoded baseline.
fn graph_cmd(args: &Args) -> Result<()> {
    use marrow::decompose::graph::NodeKind;
    let b = pick_benchmark(args)?;
    let name = b.name.clone();
    let machine = pick_machine(args)?;
    let tasks_per_slot = pick_tasks_per_slot(args)?.unwrap_or(4);
    let comp = Computation::from(b);
    let session = sim_session(args, machine.clone(), 11)?;
    let (cfg, origin) = session.resolve_config(&comp, &RequestArgs::default())?;
    let (sct, _, units) = comp.spec()?;
    let p = marrow::scheduler::plan(&machine, sct, units, &cfg, 1)?;
    let stages = flatten_stages(sct)?;
    let labels: Vec<String> = stages.iter().map(|s| s.label()).collect();
    let g = build_graph(&stages, &p, tasks_per_slot)?;
    eprintln!(
        "# {}: {} nodes ({} sync) over {} stages, {} chunks in stage 0 \
         (config {}: GPU {:.1}% / CPU {:.1}%)",
        name,
        g.n_nodes(),
        g.nodes.iter().filter(|n| n.kind == NodeKind::Sync).count(),
        g.n_stages,
        g.nodes.iter().filter(|n| n.stage == 0).count(),
        origin.label(),
        100.0 * cfg.gpu_share(),
        100.0 * cfg.cpu_share
    );
    println!("{}", g.to_dot(&labels));
    Ok(())
}

fn shoc_cmd() -> Result<()> {
    println!("host calibration (real measurements on this machine):");
    println!(
        "  f32 FMA throughput : {:.2} GFLOPS/core",
        shoc::host_flops_gflops()
    );
    println!(
        "  stream bandwidth   : {:.2} GB/s",
        shoc::host_stream_gbps()
    );
    let mut gpus = i7_hd7950(2).gpus;
    let w = shoc::rank_gpus(&mut gpus);
    println!("simulated GPU ranking (SHOC-score weights): {w:?}");
    Ok(())
}

fn info() -> Result<()> {
    for m in [opteron_6272_quad(), i7_hd7950(2)] {
        println!(
            "machine: {} — {} cores, {} GPUs",
            m.name,
            m.cpu.total_cores(),
            m.gpus.len()
        );
    }
    match Manifest::load_default() {
        Ok(man) => {
            println!("artifacts ({} families):", man.by_family.len());
            for (fam, arts) in &man.by_family {
                let chunks: Vec<u64> = arts.iter().map(|a| a.chunk_units).collect();
                println!("  {fam:<18} chunk menu {chunks:?}");
            }
        }
        Err(e) => println!("artifacts: not built ({e})"),
    }
    Ok(())
}
