//! marrow — CLI launcher for the Marrow reproduction.
//!
//! Subcommands:
//!   eval <table2|table3|table4|table5|fig11|ablations|all>
//!       regenerate the paper's tables/figures (simulated clock)
//!   profile --bench <name> --size <n> [--gpus <g>]
//!       run Algorithm 1 on one benchmark and print the profile
//!   shoc
//!       install-time calibration: host microbenchmarks + GPU ranking
//!   info
//!       machine descriptions and artifact inventory

use marrow::bench::eval::{ablations, fig11, table2, table3, table4, table5};
use marrow::bench::workloads;
use marrow::cli::Args;
use marrow::platform::device::{i7_hd7950, opteron_6272_quad};
use marrow::runtime::artifacts::Manifest;
use marrow::scheduler::SimEnv;
use marrow::sim::machine::SimMachine;
use marrow::sim::shoc;
use marrow::tuner::builder::{build_profile, TunerOpts};
use marrow::Result;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env();
    match args.command.as_deref() {
        Some("eval") => eval(&args),
        Some("profile") => profile(&args),
        Some("shoc") => shoc_cmd(),
        Some("info") => info(),
        _ => {
            println!("{}", USAGE);
            Ok(())
        }
    }
}

const USAGE: &str = "\
marrow — multi-CPU/multi-GPU execution of compound multi-kernel computations
usage:
  marrow eval <table2|table3|table4|table5|fig11|ablations|all>
  marrow profile --bench <saxpy|filter|fft|nbody|segmentation> --size <n> [--gpus <g>]
  marrow shoc
  marrow info";

fn eval(args: &Args) -> Result<()> {
    let what = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let all = what == "all";
    if all || what == "table2" {
        println!("{}", table2::report()?);
    }
    if all || what == "table3" {
        println!("{}", table3::report()?);
    }
    if all || what == "table4" {
        println!("{}", table4::report(table4::RUNS)?);
    }
    if all || what == "table5" {
        println!("{}", table5::report()?);
    }
    if all || what == "fig11" {
        println!("{}", fig11::report()?);
    }
    if all || what == "ablations" {
        println!("{}", ablations::discard_ordering()?);
        println!("{}", ablations::locality()?);
        println!("{}", ablations::interpolation()?);
    }
    Ok(())
}

fn profile(args: &Args) -> Result<()> {
    let bench = args.get_or("bench", "saxpy");
    let size = args.get_u64("size", 10_000_000)?;
    let gpus = args.get_u64("gpus", 1)? as usize;
    let b = match bench.as_str() {
        "saxpy" => workloads::saxpy(size),
        "filter" => workloads::filter_pipeline(size, size, true),
        "fft" => workloads::fft(size),
        "nbody" => workloads::nbody(size, 20),
        "segmentation" => workloads::segmentation(size),
        other => {
            return Err(marrow::Error::Usage(format!(
                "unknown benchmark '{other}'"
            )))
        }
    };
    let machine = if gpus == 0 {
        opteron_6272_quad()
    } else {
        i7_hd7950(gpus)
    };
    let mut env = SimEnv::new(SimMachine::new(machine, 7));
    env.copy_bytes = b.copy_bytes;
    let p = build_profile(
        &mut env,
        &b.sct,
        &b.workload,
        b.total_units,
        &TunerOpts::default(),
    )?;
    println!("benchmark      : {}", b.name);
    println!("sct id         : {}", p.sct_id);
    println!("workload       : {}", p.workload.id());
    println!(
        "configuration  : fission={} overlap={:?} wgs={}",
        p.config.fission.label(),
        p.config.overlap,
        p.config.wgs
    );
    println!(
        "distribution   : GPU {:.1}% / CPU {:.1}%",
        100.0 * p.config.gpu_share(),
        100.0 * p.config.cpu_share
    );
    println!("best time (sim): {:.4} s", p.best_time);
    Ok(())
}

fn shoc_cmd() -> Result<()> {
    println!("host calibration (real measurements on this machine):");
    println!(
        "  f32 FMA throughput : {:.2} GFLOPS/core",
        shoc::host_flops_gflops()
    );
    println!(
        "  stream bandwidth   : {:.2} GB/s",
        shoc::host_stream_gbps()
    );
    let mut gpus = i7_hd7950(2).gpus;
    let w = shoc::rank_gpus(&mut gpus);
    println!("simulated GPU ranking (SHOC-score weights): {w:?}");
    Ok(())
}

fn info() -> Result<()> {
    for m in [opteron_6272_quad(), i7_hd7950(2)] {
        println!(
            "machine: {} — {} cores, {} GPUs",
            m.name,
            m.cpu.total_cores(),
            m.gpus.len()
        );
    }
    match Manifest::load_default() {
        Ok(man) => {
            println!("artifacts ({} families):", man.by_family.len());
            for (fam, arts) in &man.by_family {
                let chunks: Vec<u64> = arts.iter().map(|a| a.chunk_units).collect();
                println!("  {fam:<18} chunk menu {chunks:?}");
            }
        }
        Err(e) => println!("artifacts: not built ({e})"),
    }
    Ok(())
}
