//! Minimal JSON substrate (parser + serializer).
//!
//! Used for the artifact manifest (`artifacts/manifest.json`, written by the
//! Python AOT pipeline) and for knowledge-base persistence. Implements the
//! full JSON grammar except `\u` surrogate pairs beyond the BMP; numbers are
//! kept as f64 (adequate: all persisted quantities are counts and ratios).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A JSON value. Object keys are sorted (BTreeMap) so serialization is
/// deterministic — important for artifact hashing and golden tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // --- typed accessors -----------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0).map(|n| n as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` with a descriptive error.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()
            .and_then(|o| o.get(key))
            .ok_or_else(|| Error::Json {
                offset: 0,
                msg: format!("missing key '{key}'"),
            })
    }

    // --- constructors ----------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    // --- serialization ----------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    push_indent(out, indent + 1);
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push(']');
            }
            Json::Obj(o) if !o.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    push_indent(out, indent + 1);
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn push_indent(out: &mut String, n: usize) {
    for _ in 0..n {
        out.push_str("  ");
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 9e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json {
            offset: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.pos;
                    let b = self.bytes[start];
                    let len = match b {
                        0x00..=0x7F => 1,
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(1.0));
        assert_eq!(a[2].get("b").unwrap(), &Json::Null);
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s",true,null],"obj":{"k":-3}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = Json::obj(vec![
            ("x", Json::num(1.0)),
            ("y", Json::arr(vec![Json::str("a"), Json::Bool(false)])),
        ]);
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""Aé""#).unwrap(),
            Json::Str("Aé".to_string())
        );
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo — 世界\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo — 世界"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"format": 1, "artifacts": [{"name": "saxpy_n4096",
            "file": "saxpy_n4096.hlo.txt", "chunk_units": 4096,
            "flops": 8192, "bytes": 49152, "family": "saxpy",
            "inputs": [{"name": "alpha", "shape": [1], "dtype": "f32"}],
            "outputs": [{"name": "out", "shape": [4096], "dtype": "f32"}]}]}"#;
        let v = Json::parse(src).unwrap();
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("chunk_units").unwrap().as_u64(), Some(4096));
    }
}
