//! Self-contained substrates: JSON, RNG, statistics, dense linear algebra,
//! SHA-256 hashing, crash-safe file IO and a property-testing
//! mini-framework.
//!
//! The build environment resolves crates offline from a fixed vendor set that
//! does not include serde/rand/nalgebra/proptest, so the paper's
//! infrastructure needs (knowledge-base persistence, stochastic simulation,
//! RBF interpolation, invariant testing) are implemented here from scratch.

pub mod fsio;
pub mod hash;
pub mod json;
pub mod linalg;
pub mod propcheck;
pub mod rng;
pub mod stats;
