//! Property-based testing mini-framework (proptest is unavailable offline).
//!
//! `forall(seed, cases, gen, prop)` runs `prop` against `cases` random values
//! from `gen`; on failure it performs greedy shrinking via the value's
//! [`Shrink`] implementation and panics with the minimal counterexample.
//! Used by the decomposition / tuner / balancer invariant tests.

use crate::util::rng::Rng;

/// Types that can propose smaller versions of themselves for shrinking.
pub trait Shrink: Sized + Clone + std::fmt::Debug {
    /// Candidate strictly-smaller values, in decreasing order of aggression.
    fn shrink(&self) -> Vec<Self>;
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<u64> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<usize> {
        (*self as u64).shrink().into_iter().map(|v| v as usize).collect()
    }
}

impl Shrink for f64 {
    fn shrink(&self) -> Vec<f64> {
        let mut out = Vec::new();
        if *self != 0.0 {
            out.push(0.0);
            out.push(self / 2.0);
            out.push(self.trunc());
        }
        out.retain(|v| v != self);
        out
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Vec<T>> {
        let mut out = Vec::new();
        if !self.is_empty() {
            out.push(self[..self.len() / 2].to_vec());
            out.push(self[1..].to_vec());
            // Shrink a single element (first shrinkable).
            for (i, item) in self.iter().enumerate() {
                if let Some(smaller) = item.shrink().into_iter().next() {
                    let mut v = self.clone();
                    v[i] = smaller;
                    out.push(v);
                    break;
                }
            }
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<(A, B)> {
        let mut out: Vec<(A, B)> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

impl<A: Shrink, B: Shrink, C: Shrink> Shrink for (A, B, C) {
    fn shrink(&self) -> Vec<(A, B, C)> {
        let mut out: Vec<(A, B, C)> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone(), self.2.clone()))
            .collect();
        out.extend(
            self.1
                .shrink()
                .into_iter()
                .map(|b| (self.0.clone(), b, self.2.clone())),
        );
        out.extend(
            self.2
                .shrink()
                .into_iter()
                .map(|c| (self.0.clone(), self.1.clone(), c)),
        );
        out
    }
}

impl<A: Shrink, B: Shrink, C: Shrink, D: Shrink> Shrink for (A, B, C, D) {
    fn shrink(&self) -> Vec<(A, B, C, D)> {
        let mut out: Vec<(A, B, C, D)> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone(), self.2.clone(), self.3.clone()))
            .collect();
        out.extend(
            self.1
                .shrink()
                .into_iter()
                .map(|b| (self.0.clone(), b, self.2.clone(), self.3.clone())),
        );
        out.extend(
            self.2
                .shrink()
                .into_iter()
                .map(|c| (self.0.clone(), self.1.clone(), c, self.3.clone())),
        );
        out.extend(
            self.3
                .shrink()
                .into_iter()
                .map(|d| (self.0.clone(), self.1.clone(), self.2.clone(), d)),
        );
        out
    }
}

/// Run `prop` on `cases` random inputs; shrink and panic on failure.
pub fn forall<T, G, P>(seed: u64, cases: usize, mut gen: G, prop: P)
where
    T: Shrink,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let value = gen(&mut rng);
        if let Err(msg) = prop(&value) {
            let (min_value, min_msg) = shrink_loop(value, msg, &prop);
            panic!(
                "property failed (case {case}/{cases}, seed {seed}):\n  \
                 counterexample: {min_value:?}\n  reason: {min_msg}\n  \
                 replay: propcheck::replay({seed}, {case}, gen, prop)"
            );
        }
    }
}

/// Replay one case of a failed [`forall`] run: regenerate the exact value
/// `forall(seed, ..)` drew for `case` (the generator stream is a pure
/// function of the seed) and apply `prop` to it, returning the verdict
/// instead of shrinking and panicking. The debugging hook the forall
/// failure message points at — drop it into a scratch test with the same
/// `gen`/`prop` to iterate on a single counterexample.
pub fn replay<T, G, P>(seed: u64, case: usize, mut gen: G, prop: P) -> Result<(), String>
where
    T: Shrink,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    let mut value = gen(&mut rng);
    for _ in 0..case {
        value = gen(&mut rng);
    }
    prop(&value)
}

fn shrink_loop<T: Shrink, P: Fn(&T) -> Result<(), String>>(
    mut value: T,
    mut msg: String,
    prop: &P,
) -> (T, String) {
    // Greedy descent, bounded to avoid pathological loops.
    for _ in 0..1000 {
        let mut advanced = false;
        for cand in value.shrink() {
            if let Err(m) = prop(&cand) {
                value = cand;
                msg = m;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    (value, msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_valid_property() {
        forall(
            1,
            200,
            |r| r.below(1000),
            |&n| {
                if n < 1000 {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "counterexample: 100")]
    fn shrinks_to_minimal_counterexample() {
        // Property "n < 100" fails first at some random n >= 100 and must
        // shrink to exactly 100.
        forall(
            2,
            500,
            |r| r.below(100_000),
            |&n| {
                if n < 100 {
                    Ok(())
                } else {
                    Err(format!("{n} >= 100"))
                }
            },
        );
    }

    #[test]
    fn replay_reproduces_the_forall_stream() {
        // forall and replay must draw the identical value for (seed, case):
        // collect forall's stream, then spot-check replay against it.
        let seen = std::cell::RefCell::new(Vec::new());
        forall(
            7,
            20,
            |r| r.below(1_000_000),
            |&n| {
                seen.borrow_mut().push(n);
                Ok(())
            },
        );
        let seen = seen.into_inner();
        for case in [0usize, 5, 19] {
            let expect = seen[case];
            replay(7, case, |r| r.below(1_000_000), |&n| {
                if n == expect {
                    Ok(())
                } else {
                    Err(format!("replayed {n}, forall drew {expect}"))
                }
            })
            .unwrap();
        }
    }

    #[test]
    fn vec_shrink_reduces_len() {
        let v = vec![5u64, 6, 7, 8];
        let shrunk = v.shrink();
        assert!(shrunk.iter().any(|s| s.len() < v.len()));
    }

    #[test]
    fn tuple_shrink_covers_both_slots() {
        let t = (4u64, 8u64);
        let shrunk = t.shrink();
        assert!(shrunk.iter().any(|(a, _)| *a < 4));
        assert!(shrunk.iter().any(|(_, b)| *b < 8));
    }
}
