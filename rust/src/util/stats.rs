//! Statistics helpers for the execution monitor and the bench harness.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Coefficient of variation (stddev / mean).
pub fn cv(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        0.0
    } else {
        stddev(xs) / m
    }
}

/// Minimum (NaN-free input assumed); +inf for empty.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

/// Maximum; -inf for empty.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Balance deviation of a set of concurrent execution times, as used by the
/// paper's load-balancing threshold (Section 3.3): `dev = t_min / t_max`,
/// i.e. 1.0 for a perfectly balanced execution and "all concurrent
/// executions are within X% of the best performing one" reads `dev >= X`.
///
/// (The paper's prose — "within 80% to 85% of the best performing one" with
/// maxDev calibrating to [0.8, 0.85] — fixes this semantics; the formula in
/// Section 3.3 is stated with the opposite inequality, which we treat as an
/// erratum. isUnbalanced is therefore `dev / cFactor < maxDev`.)
pub fn balance_dev(times: &[f64]) -> f64 {
    if times.len() < 2 {
        return 1.0;
    }
    let mx = max(times);
    if mx <= 0.0 {
        return 1.0;
    }
    min(times) / mx
}

/// Percentile via linear interpolation on a sorted copy (p in [0, 100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Median (50th percentile).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Exponentially-weighted moving value, the paper's lbt update rule:
/// `new = sample * weight + prev * (1 - weight)`.
pub fn ewma(prev: f64, sample: f64, weight: f64) -> f64 {
    sample * weight + prev * (1.0 - weight)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn balance_dev_bounds() {
        assert_eq!(balance_dev(&[1.0, 1.0, 1.0]), 1.0);
        assert!((balance_dev(&[0.5, 1.0]) - 0.5).abs() < 1e-12);
        assert_eq!(balance_dev(&[3.0]), 1.0);
        assert_eq!(balance_dev(&[]), 1.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn ewma_converges_to_repeated_sample() {
        let mut v = 0.0;
        for _ in 0..50 {
            v = ewma(v, 1.0, 2.0 / 3.0);
        }
        assert!((v - 1.0).abs() < 1e-6);
    }

    #[test]
    fn ewma_three_consecutive_unbalanced_cross_090() {
        // The paper: with weight 2/3, 3-4 consecutive unbalanced runs are
        // needed for lbt to reach the trigger region (~1).
        let w = 2.0 / 3.0;
        let mut lbt = 0.0;
        lbt = ewma(lbt, 1.0, w); // 0.667
        assert!(lbt < 0.9);
        lbt = ewma(lbt, 1.0, w); // 0.889
        assert!(lbt < 0.9);
        lbt = ewma(lbt, 1.0, w); // 0.963
        assert!(lbt > 0.95);
    }
}
