//! Deterministic RNG substrate: SplitMix64 seeding + xoshiro256** core.
//!
//! The simulator, the synthetic data generators and the property-testing
//! framework all need seeded, reproducible randomness; the offline vendor
//! set has no `rand`, so this implements the standard xoshiro256**
//! (Blackman & Vigna) with convenience samplers.

/// xoshiro256** pseudo-random generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that similar seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform u64 in [0, n) (Lemire-style rejection-free for our needs).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // 128-bit multiply-shift; negligible modulo bias is irrelevant here.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Lognormal multiplicative noise: exp(sigma * N(0,1)).
    pub fn lognormal(&mut self, sigma: f64) -> f64 {
        (sigma * self.normal()).exp()
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fork an independent stream (for per-subsystem determinism).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_decorrelate() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn lognormal_positive_and_centered() {
        let mut r = Rng::new(13);
        let xs: Vec<f64> = (0..20_000).map(|_| r.lognormal(0.05)).collect();
        assert!(xs.iter().all(|&x| x > 0.0));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 1.0).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn fork_streams_differ() {
        let mut a = Rng::new(5);
        let mut fa = a.fork();
        let mut fb = a.fork();
        assert_ne!(fa.next_u64(), fb.next_u64());
    }
}
