//! Small dense linear algebra: just enough for the RBF network solve used by
//! the knowledge base's configuration derivation (Section 3.2.3).
//!
//! The paper uses Alglib's Fast RBF; offline we implement a classic Gaussian
//! RBF network whose weights come from a regularized symmetric solve. Systems
//! are tiny (one row per stored profile), so an O(n³) Cholesky with partial
//! fallback to Gaussian elimination is plenty.

use crate::error::{Error, Result};

/// Row-major dense matrix.
#[derive(Clone, Debug)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn at(&self, r: usize, c: usize) -> f64 {
        self.data[r * self.cols + c]
    }

    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        self.data[r * self.cols + c] = v;
    }

    /// Matrix-vector product.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        let mut y = vec![0.0; self.rows];
        for r in 0..self.rows {
            let row = &self.data[r * self.cols..(r + 1) * self.cols];
            y[r] = row.iter().zip(x).map(|(a, b)| a * b).sum();
        }
        y
    }
}

/// Solve A x = b for symmetric positive-definite A via Cholesky (A = L Lᵀ).
pub fn solve_spd(a: &Mat, b: &[f64]) -> Result<Vec<f64>> {
    assert_eq!(a.rows, a.cols);
    assert_eq!(a.rows, b.len());
    let n = a.rows;
    let mut l = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.at(i, j);
            for k in 0..j {
                sum -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if sum <= 0.0 {
                    return Err(Error::Kb(format!(
                        "matrix not positive definite at pivot {i} ({sum})"
                    )));
                }
                l[i * n + i] = sum.sqrt();
            } else {
                l[i * n + j] = sum / l[j * n + j];
            }
        }
    }
    // Forward substitution L y = b.
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut sum = b[i];
        for k in 0..i {
            sum -= l[i * n + k] * y[k];
        }
        y[i] = sum / l[i * n + i];
    }
    // Back substitution Lᵀ x = y.
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut sum = y[i];
        for k in i + 1..n {
            sum -= l[k * n + i] * x[k];
        }
        x[i] = sum / l[i * n + i];
    }
    Ok(x)
}

/// Solve a general square system by Gaussian elimination with partial
/// pivoting (fallback when the RBF Gram matrix is near-singular and the
/// caller retries with a polynomial tail or larger regularization).
pub fn solve_general(a: &Mat, b: &[f64]) -> Result<Vec<f64>> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut m = a.data.clone();
    let mut x = b.to_vec();
    for col in 0..n {
        // Pivot.
        let mut piv = col;
        for r in col + 1..n {
            if m[r * n + col].abs() > m[piv * n + col].abs() {
                piv = r;
            }
        }
        if m[piv * n + col].abs() < 1e-14 {
            return Err(Error::Kb("singular system".to_string()));
        }
        if piv != col {
            for c in 0..n {
                m.swap(col * n + c, piv * n + c);
            }
            x.swap(col, piv);
        }
        // Eliminate below.
        for r in col + 1..n {
            let f = m[r * n + col] / m[col * n + col];
            if f == 0.0 {
                continue;
            }
            for c in col..n {
                m[r * n + c] -= f * m[col * n + c];
            }
            x[r] -= f * x[col];
        }
    }
    for i in (0..n).rev() {
        let mut sum = x[i];
        for c in i + 1..n {
            sum -= m[i * n + c] * x[c];
        }
        x[i] = sum / m[i * n + i];
    }
    Ok(x)
}

/// Euclidean distance between points.
pub fn dist(a: &[f64], b: &[f64]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f64>()
        .sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat(rows: usize, cols: usize, vals: &[f64]) -> Mat {
        Mat {
            rows,
            cols,
            data: vals.to_vec(),
        }
    }

    #[test]
    fn cholesky_solves_spd() {
        // A = [[4,2],[2,3]], b = [10, 8] -> x = [1.75, 1.5]
        let a = mat(2, 2, &[4.0, 2.0, 2.0, 3.0]);
        let x = solve_spd(&a, &[10.0, 8.0]).unwrap();
        assert!((x[0] - 1.75).abs() < 1e-12);
        assert!((x[1] - 1.5).abs() < 1e-12);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = mat(2, 2, &[1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(solve_spd(&a, &[1.0, 1.0]).is_err());
    }

    #[test]
    fn general_solver_with_pivoting() {
        // Requires a row swap: first pivot is 0.
        let a = mat(3, 3, &[0.0, 2.0, 1.0, 1.0, 0.0, 1.0, 2.0, 1.0, 0.0]);
        let xs = solve_general(&a, &[7.0, 4.0, 5.0]).unwrap();
        let back = a.matvec(&xs);
        for (g, w) in back.iter().zip(&[7.0, 4.0, 5.0]) {
            assert!((g - w).abs() < 1e-10);
        }
    }

    #[test]
    fn general_solver_detects_singular() {
        let a = mat(2, 2, &[1.0, 2.0, 2.0, 4.0]);
        assert!(solve_general(&a, &[1.0, 2.0]).is_err());
    }

    #[test]
    fn residual_random_spd() {
        // Build A = BᵀB + I (SPD), check ‖Ax - b‖ small.
        let n = 8;
        let mut rng = crate::util::rng::Rng::new(3);
        let mut bm = Mat::zeros(n, n);
        for r in 0..n {
            for c in 0..n {
                bm.set(r, c, rng.range_f64(-1.0, 1.0));
            }
        }
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += bm.at(k, i) * bm.at(k, j);
                }
                a.set(i, j, s + if i == j { 1.0 } else { 0.0 });
            }
        }
        let b: Vec<f64> = (0..n).map(|i| i as f64).collect();
        let x = solve_spd(&a, &b).unwrap();
        let r = a.matvec(&x);
        for (g, w) in r.iter().zip(&b) {
            assert!((g - w).abs() < 1e-9);
        }
    }

    #[test]
    fn dist_basic() {
        assert!((dist(&[0.0, 0.0], &[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }
}
