//! Crash-safe filesystem primitives for the durable KB store
//! (DESIGN.md §2.9): all persistent writes go through
//! [`atomic_write`] — write a temp file in the destination directory,
//! fsync it, then rename over the target — so readers only ever observe
//! either the old complete file or the new complete file, never a torn
//! prefix.

use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::error::Result;

/// Process-global counter distinguishing concurrent temp files; the pid
/// in the name distinguishes concurrent *processes* on a shared store.
static TMP_NONCE: AtomicU64 = AtomicU64::new(0);

/// Atomically replace `path` with `bytes`.
///
/// The temp file lives in the same directory as the target (rename must
/// not cross filesystems) and is fsynced before the rename, so a crash
/// at any point leaves either the previous contents or the full new
/// contents at `path` — plus, at worst, an orphaned `.tmp-` file that
/// [`KbStore::gc`](crate::kb::store::KbStore::gc) sweeps.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> Result<()> {
    let dir = path.parent().filter(|d| !d.as_os_str().is_empty());
    let name = path
        .file_name()
        .and_then(|n| n.to_str())
        .unwrap_or("file");
    let tmp_name = format!(
        ".tmp-{name}-{}-{}",
        std::process::id(),
        TMP_NONCE.fetch_add(1, Ordering::Relaxed)
    );
    let tmp = match dir {
        Some(d) => d.join(&tmp_name),
        None => std::path::PathBuf::from(&tmp_name),
    };
    let write = (|| -> Result<()> {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
        Ok(())
    })();
    let renamed = write
        .and_then(|_| std::fs::rename(&tmp, path).map_err(crate::error::Error::from));
    if let Err(e) = renamed {
        let _ = std::fs::remove_file(&tmp);
        return Err(e);
    }
    // Best-effort directory fsync: persists the rename itself. Some
    // filesystems refuse to open directories for writing — ignore.
    if let Some(d) = dir {
        if let Ok(dirf) = std::fs::File::open(d) {
            let _ = dirf.sync_all();
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replaces_existing_contents() {
        let path = std::env::temp_dir().join(format!(
            "marrow_fsio_test_{}.txt",
            std::process::id()
        ));
        atomic_write(&path, b"first").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"first");
        atomic_write(&path, b"second, longer than the first").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second, longer than the first");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn leaves_no_temp_residue() {
        let dir = std::env::temp_dir().join(format!(
            "marrow_fsio_residue_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        for i in 0..4 {
            atomic_write(&dir.join("data.json"), format!("v{i}").as_bytes()).unwrap();
        }
        let names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(names, vec!["data.json".to_string()], "residue: {names:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_parent_dir_is_an_error() {
        let path = std::env::temp_dir()
            .join(format!("marrow_fsio_absent_{}", std::process::id()))
            .join("nested")
            .join("data.json");
        assert!(atomic_write(&path, b"x").is_err());
    }
}
