//! The dataflow task graph (DESIGN.md §2.7): one node per (stage ×
//! partition chunk) with explicit dependency edges, replacing the per-stage
//! barrier drain.
//!
//! `decompose` guarantees identical partitioning across consecutive kernels
//! (Section 3.1), so a consumer chunk depends on exactly the producer chunk
//! covering its unit range — a 1:1 edge. The only surviving barriers are
//! *sync nodes*: `Loop` condition reductions / host state updates (which
//! also re-broadcast COPY arguments) and `MapReduce` fan-ins. Everything
//! else drains as soon as its dependencies retire, so a fast GPU slot can
//! start stage 2 of its chunks while a slow CPU sub-device is still
//! finishing stage 1 of its own — the cross-stage overlap the paper's
//! compound computations leave on the table under a barrier drain.
//!
//! The graph is built from a flattened *stage program* ([`flatten_stages`])
//! that both the builder and the executor interpret, so the node a worker
//! pops always agrees with the subtree it must run.

use crate::decompose::{chunk_partition, ExecSlot, Partition, PartitionPlan};
use crate::error::{Error, Result};
use crate::sct::{LoopState, ParamSpec, Reduction, Sct};

/// One flattened stage of an execution request.
pub enum StageOp<'s> {
    /// Run this subtree over each chunk on a device slot. `carried` marks
    /// stages that consume the previous compute stage's first output
    /// (pipeline chaining); `vec_off`/`scalar_off` position the
    /// request-argument cursor at this stage (earlier stages already
    /// consumed their own request vectors and scalars).
    Compute {
        sct: &'s Sct,
        carried: bool,
        vec_off: usize,
        scalar_off: usize,
    },
    /// Host-side global sync: `Loop` stage 3 for iteration `iter` —
    /// stoppage condition + state update + COPY re-broadcast.
    LoopSync { state: &'s LoopState, iter: u32 },
    /// Host-side reduction fan-in (`MapReduce`).
    Reduce { reduce: &'s Reduction },
}

impl StageOp<'_> {
    pub fn is_sync(&self) -> bool {
        !matches!(self, StageOp::Compute { .. })
    }

    /// Human label for DOT dumps and error messages.
    pub fn label(&self) -> String {
        match self {
            StageOp::Compute { sct, .. } => sct.id(),
            StageOp::LoopSync { iter, .. } => format!("loop-sync it{iter}"),
            StageOp::Reduce { .. } => "reduce".to_string(),
        }
    }
}

/// Flatten a device-side subtree into compute stages: a pipeline of
/// kernels splits into one stage per kernel (that split is what buys
/// cross-stage overlap); anything else runs whole as a single compute
/// stage per chunk — exactly the shapes the barrier executor's
/// tree-traversal supports, so both drain modes cover the same SCTs.
fn flatten_compute<'s>(sct: &'s Sct, out: &mut Vec<StageOp<'s>>) {
    match sct {
        Sct::Pipeline(stages)
            if stages.len() > 1 && stages.iter().all(|s| matches!(s, Sct::Kernel(_))) =>
        {
            let mut vec_off = 0usize;
            let mut scalar_off = 0usize;
            for (i, s) in stages.iter().enumerate() {
                let k = match s {
                    Sct::Kernel(k) => k,
                    _ => unreachable!("guarded by the match arm"),
                };
                let carried = i > 0;
                out.push(StageOp::Compute {
                    sct: s,
                    carried,
                    vec_off,
                    scalar_off,
                });
                // Advance the request-arg cursor past this stage's params;
                // the first VecIn of a carried stage binds the pipeline
                // intermediate, not a request vector (mirrors the chunk
                // runner's bind_params).
                let mut first_vecin = true;
                for p in &k.params {
                    match p {
                        ParamSpec::VecIn => {
                            if !(carried && first_vecin) {
                                vec_off += 1;
                            }
                            first_vecin = false;
                        }
                        ParamSpec::VecCopy => vec_off += 1,
                        ParamSpec::ScalarF32(_) | ParamSpec::ScalarI32(_) => scalar_off += 1,
                    }
                }
            }
        }
        Sct::Map(inner) => flatten_compute(inner, out),
        other => out.push(StageOp::Compute {
            sct: other,
            carried: false,
            vec_off: 0,
            scalar_off: 0,
        }),
    }
}

/// Flatten a request's SCT into the linear stage program the task graph is
/// built over. Top-level global-sync `Loop`s expand to `max_iters` copies
/// of (body stages + a `LoopSync` node); top-level `MapReduce` appends a
/// `Reduce` fan-in after its map stages. These mirror the request-level
/// skeleton handling of the barrier scheduler, so both modes execute the
/// same structure — only the draining differs.
pub fn flatten_stages(sct: &Sct) -> Result<Vec<StageOp<'_>>> {
    let mut out = Vec::new();
    match sct {
        Sct::Loop { body, state } if state.global_sync => {
            for iter in 0..state.max_iters {
                flatten_compute(body, &mut out);
                out.push(StageOp::LoopSync { state, iter });
            }
        }
        Sct::MapReduce { map, reduce } => {
            flatten_compute(map, &mut out);
            out.push(StageOp::Reduce { reduce });
        }
        other => flatten_compute(other, &mut out),
    }
    if out.is_empty() {
        return Err(Error::Spec(
            "SCT flattens to an empty stage program (zero-iteration loop?)".into(),
        ));
    }
    Ok(out)
}

/// Node kind: device-side chunk work, or a host-side global sync point.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeKind {
    Compute,
    Sync,
}

/// One task node: a (stage × chunk) unit of work.
#[derive(Clone, Debug)]
pub struct TaskNode {
    pub id: usize,
    /// Index into the stage program (member-local in a fused graph).
    pub stage: u32,
    pub kind: NodeKind,
    /// The chunk this node covers (sync nodes span the whole domain and
    /// are homed on the first slot, freely stealable host work).
    pub partition: Partition,
    /// Unit-order position within the stage: sorting a stage's outputs by
    /// `seq` reconstructs the domain. [`fuse_graphs`] re-bases seqs into
    /// disjoint per-member ranges so fused sink partials stay separable.
    pub seq: usize,
    /// Producer node whose first output chains into this node's carried
    /// input (pipeline stages only).
    pub carried_from: Option<usize>,
    /// Which fused batch member this node belongs to (DESIGN.md §2.10):
    /// per-request chunk provenance. 0 for a solo (unfused) graph.
    pub member: usize,
}

/// The dependency graph of one execution request.
#[derive(Clone, Debug, Default)]
pub struct TaskGraph {
    pub nodes: Vec<TaskNode>,
    /// `deps[i]`: nodes that must retire before node `i` may start.
    pub deps: Vec<Vec<usize>>,
    /// `consumers[i]`: nodes waiting on node `i` (reverse edges).
    pub consumers: Vec<Vec<usize>>,
    pub n_stages: u32,
}

/// Build the task graph for a stage program over a partition plan. Compute
/// stages share one chunk layout (the same splitter the chunked barrier
/// queues use, so both modes see identical chunk boundaries); `MapReduce`
/// programs stay at partition granularity — splitting would change the
/// fold arity for order-sensitive merges.
pub fn build_graph(
    stages: &[StageOp<'_>],
    plan: &PartitionPlan,
    tasks_per_slot: u32,
) -> Result<TaskGraph> {
    let reduce_present = stages.iter().any(|s| matches!(s, StageOp::Reduce { .. }));
    let chunks: Vec<Partition> = if reduce_present {
        plan.active().copied().collect()
    } else {
        let mut v = Vec::new();
        for part in plan.active() {
            v.extend(chunk_partition(part, plan.quantum, tasks_per_slot));
        }
        v
    };
    if chunks.is_empty() {
        return Err(Error::Decompose(
            "no active partitions to build a task graph over".into(),
        ));
    }
    let sync_slot = chunks[0].slot;
    let total_units = plan.total_units();

    let mut g = TaskGraph {
        n_stages: stages.len() as u32,
        ..TaskGraph::default()
    };
    let mut prev: Vec<usize> = Vec::new();
    let mut prev_compute = false;
    for (s, op) in stages.iter().enumerate() {
        let mut cur = Vec::new();
        match op {
            StageOp::Compute { carried, .. } => {
                for (c, chunk) in chunks.iter().enumerate() {
                    let id = g.nodes.len();
                    let mut deps = Vec::new();
                    let mut carried_from = None;
                    if !prev.is_empty() {
                        if prev_compute {
                            // Identical partitioning across consecutive
                            // kernels: the consumer chunk depends on the
                            // single producer chunk covering its range.
                            deps.push(prev[c]);
                            if *carried {
                                carried_from = Some(prev[c]);
                            }
                        } else {
                            // Fan-out from the preceding sync node.
                            deps.push(prev[0]);
                        }
                    }
                    g.nodes.push(TaskNode {
                        id,
                        stage: s as u32,
                        kind: NodeKind::Compute,
                        partition: *chunk,
                        seq: c,
                        carried_from,
                        member: 0,
                    });
                    g.deps.push(deps);
                    cur.push(id);
                }
                prev_compute = true;
            }
            StageOp::LoopSync { .. } | StageOp::Reduce { .. } => {
                let id = g.nodes.len();
                g.nodes.push(TaskNode {
                    id,
                    stage: s as u32,
                    kind: NodeKind::Sync,
                    partition: Partition {
                        slot: sync_slot,
                        start_unit: 0,
                        units: total_units,
                    },
                    seq: 0,
                    carried_from: None,
                    member: 0,
                });
                // Fan-in: every chunk of the previous stage gates the sync.
                g.deps.push(prev.clone());
                cur.push(id);
                prev_compute = false;
            }
        }
        prev = cur;
    }

    g.consumers = vec![Vec::new(); g.nodes.len()];
    for (i, deps) in g.deps.iter().enumerate() {
        for &d in deps {
            g.consumers[d].push(i);
        }
    }
    Ok(g)
}

impl TaskGraph {
    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Nodes nothing depends on — the final frontier whose outputs are the
    /// request's result (unless a sync node overrides them).
    pub fn sinks(&self) -> Vec<usize> {
        (0..self.nodes.len())
            .filter(|&i| self.consumers[i].is_empty())
            .collect()
    }

    /// Kahn topological order; `None` means the graph has a cycle (which
    /// the builder can never produce — property-tested).
    pub fn topo_order(&self) -> Option<Vec<usize>> {
        let mut indeg: Vec<usize> = self.deps.iter().map(|d| d.len()).collect();
        let mut ready: Vec<usize> = (0..self.nodes.len())
            .filter(|&i| indeg[i] == 0)
            .collect();
        let mut order = Vec::with_capacity(self.nodes.len());
        while let Some(n) = ready.pop() {
            order.push(n);
            for &c in &self.consumers[n] {
                indeg[c] -= 1;
                if indeg[c] == 0 {
                    ready.push(c);
                }
            }
        }
        if order.len() == self.nodes.len() {
            Some(order)
        } else {
            None
        }
    }

    /// GraphViz DOT dump (the `marrow graph` subcommand): compute nodes
    /// labelled stage/chunk/slot, sync nodes highlighted.
    pub fn to_dot(&self, stage_labels: &[String]) -> String {
        let mut out = String::from(
            "digraph taskgraph {\n  rankdir=LR;\n  node [shape=box, fontsize=10];\n",
        );
        for n in &self.nodes {
            let label = stage_labels
                .get(n.stage as usize)
                .cloned()
                .unwrap_or_default();
            match n.kind {
                NodeKind::Compute => {
                    out.push_str(&format!(
                        "  n{} [label=\"s{} {}\\nchunk {} [{}] {}u\"];\n",
                        n.id, n.stage, label, n.seq, n.partition.slot, n.partition.units
                    ));
                }
                NodeKind::Sync => {
                    out.push_str(&format!(
                        "  n{} [label=\"s{} {}\\nSYNC {}u\", shape=doubleoctagon, \
                         style=filled, fillcolor=gold];\n",
                        n.id, n.stage, label, n.partition.units
                    ));
                }
            }
        }
        for (i, deps) in self.deps.iter().enumerate() {
            for &d in deps {
                out.push_str(&format!("  n{d} -> n{i};\n"));
            }
        }
        out.push_str("}\n");
        out
    }

    /// The per-slot prefetch lookahead (DESIGN.md §2.12): the next `depth`
    /// compute nodes homed on `slot` whose inputs can be staged ahead of
    /// need. Node ids are already a topological order (the builder only
    /// ever points deps at earlier ids), so iterating in id order walks
    /// the graph in execution waves. Initially-ready nodes (no deps) are
    /// excluded — the drain stages those immediately anyway; the 1:1 edge
    /// contract pins every later node's placement at build time, which is
    /// what makes this lookahead sound before the nodes are ready.
    pub fn prefetch_horizon(&self, slot: ExecSlot, depth: u32) -> Vec<usize> {
        self.prefetch_horizon_where(slot, depth, |_| true)
    }

    /// [`TaskGraph::prefetch_horizon`] restricted by a runtime readiness
    /// predicate: the drain passes `not_ready(id)` so the horizon advances
    /// past nodes that already became ready (or retired) — prefetching
    /// those would stage data their execution stages anyway.
    pub fn prefetch_horizon_where<F: Fn(usize) -> bool>(
        &self,
        slot: ExecSlot,
        depth: u32,
        not_ready: F,
    ) -> Vec<usize> {
        if depth == 0 {
            return Vec::new();
        }
        let mut out = Vec::new();
        for n in &self.nodes {
            if n.kind == NodeKind::Compute
                && n.partition.slot == slot
                && !self.deps[n.id].is_empty()
                && not_ready(n.id)
            {
                out.push(n.id);
                if out.len() >= depth as usize {
                    break;
                }
            }
        }
        out
    }

    /// [`TaskGraph::to_dot`] plus dashed prefetch-edge annotations: for
    /// every slot, the nodes inside its `depth`-deep prefetch horizon get
    /// a `pf` edge from their producer — the upload the prefetch pipeline
    /// would issue under that producer's compute.
    pub fn to_dot_with_prefetch(&self, stage_labels: &[String], depth: u32) -> String {
        let mut out = self.to_dot(stage_labels);
        if depth == 0 {
            return out;
        }
        out.truncate(out.len() - "}\n".len());
        let mut slots: Vec<ExecSlot> = Vec::new();
        for n in &self.nodes {
            if !slots.contains(&n.partition.slot) {
                slots.push(n.partition.slot);
            }
        }
        for slot in slots {
            for id in self.prefetch_horizon(slot, depth) {
                for &d in &self.deps[id] {
                    out.push_str(&format!(
                        "  n{d} -> n{id} [style=dashed, color=royalblue, \
                         constraint=false, label=\"pf\"];\n"
                    ));
                }
            }
        }
        out.push_str("}\n");
        out
    }
}

/// Whether a request's stage program can participate in graph fusion and
/// same-SCT batching (DESIGN.md §2.10): every flattened stage must be
/// device-side compute. Global-sync loops and reductions keep request-wide
/// barrier and output semantics a fused graph cannot disentangle per
/// member, so they serve solo.
pub fn fusable(sct: &Sct) -> bool {
    flatten_stages(sct)
        .map(|stages| stages.iter().all(|op| !op.is_sync()))
        .unwrap_or(false)
}

/// One member's slice of a fused graph: the node-id range it contributed
/// and the offset its chunk seqs were re-based by.
#[derive(Clone, Debug)]
pub struct FusedMember {
    pub nodes: std::ops::Range<usize>,
    pub seq_base: usize,
    /// The member's own stage-program length (`TaskNode::stage` stays
    /// member-local, so a fused runner dispatches on `(member, stage)`).
    pub n_stages: u32,
}

/// Several requests' task graphs fused into one schedulable graph
/// (DESIGN.md §2.10): co-admitted compatible requests drain under a single
/// ready-set scheduler pass, so a small request's chunks fill slots a
/// large one leaves idle instead of queuing behind it.
#[derive(Clone, Debug, Default)]
pub struct FusedGraph {
    pub graph: TaskGraph,
    pub members: Vec<FusedMember>,
}

impl FusedGraph {
    /// The member owning a (fused) sink seq, if any.
    pub fn member_of_seq(&self, seq: usize) -> Option<usize> {
        self.members
            .iter()
            .position(|m| seq >= m.seq_base && seq < m.seq_base + m.nodes.len())
    }

    /// Split a fused drain's seq-keyed sink partials back into per-member
    /// result sets, seqs re-based to each member's own numbering — the
    /// disassembly step that makes fused results bit-identical to solo
    /// runs per request.
    pub fn split_partials<T: Clone>(&self, partials: &[(usize, T)]) -> Vec<Vec<(usize, T)>> {
        let mut out: Vec<Vec<(usize, T)>> = vec![Vec::new(); self.members.len()];
        for (seq, val) in partials {
            if let Some(m) = self.member_of_seq(*seq) {
                out[m].push((*seq - self.members[m].seq_base, val.clone()));
            }
        }
        for member in &mut out {
            member.sort_by_key(|(s, _)| *s);
        }
        out
    }
}

/// Fuse several requests' task graphs into one (DESIGN.md §2.10). Node ids
/// and seqs are offset into disjoint per-member ranges, dependency edges
/// stay within their member — no cross-request edges; the ready-set
/// scheduler is what interleaves members onto shared slots — and every
/// node carries its member index for per-request result disassembly and
/// trace attribution. Graphs with sync nodes are rejected ([`fusable`] is
/// the admission-side check): a fused graph has no request-wide barrier or
/// single output slot.
pub fn fuse_graphs(parts: Vec<TaskGraph>) -> Result<FusedGraph> {
    if parts.is_empty() {
        return Err(Error::Decompose("cannot fuse zero task graphs".into()));
    }
    let mut fused = TaskGraph::default();
    let mut members = Vec::with_capacity(parts.len());
    for (m, g) in parts.into_iter().enumerate() {
        if g.nodes.iter().any(|n| n.kind == NodeKind::Sync) {
            return Err(Error::Decompose(format!(
                "graph fusion requires sync-free stage programs \
                 (member {m} has a sync node)"
            )));
        }
        let base = fused.nodes.len();
        // Seqs are chunk indices within a stage, so every member seq is
        // below its node count — offsetting by the node base keeps the
        // ranges disjoint.
        let seq_base = base;
        let n_member_stages = g.n_stages;
        for mut n in g.nodes {
            n.id += base;
            n.seq += seq_base;
            n.member = m;
            n.carried_from = n.carried_from.map(|c| c + base);
            fused.nodes.push(n);
        }
        for deps in g.deps {
            fused.deps.push(deps.into_iter().map(|d| d + base).collect());
        }
        fused.n_stages = fused.n_stages.max(n_member_stages);
        members.push(FusedMember {
            nodes: base..fused.nodes.len(),
            seq_base,
            n_stages: n_member_stages,
        });
    }
    fused.consumers = vec![Vec::new(); fused.nodes.len()];
    for (i, deps) in fused.deps.iter().enumerate() {
        for &d in deps {
            fused.consumers[d].push(i);
        }
    }
    Ok(FusedGraph {
        graph: fused,
        members,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decompose::{decompose, DecomposeConfig};
    use crate::sct::{KernelSpec, ParamSpec, Sct};
    use crate::util::propcheck::forall;

    fn kernel(name: &str) -> Sct {
        Sct::kernel(KernelSpec::new(name, vec![ParamSpec::VecIn], 1))
    }

    fn pipe(n: usize) -> Sct {
        Sct::pipeline((0..n).map(|i| kernel(&format!("k{i}"))).collect())
    }

    fn plan_for(sct: &Sct, total: u64, quantum: u64) -> PartitionPlan {
        decompose(
            sct,
            total,
            &DecomposeConfig {
                cpu_subdevices: 3,
                gpu_overlap: vec![2],
                gpu_weights: vec![1.0],
                cpu_share: 0.4,
                wgs: 1,
                chunk_quantum: quantum,
            },
        )
        .unwrap()
    }

    #[test]
    fn pipeline_flattens_per_kernel_with_cursor_offsets() {
        use crate::data::vector::ScalarTrait;
        // Stage 0: VecIn + scalar (consumes vec 0, scalar 0); stage 1:
        // VecIn binds the carried intermediate + VecCopy consumes vec 1.
        let mut a = KernelSpec::new("a", vec![ParamSpec::VecIn], 1);
        a.params.push(ParamSpec::ScalarF32(ScalarTrait::Bound));
        let b = KernelSpec::new("b", vec![ParamSpec::VecIn, ParamSpec::VecCopy], 1);
        let c = KernelSpec::new("c", vec![ParamSpec::VecIn], 1);
        let sct = Sct::pipeline(vec![Sct::kernel(a), Sct::kernel(b), Sct::kernel(c)]);
        let stages = flatten_stages(&sct).unwrap();
        assert_eq!(stages.len(), 3);
        match &stages[0] {
            StageOp::Compute {
                carried,
                vec_off,
                scalar_off,
                ..
            } => {
                assert!(!carried);
                assert_eq!((*vec_off, *scalar_off), (0, 0));
            }
            _ => panic!("stage 0 must be compute"),
        }
        match &stages[1] {
            StageOp::Compute {
                carried,
                vec_off,
                scalar_off,
                ..
            } => {
                assert!(*carried);
                assert_eq!((*vec_off, *scalar_off), (1, 1));
            }
            _ => panic!("stage 1 must be compute"),
        }
        match &stages[2] {
            StageOp::Compute { vec_off, .. } => {
                // Stage 1 consumed only the VecCopy (its VecIn was carried).
                assert_eq!(*vec_off, 2);
            }
            _ => panic!("stage 2 must be compute"),
        }
    }

    #[test]
    fn global_sync_loop_expands_to_iterations_with_sync_nodes() {
        let sct = Sct::for_loop(pipe(2), 3, true);
        let stages = flatten_stages(&sct).unwrap();
        assert_eq!(stages.len(), 9); // 3 x (2 compute + 1 sync)
        assert!(stages[2].is_sync() && stages[5].is_sync() && stages[8].is_sync());
        let p = plan_for(&sct, 1024, 8);
        let g = build_graph(&stages, &p, 2).unwrap();
        // Sync nodes are exactly the per-iteration barriers, and the last
        // node is the final sync (the graph's only sink).
        let syncs: Vec<&TaskNode> = g
            .nodes
            .iter()
            .filter(|n| n.kind == NodeKind::Sync)
            .collect();
        assert_eq!(syncs.len(), 3);
        assert_eq!(g.sinks(), vec![g.n_nodes() - 1]);
        assert_eq!(g.nodes[g.n_nodes() - 1].kind, NodeKind::Sync);
        // Fan-in: each sync waits on every chunk of the previous stage;
        // fan-out: each first-body-stage node of the next iteration waits
        // on the sync alone.
        let chunks = g.nodes.iter().filter(|n| n.stage == 0).count();
        assert!(chunks >= 2);
        assert_eq!(g.deps[syncs[0].id].len(), chunks);
        for n in g.nodes.iter().filter(|n| n.stage == 3) {
            assert_eq!(g.deps[n.id], vec![syncs[0].id]);
            assert!(n.carried_from.is_none());
        }
    }

    #[test]
    fn map_reduce_stays_at_partition_granularity() {
        use crate::data::vector::Merge;
        let sct = Sct::map_reduce(kernel("m"), Reduction::Host(Merge::Add));
        let stages = flatten_stages(&sct).unwrap();
        assert_eq!(stages.len(), 2);
        let p = plan_for(&sct, 1000, 1);
        let g = build_graph(&stages, &p, 4).unwrap();
        let map_nodes = g.nodes.iter().filter(|n| n.stage == 0).count();
        assert_eq!(map_nodes, p.active().count(), "no chunk splitting");
        assert_eq!(g.sinks(), vec![g.n_nodes() - 1]);
    }

    #[test]
    fn dot_dump_highlights_sync_nodes() {
        let sct = Sct::for_loop(kernel("body"), 2, true);
        let stages = flatten_stages(&sct).unwrap();
        let labels: Vec<String> = stages.iter().map(|s| s.label()).collect();
        let p = plan_for(&sct, 256, 1);
        let g = build_graph(&stages, &p, 2).unwrap();
        let dot = g.to_dot(&labels);
        assert!(dot.starts_with("digraph"));
        assert!(dot.contains("doubleoctagon"), "sync nodes highlighted");
        assert!(dot.contains("loop-sync it0"));
        assert!(dot.contains("->"));
    }

    #[test]
    fn prefetch_horizon_walks_dependent_compute_nodes_per_slot() {
        let sct = pipe(3);
        let stages = flatten_stages(&sct).unwrap();
        let p = plan_for(&sct, 1024, 8);
        let g = build_graph(&stages, &p, 2).unwrap();
        let slot = g.nodes[0].partition.slot;
        assert!(
            g.prefetch_horizon(slot, 0).is_empty(),
            "depth 0 disables the lookahead"
        );
        let h = g.prefetch_horizon(slot, 4);
        assert!(!h.is_empty() && h.len() <= 4);
        let mut last = 0;
        for &id in &h {
            let n = &g.nodes[id];
            assert_eq!(n.kind, NodeKind::Compute);
            assert_eq!(n.partition.slot, slot, "horizon is homed on the slot");
            assert!(
                !g.deps[id].is_empty(),
                "initially-ready nodes are staged by the drain itself"
            );
            assert!(id >= last, "horizon follows execution waves");
            last = id;
        }
        // A huge depth is clamped to the slot's dependent node count.
        let all = g.prefetch_horizon(slot, u32::MAX);
        let expect = g
            .nodes
            .iter()
            .filter(|n| {
                n.kind == NodeKind::Compute
                    && n.partition.slot == slot
                    && !g.deps[n.id].is_empty()
            })
            .count();
        assert_eq!(all.len(), expect);
    }

    #[test]
    fn dot_prefetch_annotation_adds_dashed_edges() {
        let sct = pipe(3);
        let stages = flatten_stages(&sct).unwrap();
        let labels: Vec<String> = stages.iter().map(|s| s.label()).collect();
        let p = plan_for(&sct, 1024, 8);
        let g = build_graph(&stages, &p, 2).unwrap();
        let plain = g.to_dot_with_prefetch(&labels, 0);
        assert_eq!(plain, g.to_dot(&labels), "depth 0 is the plain dump");
        let dot = g.to_dot_with_prefetch(&labels, 2);
        assert!(dot.contains("style=dashed"), "prefetch edges annotated");
        assert!(dot.contains("label=\"pf\""));
        assert!(dot.ends_with("}\n"));
    }

    #[test]
    fn prop_graph_edges_respect_ranges_and_topology() {
        // For random (domain size, tasks per slot, pipeline depth, share):
        //  * a topological order exists (no cycles);
        //  * compute nodes have fan-in <= 1, and a compute->compute edge
        //    connects identical unit ranges (the 1:1 locality contract);
        //  * sync nodes are the only fan-ins wider than 1;
        //  * every compute stage's chunks tile the domain exactly.
        forall(
            0x6A4F,
            200,
            |r| {
                (
                    r.below(1 << 12) + 1, // total units
                    r.below(6) + 1,       // tasks per slot
                    r.below(4) + 1,       // pipeline depth
                    r.below(101),         // cpu share %
                )
            },
            |&(total, tps, depth, share)| {
                let sct = if depth == 1 {
                    kernel("k0")
                } else {
                    pipe(depth as usize)
                };
                let plan = decompose(
                    &sct,
                    total,
                    &DecomposeConfig {
                        cpu_subdevices: 2,
                        gpu_overlap: vec![2],
                        gpu_weights: vec![1.0],
                        cpu_share: share as f64 / 100.0,
                        wgs: 1,
                        chunk_quantum: 8,
                    },
                )
                .map_err(|e| format!("{e}"))?;
                let stages = flatten_stages(&sct).map_err(|e| format!("{e}"))?;
                if stages.len() != depth as usize {
                    return Err(format!("{} stages for depth {depth}", stages.len()));
                }
                let g = build_graph(&stages, &plan, tps as u32)
                    .map_err(|e| format!("{e}"))?;
                if g.topo_order().is_none() {
                    return Err("cycle in task graph".to_string());
                }
                for n in &g.nodes {
                    let fan_in = g.deps[n.id].len();
                    match n.kind {
                        NodeKind::Compute => {
                            if fan_in > 1 {
                                return Err(format!(
                                    "compute node {} has fan-in {fan_in}",
                                    n.id
                                ));
                            }
                            for &d in &g.deps[n.id] {
                                let dep = &g.nodes[d];
                                if dep.kind == NodeKind::Compute
                                    && (dep.partition.start_unit != n.partition.start_unit
                                        || dep.partition.units != n.partition.units)
                                {
                                    return Err(format!(
                                        "edge {d}->{} crosses unit ranges",
                                        n.id
                                    ));
                                }
                            }
                        }
                        NodeKind::Sync => {}
                    }
                }
                // Each stage tiles [0, total).
                for s in 0..g.n_stages {
                    let mut stage_nodes: Vec<&TaskNode> = g
                        .nodes
                        .iter()
                        .filter(|n| n.stage == s && n.kind == NodeKind::Compute)
                        .collect();
                    stage_nodes.sort_by_key(|n| n.seq);
                    let mut cursor = 0u64;
                    for n in &stage_nodes {
                        if n.partition.start_unit != cursor {
                            return Err(format!(
                                "stage {s} gap at {cursor} (node {})",
                                n.id
                            ));
                        }
                        cursor += n.partition.units;
                    }
                    if cursor != total {
                        return Err(format!("stage {s} tiles {cursor} of {total}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn fusion_keeps_members_disjoint_and_tagged() {
        let a_sct = pipe(2);
        let b_sct = kernel("solo");
        let a = build_graph(
            &flatten_stages(&a_sct).unwrap(),
            &plan_for(&a_sct, 512, 8),
            2,
        )
        .unwrap();
        let b = build_graph(
            &flatten_stages(&b_sct).unwrap(),
            &plan_for(&b_sct, 256, 8),
            2,
        )
        .unwrap();
        let (na, nb) = (a.n_nodes(), b.n_nodes());
        let fused = fuse_graphs(vec![a, b]).unwrap();
        let g = &fused.graph;
        assert_eq!(g.n_nodes(), na + nb);
        assert_eq!(fused.members.len(), 2);
        assert_eq!(fused.members[0].nodes, 0..na);
        assert_eq!(fused.members[1].nodes, na..na + nb);
        assert_eq!(fused.members[0].n_stages, 2);
        assert_eq!(fused.members[1].n_stages, 1);
        assert_eq!(g.n_stages, 2);
        assert!(g.topo_order().is_some());
        // Provenance: every node tagged with its member, and no edge
        // crosses the member boundary.
        for n in &g.nodes {
            let m = &fused.members[n.member];
            assert!(m.nodes.contains(&n.id), "node {} outside member range", n.id);
            for &d in &g.deps[n.id] {
                assert_eq!(g.nodes[d].member, n.member, "edge {d}->{} crosses members", n.id);
            }
            if let Some(c) = n.carried_from {
                assert_eq!(g.nodes[c].member, n.member);
            }
        }
        // Seqs are globally unique, so sink partials stay separable.
        let mut seqs: Vec<usize> = g.nodes.iter().map(|n| n.seq).collect();
        seqs.sort_unstable();
        seqs.dedup();
        assert_eq!(seqs.len(), g.n_nodes());
        // Disassembly re-bases each member's seqs to its own numbering.
        let sink_partials: Vec<(usize, usize)> =
            g.sinks().iter().map(|&id| (g.nodes[id].seq, id)).collect();
        let split = fused.split_partials(&sink_partials);
        assert_eq!(split.len(), 2);
        for (m, part) in split.iter().enumerate() {
            assert!(!part.is_empty(), "member {m} lost its sink partials");
            for (local_seq, id) in part {
                assert_eq!(fused.graph.nodes[*id].member, m);
                assert_eq!(
                    local_seq + fused.members[m].seq_base,
                    fused.graph.nodes[*id].seq
                );
            }
        }
    }

    #[test]
    fn fusion_rejects_sync_programs() {
        assert!(fusable(&pipe(3)));
        assert!(fusable(&kernel("k")));
        let looped = Sct::for_loop(kernel("body"), 2, true);
        assert!(!fusable(&looped));
        use crate::data::vector::Merge;
        let mr = Sct::map_reduce(kernel("m"), Reduction::Host(Merge::Add));
        assert!(!fusable(&mr));

        let stages = flatten_stages(&looped).unwrap();
        let g = build_graph(&stages, &plan_for(&looped, 256, 1), 2).unwrap();
        assert!(fuse_graphs(vec![g]).is_err(), "sync graphs must not fuse");
        assert!(fuse_graphs(Vec::new()).is_err(), "empty fusion must error");
    }
}
