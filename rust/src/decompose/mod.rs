//! Locality-aware domain decomposition (Section 3.1).
//!
//! The data-set is decomposed into partitions adjustable to the best
//! work-group size of each device; every vector communicated between
//! consecutive kernels must see an *identical* partitioning so data persists
//! in device memory with no inter-device movement. The partitioner therefore
//! works with a global vision of the SCT: the partition quantum is the least
//! common multiple of every kernel's granularity constraint plus the AOT
//! chunk-menu constraint (static HLO shapes; DESIGN.md §1.2).

pub mod graph;

use crate::error::{Error, Result};
use crate::sct::Sct;

/// One parallel execution slot of the machine (Section 3.2.2: fission
/// sub-devices and GPU overlap slots all count towards the SCT's level of
/// coarse parallelism).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ExecSlot {
    /// Fission sub-device `idx` of the CPU device.
    CpuSub { idx: u32 },
    /// Overlap slot `slot` of GPU `gpu`.
    GpuSlot { gpu: u32, slot: u32 },
}

impl ExecSlot {
    pub fn is_cpu(&self) -> bool {
        matches!(self, ExecSlot::CpuSub { .. })
    }

    /// Whether two slots share one physical device (and therefore one
    /// memory): CPU sub-devices all read host memory; a GPU's overlap
    /// slots share its device memory. Migrating work between same-device
    /// slots moves no data; across devices it forfeits residency.
    pub fn same_device(&self, other: &ExecSlot) -> bool {
        match (self, other) {
            (ExecSlot::CpuSub { .. }, ExecSlot::CpuSub { .. }) => true,
            (ExecSlot::GpuSlot { gpu: a, .. }, ExecSlot::GpuSlot { gpu: b, .. }) => a == b,
            _ => false,
        }
    }
}

impl std::fmt::Display for ExecSlot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecSlot::CpuSub { idx } => write!(f, "cpu{idx}"),
            ExecSlot::GpuSlot { gpu, slot } => write!(f, "gpu{gpu}.{slot}"),
        }
    }
}

/// A contiguous range of epu units assigned to one execution slot.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Partition {
    pub slot: ExecSlot,
    pub start_unit: u64,
    pub units: u64,
}

/// Split one partition into roughly `tasks_per_slot` stealable chunks,
/// every piece aligned to `quantum` (the last piece absorbs the remainder,
/// preserving whatever residue the partition carried). Both the chunked
/// work queues and the dataflow task graph use this single splitter, so
/// barrier and dataflow drains see byte-identical chunk boundaries.
pub fn chunk_partition(part: &Partition, quantum: u64, tasks_per_slot: u32) -> Vec<Partition> {
    let q = quantum.max(1);
    let pieces = tasks_per_slot.max(1) as u64;
    let grain = (part.units / pieces / q).max(1) * q;
    let mut out = Vec::new();
    let mut start = part.start_unit;
    let mut left = part.units;
    while left > grain + grain / 2 {
        out.push(Partition {
            slot: part.slot,
            start_unit: start,
            units: grain,
        });
        start += grain;
        left -= grain;
    }
    out.push(Partition {
        slot: part.slot,
        start_unit: start,
        units: left,
    });
    out
}

/// The decomposition of one execution request across the machine.
#[derive(Clone, Debug)]
pub struct PartitionPlan {
    pub partitions: Vec<Partition>,
    /// Quantum every partition is a multiple of (epu units).
    pub quantum: u64,
    /// Fraction of units that went to GPU slots.
    pub gpu_share: f64,
}

impl PartitionPlan {
    pub fn total_units(&self) -> u64 {
        self.partitions.iter().map(|p| p.units).sum()
    }

    pub fn cpu_units(&self) -> u64 {
        self.partitions
            .iter()
            .filter(|p| p.slot.is_cpu())
            .map(|p| p.units)
            .sum()
    }

    pub fn gpu_units(&self) -> u64 {
        self.total_units() - self.cpu_units()
    }

    /// Non-empty partitions (slots can receive zero units when the workload
    /// is smaller than slots x quantum).
    pub fn active(&self) -> impl Iterator<Item = &Partition> {
        self.partitions.iter().filter(|p| p.units > 0)
    }
}

/// Decomposition inputs: how many parallel executions of each type, their
/// weights, and the CPU/GPU split.
#[derive(Clone, Debug)]
pub struct DecomposeConfig {
    /// Number of CPU fission sub-devices participating.
    pub cpu_subdevices: u32,
    /// Overlap factor per GPU (one entry per GPU).
    pub gpu_overlap: Vec<u32>,
    /// Static relative weights per GPU (Section 3.2, SHOC-derived).
    pub gpu_weights: Vec<f64>,
    /// Fraction of units assigned to the CPU device type [0, 1].
    pub cpu_share: f64,
    /// Work-group size used for quantum computation on GPU kernels.
    pub wgs: u32,
    /// Extra granularity from the AOT chunk menu (units per smallest chunk).
    pub chunk_quantum: u64,
}

/// Decompose `total_units` of an SCT's domain across the machine.
///
/// Guarantees (property-tested):
///  * partitions tile [0, total_units) contiguously without gaps/overlap;
///  * every partition size is a multiple of the quantum (the last CPU
///    partition absorbs the remainder when `total_units` itself is not);
///  * the realized GPU share is the closest quantum-aligned value to the
///    requested split.
pub fn decompose(sct: &Sct, total_units: u64, cfg: &DecomposeConfig) -> Result<PartitionPlan> {
    if cfg.gpu_overlap.len() != cfg.gpu_weights.len() {
        return Err(Error::Decompose(
            "gpu_overlap and gpu_weights length mismatch".into(),
        ));
    }
    if !(0.0..=1.0).contains(&cfg.cpu_share) {
        return Err(Error::Decompose(format!(
            "cpu_share {} out of [0,1]",
            cfg.cpu_share
        )));
    }
    let quantum = sct.quantum_units(cfg.wgs).max(1) * cfg.chunk_quantum.max(1)
        / gcd(sct.quantum_units(cfg.wgs).max(1), cfg.chunk_quantum.max(1));
    if total_units == 0 {
        return Err(Error::Decompose("empty workload".into()));
    }

    let n_gpu_slots: u32 = cfg.gpu_overlap.iter().sum();
    let has_gpu = n_gpu_slots > 0;
    let has_cpu = cfg.cpu_subdevices > 0;
    if !has_gpu && !has_cpu {
        return Err(Error::Decompose("no execution slots".into()));
    }

    // Round the CPU total to the quantum grid.
    let cpu_share = if has_gpu { cfg.cpu_share } else { 1.0 };
    let gpu_share = if has_cpu { 1.0 - cpu_share } else { 1.0 };
    let mut gpu_total = round_to(total_units as f64 * gpu_share, quantum);
    gpu_total = gpu_total.min(total_units / quantum * quantum);
    let cpu_total = total_units - gpu_total;

    let mut partitions = Vec::new();
    let mut cursor = 0u64;

    // GPU partitions first (matches the paper's tables: GPU gets the head
    // of the domain), split per device by the static weights, then evenly
    // across that device's overlap slots.
    if has_gpu && gpu_total > 0 {
        let mut remaining = gpu_total;
        // The remainder-absorbing device must be able to hold units: the
        // last GPU *with overlap slots*. A trailing GPU masked out by a
        // reservation projection (overlap 0, DESIGN.md §2.8) has no slots
        // to place the residue on — routing it there would silently drop
        // the tail of the domain.
        let last_active = cfg.gpu_overlap.iter().rposition(|&o| o > 0);
        for (g, (&overlap, &weight)) in
            cfg.gpu_overlap.iter().zip(&cfg.gpu_weights).enumerate()
        {
            let dev_units = if overlap == 0 {
                0
            } else if Some(g) == last_active {
                remaining
            } else {
                round_to(gpu_total as f64 * weight, quantum).min(remaining)
            };
            remaining -= dev_units;
            // Split across overlap slots on the quantum grid.
            let mut left = dev_units;
            for slot in 0..overlap {
                let share = if slot + 1 == overlap {
                    left
                } else {
                    round_to(dev_units as f64 / overlap as f64, quantum).min(left)
                };
                partitions.push(Partition {
                    slot: ExecSlot::GpuSlot {
                        gpu: g as u32,
                        slot,
                    },
                    start_unit: cursor,
                    units: share,
                });
                cursor += share;
                left -= share;
            }
        }
    }

    // CPU partitions: even quantum-aligned split across sub-devices; the
    // last sub-device absorbs the remainder (including any sub-quantum tail
    // of the whole domain).
    if has_cpu {
        let mut left = cpu_total;
        for idx in 0..cfg.cpu_subdevices {
            let share = if idx + 1 == cfg.cpu_subdevices {
                left
            } else {
                round_to(cpu_total as f64 / cfg.cpu_subdevices as f64, quantum).min(left)
            };
            partitions.push(Partition {
                slot: ExecSlot::CpuSub { idx },
                start_unit: cursor,
                units: share,
            });
            cursor += share;
            left -= share;
        }
    } else if cpu_total > 0 {
        return Err(Error::Decompose(
            "workload residue with no CPU sub-devices".into(),
        ));
    }

    debug_assert_eq!(cursor, total_units);
    let plan = PartitionPlan {
        gpu_share: gpu_total as f64 / total_units as f64,
        partitions,
        quantum,
    };
    Ok(plan)
}

fn round_to(x: f64, q: u64) -> u64 {
    let q = q.max(1);
    ((x / q as f64).round() as u64) * q
}

fn gcd(a: u64, b: u64) -> u64 {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sct::{KernelSpec, ParamSpec, Sct};
    use crate::util::propcheck::forall;

    fn line_sct() -> Sct {
        // Line-partitioned kernel (epu spans many elements): quantum 1.
        Sct::kernel(KernelSpec::new(
            "filter_pipeline",
            vec![ParamSpec::VecIn],
            2048,
        ))
    }

    fn cfg(cpu_subs: u32, overlaps: Vec<u32>, cpu_share: f64, chunk_q: u64) -> DecomposeConfig {
        let n = overlaps.len();
        DecomposeConfig {
            cpu_subdevices: cpu_subs,
            gpu_overlap: overlaps,
            gpu_weights: vec![1.0 / n.max(1) as f64; n],
            cpu_share,
            wgs: 256,
            chunk_quantum: chunk_q,
        }
    }

    #[test]
    fn tiles_domain_exactly() {
        let plan = decompose(&line_sct(), 2048, &cfg(6, vec![4], 0.25, 8)).unwrap();
        assert_eq!(plan.total_units(), 2048);
        // Contiguous coverage.
        let mut cursor = 0;
        for p in &plan.partitions {
            assert_eq!(p.start_unit, cursor);
            cursor += p.units;
        }
        assert_eq!(cursor, 2048);
    }

    #[test]
    fn respects_requested_share_on_quantum_grid() {
        let plan = decompose(&line_sct(), 4096, &cfg(6, vec![4], 0.25, 8)).unwrap();
        let realized_cpu = plan.cpu_units() as f64 / 4096.0;
        assert!((realized_cpu - 0.25).abs() < 8.0 * 2.0 / 4096.0);
    }

    #[test]
    fn cpu_only_when_no_gpus() {
        let plan = decompose(&line_sct(), 1024, &cfg(32, vec![], 0.0, 8)).unwrap();
        assert_eq!(plan.cpu_units(), 1024);
        assert_eq!(plan.gpu_share, 0.0);
        assert_eq!(plan.partitions.len(), 32);
    }

    #[test]
    fn gpu_only_when_share_zero() {
        let plan = decompose(&line_sct(), 1024, &cfg(6, vec![4], 0.0, 8)).unwrap();
        assert_eq!(plan.gpu_units(), 1024);
        // CPU slots still present but empty.
        assert!(plan
            .partitions
            .iter()
            .filter(|p| p.slot.is_cpu())
            .all(|p| p.units == 0));
    }

    #[test]
    fn two_gpu_weights_split() {
        let mut c = cfg(0, vec![2, 2], 0.0, 1);
        c.cpu_subdevices = 0;
        c.gpu_weights = vec![0.75, 0.25];
        let plan = decompose(&line_sct(), 4000, &c).unwrap();
        let g0: u64 = plan
            .partitions
            .iter()
            .filter(|p| matches!(p.slot, ExecSlot::GpuSlot { gpu: 0, .. }))
            .map(|p| p.units)
            .sum();
        assert!((g0 as f64 / 4000.0 - 0.75).abs() < 0.01);
    }

    #[test]
    fn residue_never_routed_to_a_zero_overlap_gpu() {
        // A trailing GPU with no overlap slots (masked out by a
        // reservation projection) must not become the remainder absorber:
        // the whole domain lands on the GPUs that still have slots.
        let c = DecomposeConfig {
            cpu_subdevices: 1,
            gpu_overlap: vec![2, 0],
            gpu_weights: vec![0.5, 0.5],
            cpu_share: 0.0,
            wgs: 256,
            chunk_quantum: 1,
        };
        let plan = decompose(&line_sct(), 1024, &c).unwrap();
        assert_eq!(plan.total_units(), 1024);
        assert!(plan
            .partitions
            .iter()
            .all(|p| !matches!(p.slot, ExecSlot::GpuSlot { gpu: 1, .. })));
        assert_eq!(plan.gpu_units(), 1024);
    }

    #[test]
    fn rejects_bad_inputs() {
        assert!(decompose(&line_sct(), 0, &cfg(1, vec![], 0.0, 1)).is_err());
        assert!(decompose(&line_sct(), 10, &cfg(0, vec![], 0.0, 1)).is_err());
        assert!(decompose(&line_sct(), 10, &cfg(1, vec![1], 1.5, 1)).is_err());
    }

    #[test]
    fn prop_partitions_always_tile_domain() {
        forall(
            0xDEC0,
            300,
            |r| {
                (
                    r.below(1 << 14) + 1,       // total units
                    r.below(32) + 1,            // cpu subdevices
                    r.below(100),               // cpu share %
                )
            },
            |&(total, subs, share)| {
                let c = cfg(subs as u32, vec![4], share as f64 / 100.0, 4);
                let plan = decompose(&line_sct(), total, &c)
                    .map_err(|e| format!("{e}"))?;
                if plan.total_units() != total {
                    return Err(format!(
                        "tiled {} of {total}",
                        plan.total_units()
                    ));
                }
                let mut cursor = 0;
                for p in &plan.partitions {
                    if p.start_unit != cursor {
                        return Err(format!("gap at {cursor}"));
                    }
                    cursor += p.units;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_non_tail_partitions_quantum_aligned() {
        forall(
            0xDEC1,
            300,
            |r| (r.below(1 << 12) + 1, r.below(7) + 1),
            |&(total_q, chunk_q)| {
                // Make total a multiple of quantum so every partition must be
                // aligned.
                let c = cfg(4, vec![2], 0.5, chunk_q);
                let plan = decompose(&line_sct(), total_q * chunk_q, &c)
                    .map_err(|e| format!("{e}"))?;
                for p in plan.partitions.iter() {
                    if p.units % plan.quantum != 0 {
                        return Err(format!(
                            "partition {p:?} not multiple of quantum {}",
                            plan.quantum
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}
