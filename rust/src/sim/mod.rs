//! Device performance simulator — the substitution substrate for the
//! paper's OpenCL CPU/GPU testbeds (DESIGN.md §1.1).
//!
//! The analytic cost model prices each task (an SCT executed over one
//! partition on one execution slot) from the kernel's flop/byte counts and
//! the device description: a roofline term, a cache-locality term driven by
//! the fission level's affinity-domain cache, a NUMA cross-socket penalty,
//! PCIe transfer exposure under overlap, per-launch overheads and global
//! synchronization costs. Multiplicative lognormal noise plus rare straggler
//! events give the execution-time distributions the paper's load-balancing
//! machinery reacts to.

pub mod cost;
pub mod cpuload;
pub mod machine;
pub mod shoc;

pub use cost::{CostParams, SctCost};
pub use cpuload::LoadProfile;
pub use machine::SimMachine;
