//! External CPU load generator (the Fig. 11 experiment).
//!
//! The paper introduces load fluctuation by spawning a configurable number
//! of software threads running a computationally heavy algebraic problem.
//! Here the profile maps a run index to the number of interfering threads;
//! the cost model turns that into a time-sharing multiplier for CPU tasks.

/// Piecewise-constant external load: `(from_run, threads)` steps.
#[derive(Clone, Debug, Default)]
pub struct LoadProfile {
    steps: Vec<(u64, u32)>,
}

impl LoadProfile {
    /// No external load.
    pub fn idle() -> LoadProfile {
        LoadProfile { steps: Vec::new() }
    }

    /// Build from steps; they are sorted by run index.
    pub fn new(mut steps: Vec<(u64, u32)>) -> LoadProfile {
        steps.sort_by_key(|s| s.0);
        LoadProfile { steps }
    }

    /// Step load: `threads` interfering threads from run `from_run` on.
    pub fn step_at(from_run: u64, threads: u32) -> LoadProfile {
        LoadProfile::new(vec![(0, 0), (from_run, threads)])
    }

    /// The raw `(from_run, threads)` steps, for recording a replay trace.
    pub fn steps(&self) -> &[(u64, u32)] {
        &self.steps
    }

    /// Interfering threads at a run index.
    pub fn threads_at(&self, run: u64) -> u32 {
        let mut t = 0;
        for &(from, threads) in &self.steps {
            if run >= from {
                t = threads;
            } else {
                break;
            }
        }
        t
    }

    /// Time-sharing multiplier for CPU tasks at a run index: with `k`
    /// compute-bound interfering threads on `cores` cores, the OS gives the
    /// framework `cores / (cores + k)` of the machine.
    pub fn load_factor(&self, run: u64, cores: u32) -> f64 {
        let k = self.threads_at(run) as f64;
        1.0 + k / cores.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_is_unit_factor() {
        let l = LoadProfile::idle();
        assert_eq!(l.load_factor(100, 6), 1.0);
    }

    #[test]
    fn step_applies_from_run() {
        let l = LoadProfile::step_at(50, 6);
        assert_eq!(l.threads_at(49), 0);
        assert_eq!(l.threads_at(50), 6);
        assert_eq!(l.load_factor(60, 6), 2.0);
    }

    #[test]
    fn multi_step_profile() {
        let l = LoadProfile::new(vec![(0, 0), (10, 3), (20, 0)]);
        assert_eq!(l.threads_at(5), 0);
        assert_eq!(l.threads_at(15), 3);
        assert_eq!(l.threads_at(25), 0);
    }
}
