//! The simulated machine: device descriptions + cost parameters + noise.
//!
//! `SimMachine` prices a full [`PartitionPlan`] execution: per-slot times
//! from the cost model, lognormal noise and straggler events (seeded,
//! reproducible), external CPU load, and the plan-level completion time
//! (max over concurrent slots).

use crate::decompose::{ExecSlot, PartitionPlan};
use crate::platform::cpu::{CpuPlatform, FissionLevel};
use crate::platform::device::Machine;
use crate::platform::gpu::GpuPlatform;
use crate::sim::cost::{self, CostParams, SctCost};
use crate::sim::cpuload::LoadProfile;
use crate::util::rng::Rng;

/// Per-execution simulation outcome.
#[derive(Clone, Debug)]
pub struct SimOutcome {
    /// Time of each parallel execution slot, in plan order (seconds).
    pub slot_times: Vec<f64>,
    /// Completion time of the whole execution (max over slots).
    pub total: f64,
    /// Completion time per device type (max over that type's slots).
    pub cpu_time: f64,
    pub gpu_time: f64,
}

/// The simulated machine state.
pub struct SimMachine {
    pub machine: Machine,
    pub params: CostParams,
    pub load: LoadProfile,
    pub run_index: u64,
    rng: Rng,
}

/// Deterministic per-partition cost multiplier for irregular kernels: a
/// pure hash of the partition index mapped to a uniform with mean 1 and
/// standard deviation `cv` (floored away from zero). Being a function of
/// the index alone — never the noise stream — the same plan prices the
/// same skew on every run: the imbalance models a property of the *data*,
/// so replay and seed-reproducibility are untouched.
fn chunk_skew(index: usize, cv: f64) -> f64 {
    let mut z = (index as u64).wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^= z >> 31;
    let u = (z >> 11) as f64 / (1u64 << 53) as f64;
    // Uniform on [1-a, 1+a] has std a/sqrt(3): a = sqrt(3)*cv gives std cv.
    (1.0 + (2.0 * u - 1.0) * 3f64.sqrt() * cv).max(0.05)
}

impl SimMachine {
    pub fn new(machine: Machine, seed: u64) -> SimMachine {
        SimMachine {
            machine,
            params: CostParams::default(),
            load: LoadProfile::idle(),
            run_index: 0,
            rng: Rng::new(seed),
        }
    }

    pub fn with_params(mut self, params: CostParams) -> SimMachine {
        self.params = params;
        self
    }

    /// A noise-free simulated machine ([`CostParams::quiet`]): pricing is
    /// a pure function of (plan, cost, config), so repeated runs agree to
    /// the bit. The standard base for deterministic tests and propchecks.
    pub fn quiet(machine: Machine, seed: u64) -> SimMachine {
        SimMachine::new(machine, seed).with_params(CostParams::quiet())
    }

    pub fn with_load(mut self, load: LoadProfile) -> SimMachine {
        self.load = load;
        self
    }

    pub fn cpu_platform(&self) -> CpuPlatform {
        CpuPlatform::new(self.machine.cpu.clone())
    }

    pub fn gpu_platform(&self, idx: usize) -> GpuPlatform {
        GpuPlatform::new(self.machine.gpus[idx].clone())
    }

    /// Price one execution of `plan` under fission `level`, GPU occupancy
    /// `occ` and per-GPU overlap factors, advancing the run index and the
    /// noise stream.
    pub fn execute(
        &mut self,
        plan: &PartitionPlan,
        cost: &SctCost,
        level: FissionLevel,
        occ: f64,
        gpu_overlap: &[u32],
        chunk_units: u64,
    ) -> SimOutcome {
        let run = self.run_index;
        self.run_index += 1;
        let cpu_plat = self.cpu_platform();
        let sub = cpu_plat.subdevice(level);
        let load_factor = self
            .load
            .load_factor(run, self.machine.cpu.total_cores());

        let n_slots = plan.partitions.iter().filter(|p| p.units > 0).count() as u32;

        // A GPU's overlap slots share one device and one PCIe link: the
        // device is priced once over its total units (the multi-buffered
        // pipeline), and each of its slots observes the device time.
        let mut gpu_units = vec![0u64; self.machine.gpus.len()];
        for part in &plan.partitions {
            if let ExecSlot::GpuSlot { gpu, .. } = part.slot {
                gpu_units[gpu as usize] += part.units;
            }
        }
        // Data-dependent cost skew (ROADMAP item 4): partitions of an
        // irregular kernel (chunk_cv > 0) each carry a deterministic cost
        // multiplier. CPU slots see their own skew — genuine imbalance the
        // steal pricing must absorb. A GPU averages the skew of its
        // partitions, units-weighted (SIMT divergence amortizes across the
        // whole device's occupancy). chunk_cv == 0 keeps every multiplier
        // at exactly 1.0 and consumes nothing from the noise stream.
        let skewed = cost.chunk_cv > 0.0;
        let mut gpu_skew = vec![1.0f64; self.machine.gpus.len()];
        if skewed {
            let mut weighted = vec![0.0f64; self.machine.gpus.len()];
            for (i, part) in plan.partitions.iter().enumerate() {
                if let ExecSlot::GpuSlot { gpu, .. } = part.slot {
                    weighted[gpu as usize] +=
                        part.units as f64 * chunk_skew(i, cost.chunk_cv);
                }
            }
            for (g, w) in weighted.iter().enumerate() {
                if gpu_units[g] > 0 {
                    gpu_skew[g] = w / gpu_units[g] as f64;
                }
            }
        }
        let gpu_dev_time: Vec<f64> = gpu_units
            .iter()
            .enumerate()
            .map(|(g, &units)| {
                let overlap = gpu_overlap.get(g).copied().unwrap_or(1);
                let base = cost::gpu_partition_time(
                    units,
                    &self.machine.gpus[g],
                    cost,
                    &self.params,
                    occ,
                    overlap,
                    chunk_units,
                );
                base * gpu_skew[g] * self.rng.lognormal(self.params.gpu_noise)
            })
            .collect();

        let mut slot_times = Vec::with_capacity(plan.partitions.len());
        let (mut cpu_t, mut gpu_t) = (0.0f64, 0.0f64);
        for (i, part) in plan.partitions.iter().enumerate() {
            if part.units == 0 {
                slot_times.push(0.0);
                continue;
            }
            let t = match part.slot {
                ExecSlot::CpuSub { .. } => {
                    let base = cost::cpu_partition_time(
                        part.units,
                        &sub,
                        &self.machine.cpu,
                        cost,
                        &self.params,
                        load_factor,
                        chunk_units,
                        n_slots,
                    );
                    let mut noise = self.rng.lognormal(self.params.cpu_noise);
                    if self.rng.chance(self.params.straggler_p) {
                        noise *= self.params.straggler_mult;
                    }
                    let skew = if skewed {
                        chunk_skew(i, cost.chunk_cv)
                    } else {
                        1.0
                    };
                    base * skew * noise
                }
                ExecSlot::GpuSlot { gpu, .. } => gpu_dev_time[gpu as usize],
            };
            if part.slot.is_cpu() {
                cpu_t = cpu_t.max(t);
            } else {
                gpu_t = gpu_t.max(t);
            }
            slot_times.push(t);
        }
        // Global-sync loops: when CPU sub-devices participate, every
        // iteration gates on the host barrier + state re-broadcast across
        // the (slow, time-shared) CPU slots — the reason Table 3 assigns
        // NBody 100% to the GPUs.
        let cpu_participates = plan
            .partitions
            .iter()
            .any(|p| p.slot.is_cpu() && p.units > 0);
        if cpu_participates && cost.sync_points > 0 {
            let barrier =
                self.params.cpu_loop_sync_ms * 1e-3 * cost.iter_factor * load_factor;
            cpu_t += barrier;
            if gpu_t > 0.0 {
                gpu_t += barrier;
            }
        }
        SimOutcome {
            total: cpu_t.max(gpu_t),
            cpu_time: cpu_t,
            gpu_time: gpu_t,
            slot_times,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decompose::{decompose, DecomposeConfig};
    use crate::platform::device::i7_hd7950;
    use crate::sct::{KernelSpec, ParamSpec, Sct};

    fn saxpy_sct() -> Sct {
        let mut k = KernelSpec::new("saxpy", vec![ParamSpec::VecIn], 1);
        k.flops_per_unit = 2.0;
        k.bytes_per_unit = 12.0;
        Sct::kernel(k)
    }

    fn plan(total: u64, cpu_share: f64) -> crate::decompose::PartitionPlan {
        decompose(
            &saxpy_sct(),
            total,
            &DecomposeConfig {
                cpu_subdevices: 6,
                gpu_overlap: vec![4],
                gpu_weights: vec![1.0],
                cpu_share,
                wgs: 256,
                chunk_quantum: 4096,
            },
        )
        .unwrap()
    }

    #[test]
    fn outcome_reproducible_per_seed() {
        let p = plan(1 << 22, 0.25);
        let cost = SctCost::from_sct(&saxpy_sct(), 0.0);
        let mut a = SimMachine::new(i7_hd7950(1), 7);
        let mut b = SimMachine::new(i7_hd7950(1), 7);
        let oa = a.execute(&p, &cost, FissionLevel::L2, 1.0, &[4], 4096);
        let ob = b.execute(&p, &cost, FissionLevel::L2, 1.0, &[4], 4096);
        assert_eq!(oa.slot_times, ob.slot_times);
    }

    #[test]
    fn total_is_max_of_device_types() {
        let p = plan(1 << 22, 0.25);
        let cost = SctCost::from_sct(&saxpy_sct(), 0.0);
        let mut m = SimMachine::new(i7_hd7950(1), 1);
        let o = m.execute(&p, &cost, FissionLevel::L2, 1.0, &[4], 4096);
        assert!((o.total - o.cpu_time.max(o.gpu_time)).abs() < 1e-15);
        assert!(o.cpu_time > 0.0 && o.gpu_time > 0.0);
    }

    #[test]
    fn external_load_slows_cpu_only() {
        let p = plan(1 << 22, 0.5);
        let cost = SctCost::from_sct(&saxpy_sct(), 0.0);
        let mut idle = SimMachine::new(i7_hd7950(1), 3);
        let mut busy =
            SimMachine::new(i7_hd7950(1), 3).with_load(LoadProfile::step_at(0, 6));
        let oi = idle.execute(&p, &cost, FissionLevel::L2, 1.0, &[4], 4096);
        let ob = busy.execute(&p, &cost, FissionLevel::L2, 1.0, &[4], 4096);
        assert!(ob.cpu_time > oi.cpu_time * 1.8);
        assert!((ob.gpu_time / oi.gpu_time - 1.0).abs() < 0.1);
    }

    #[test]
    fn chunk_skew_spreads_cpu_slots_deterministically() {
        let p = plan(1 << 22, 0.5);
        let mut cost = SctCost::from_sct(&saxpy_sct(), 0.0);
        cost.chunk_cv = 0.6;
        let price = || {
            let mut m = SimMachine::quiet(i7_hd7950(1), 9);
            m.execute(&p, &cost, FissionLevel::L2, 1.0, &[4], 4096)
        };
        let (oa, ob) = (price(), price());
        // Skew is a pure function of the partition index: bit-identical
        // across runs even though it spreads the quiet CPU slot times.
        assert_eq!(oa.slot_times, ob.slot_times);
        let cpu_times: Vec<f64> = p
            .partitions
            .iter()
            .zip(&oa.slot_times)
            .filter(|(part, _)| part.slot.is_cpu() && part.units > 0)
            .map(|(_, &t)| t)
            .collect();
        let (min, max) = cpu_times
            .iter()
            .fold((f64::INFINITY, 0.0f64), |(lo, hi), &t| {
                (lo.min(t), hi.max(t))
            });
        assert!(
            max > min * 1.2,
            "cv=0.6 must spread quiet CPU slot times: {min} .. {max}"
        );
        // cv = 0 stays exactly uniform (per-slot times equal under quiet
        // params for equal unit counts) — the regular path is untouched.
        let mut m = SimMachine::quiet(i7_hd7950(1), 9);
        let cost0 = SctCost::from_sct(&saxpy_sct(), 0.0);
        let o0 = m.execute(&p, &cost0, FissionLevel::L2, 1.0, &[4], 4096);
        let uniform: Vec<f64> = p
            .partitions
            .iter()
            .zip(&o0.slot_times)
            .filter(|(part, _)| part.slot.is_cpu() && part.units > 0)
            .map(|(_, &t)| t)
            .collect();
        for w in uniform.windows(2) {
            assert!((w[0] - w[1]).abs() / w[0].max(1e-30) < 0.1);
        }
    }

    #[test]
    fn run_index_advances() {
        let p = plan(1 << 20, 0.2);
        let cost = SctCost::from_sct(&saxpy_sct(), 0.0);
        let mut m = SimMachine::new(i7_hd7950(1), 5);
        assert_eq!(m.run_index, 0);
        m.execute(&p, &cost, FissionLevel::L2, 1.0, &[4], 4096);
        assert_eq!(m.run_index, 1);
    }
}
