//! SHOC-style install-time calibration (Section 3.2).
//!
//! The paper runs the SHOC benchmark suite at installation time to establish
//! the relative performance of the GPU devices (for the static multi-GPU
//! distribution). Here calibration has two parts:
//!
//!  1. `rank_gpus` orders simulated GPUs by a SHOC-like score combining
//!     peak FLOPS and memory bandwidth (the suite's MaxFlops / DeviceMemory
//!     microbenchmarks).
//!  2. `host_flops_gbps` measures the *actual* host's arithmetic throughput
//!     with a vectorizable f32 kernel. The Real-mode executor reports this
//!     alongside simulated numbers so EXPERIMENTS.md can relate the two
//!     timescales.

use std::time::Instant;

use crate::platform::device::GpuSpec;

/// SHOC-like score: geometric mean of normalized FLOPS and bandwidth.
pub fn shoc_score(gpu: &GpuSpec) -> f64 {
    (gpu.gflops * gpu.mem_bw_gbps).sqrt()
}

/// Derive the static relative-performance weights for a GPU set (the
/// paper's install-time ranking). Weights are written back to
/// `relative_perf` and returned normalized.
pub fn rank_gpus(gpus: &mut [GpuSpec]) -> Vec<f64> {
    let scores: Vec<f64> = gpus.iter().map(shoc_score).collect();
    let total: f64 = scores.iter().sum();
    for (g, s) in gpus.iter_mut().zip(&scores) {
        g.relative_perf = *s;
    }
    scores.iter().map(|s| s / total.max(1e-12)).collect()
}

/// Measure the host's achievable single-thread f32 GFLOPS with a fused
/// multiply-add loop over a small in-cache buffer.
pub fn host_flops_gflops() -> f64 {
    const N: usize = 4096;
    const REPS: usize = 2000;
    let mut a = vec![1.000001f32; N];
    let x = 1.000000119f32;
    let y = 0.0000001f32;
    let start = Instant::now();
    for _ in 0..REPS {
        for v in a.iter_mut() {
            *v = *v * x + y;
        }
    }
    let secs = start.elapsed().as_secs_f64();
    // 2 flops per element per rep; prevent the loop being optimized away.
    let checksum: f32 = a.iter().sum();
    std::hint::black_box(checksum);
    (2.0 * N as f64 * REPS as f64) / secs / 1e9
}

/// Measure host memory streaming bandwidth (GB/s) over a buffer far larger
/// than L2.
pub fn host_stream_gbps() -> f64 {
    const N: usize = 8 << 20; // 32 MiB of f32
    let src = vec![1.0f32; N];
    let mut dst = vec![0.0f32; N];
    let start = Instant::now();
    for _ in 0..4 {
        dst.copy_from_slice(&src);
        std::hint::black_box(&dst);
    }
    let secs = start.elapsed().as_secs_f64();
    (2.0 * 4.0 * (N * 4) as f64) / secs / 1e9 // read + write
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::device::i7_hd7950;

    #[test]
    fn equal_gpus_get_equal_weights() {
        let mut gpus = i7_hd7950(2).gpus;
        let w = rank_gpus(&mut gpus);
        assert!((w[0] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn faster_gpu_ranks_higher() {
        let mut gpus = i7_hd7950(2).gpus;
        gpus[1].gflops *= 4.0;
        gpus[1].mem_bw_gbps *= 4.0;
        let w = rank_gpus(&mut gpus);
        assert!(w[1] > 0.75);
        assert!(gpus[1].relative_perf > gpus[0].relative_perf);
    }

    #[test]
    fn host_microbenches_positive() {
        assert!(host_flops_gflops() > 0.01);
        assert!(host_stream_gbps() > 0.01);
    }
}
