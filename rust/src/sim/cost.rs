//! The analytic cost model (DESIGN.md §1.1 substitution 2).

use crate::platform::cpu::SubDevice;
use crate::platform::device::{CpuSpec, GpuSpec};
use crate::sct::Sct;

/// Tunable model constants. Defaults were calibrated so the regenerated
/// tables land in the paper's qualitative regime (EXPERIMENTS.md records the
/// calibration); `sim::shoc` re-derives the CPU efficiency on the host.
#[derive(Clone, Debug)]
pub struct CostParams {
    /// Achievable fraction of peak CPU FLOPS for OpenCL-style kernels.
    pub cpu_eff: f64,
    /// Achievable fraction of peak CPU memory bandwidth.
    pub cpu_bw_eff: f64,
    /// Cross-socket (NUMA) bandwidth penalty coefficient: traffic of a
    /// sub-device spanning `s` sockets pays `1 + gamma * (1 - 1/s)`.
    pub numa_gamma: f64,
    /// Cross-socket compute penalty: a sub-device spanning `s` sockets loses
    /// FLOPS as `1 + gamma_f * (s - 1)` (thread placement churn, remote
    /// cache-line sharing — why compute-bound NBody also gains from fission).
    pub numa_flops_gamma: f64,
    /// Host-side fork/join dispatch cost per execution, per parallel slot
    /// (µs): many sub-devices make small executions dispatch-bound.
    pub forkjoin_us: f64,
    /// Relative cost of re-traversing a working set that fits the affinity
    /// domain's cache (vs. re-streaming it from DRAM).
    pub cache_repass: f64,
    /// Achievable fraction of peak GPU FLOPS.
    pub gpu_eff: f64,
    /// Compute efficiency at zero occupancy (latency-bound floor).
    pub gpu_occ_floor: f64,
    /// Host-side cost per global synchronization point, per participating
    /// execution slot (µs).
    pub sync_us_per_slot: f64,
    /// Extra per-iteration cost when CPU sub-devices participate in a
    /// global-sync loop (ms): barrier stragglers + host update serialization.
    pub cpu_loop_sync_ms: f64,
    /// Lognormal noise sigma per device type.
    pub cpu_noise: f64,
    pub gpu_noise: f64,
    /// Straggler events: probability and multiplier (CPU only; time-shared).
    pub straggler_p: f64,
    pub straggler_mult: f64,
}

impl Default for CostParams {
    fn default() -> Self {
        CostParams {
            cpu_eff: 0.32,
            cpu_bw_eff: 0.055,
            numa_gamma: 2.4,
            numa_flops_gamma: 0.55,
            forkjoin_us: 4.0,
            cache_repass: 0.12,
            gpu_eff: 0.55,
            gpu_occ_floor: 0.35,
            sync_us_per_slot: 60.0,
            cpu_loop_sync_ms: 10.0,
            cpu_noise: 0.025,
            gpu_noise: 0.010,
            straggler_p: 0.004,
            straggler_mult: 1.25,
        }
    }
}

impl CostParams {
    /// The calibrated model with every stochastic term zeroed (no lognormal
    /// noise, no stragglers) — deterministic simulated timings for tests
    /// that assert on exact schedules or reproducible balance decisions.
    pub fn quiet() -> CostParams {
        CostParams {
            cpu_noise: 0.0,
            gpu_noise: 0.0,
            straggler_p: 0.0,
            ..CostParams::default()
        }
    }
}

/// Aggregated cost profile of one SCT execution request, per epu unit.
/// Iteration factors (Loop) are folded in at aggregation time.
#[derive(Clone, Debug)]
pub struct SctCost {
    /// FLOPs per unit across all kernel leaves x loop iterations.
    pub flops_per_unit: f64,
    /// Bytes touched per unit per traversal.
    pub bytes_per_unit: f64,
    /// Number of working-set traversals (kernel passes x iterations).
    pub passes: f64,
    /// Host<->device bytes per unit (partitioned vectors, in + out).
    pub transfer_bytes_per_unit: f64,
    /// COPY-mode bytes replicated to each device, per transfer event.
    pub copy_bytes: f64,
    /// Global synchronization points per execution.
    pub sync_points: u32,
    /// Loop iteration multiplier (for per-iteration costs).
    pub iter_factor: f64,
    /// Per-chunk cost coefficient of variation (max over kernel leaves).
    /// 0 for regular kernels; irregular kernels (sparse rows, frontier
    /// expansion, escape iteration) spread per-partition cost around the
    /// mean, which SimMachine::execute turns into deterministic per-slot
    /// skew so stealing sees genuine imbalance.
    pub chunk_cv: f64,
}

impl SctCost {
    /// Aggregate the cost profile of an SCT from its kernel metadata.
    /// `copy_bytes` is the total size of COPY-mode vectors in the request.
    pub fn from_sct(sct: &Sct, copy_bytes: f64) -> SctCost {
        let iter = sct.iteration_factor();
        let kernels = sct.kernels();
        let flops: f64 = kernels.iter().map(|k| k.flops_per_unit).sum();
        let bytes: f64 = kernels
            .iter()
            .map(|k| k.bytes_per_unit)
            .fold(0.0, f64::max);
        let passes: f64 = kernels.iter().map(|k| k.passes).sum();
        let chunk_cv: f64 = kernels.iter().map(|k| k.chunk_cv).fold(0.0, f64::max);
        SctCost {
            flops_per_unit: flops * iter,
            bytes_per_unit: bytes,
            passes: passes * iter,
            transfer_bytes_per_unit: bytes, // in + out approximated by max pass
            copy_bytes,
            sync_points: sct.sync_points(),
            iter_factor: iter,
            chunk_cv,
        }
    }

    /// Per-stage cost profiles, one per kernel leaf in execution order —
    /// what a *barrier* drain prices stage by stage (DESIGN.md §2.7: the
    /// dataflow drain overlaps stages, so it prices the aggregate instead).
    ///
    /// The stage costs partition the aggregate: per-stage flops/passes carry
    /// the leaf's own loop-iteration multiplier, host<->device transfer is
    /// split evenly across stages (intermediates stay device-resident, so
    /// only the domain crosses the link once in and once out), and the
    /// COPY re-broadcast plus every global sync point land on the last
    /// stage — a global sync gates the whole iteration, not one kernel.
    pub fn stage_costs(sct: &Sct, copy_bytes: f64) -> Vec<SctCost> {
        fn collect(sct: &Sct, mult: f64, out: &mut Vec<(f64, f64, f64)>) {
            match sct {
                Sct::Kernel(k) => {
                    out.push((k.flops_per_unit * mult, k.bytes_per_unit, k.passes * mult))
                }
                Sct::Pipeline(stages) => {
                    for s in stages {
                        collect(s, mult, out);
                    }
                }
                Sct::Loop { body, state } => {
                    collect(body, mult * state.max_iters as f64, out)
                }
                Sct::Map(t) => collect(t, mult, out),
                Sct::MapReduce { map, reduce } => {
                    collect(map, mult, out);
                    if let crate::sct::Reduction::Device { kernel, .. } = reduce {
                        out.push((
                            kernel.flops_per_unit * mult,
                            kernel.bytes_per_unit,
                            kernel.passes * mult,
                        ));
                    }
                }
            }
        }
        let full = SctCost::from_sct(sct, copy_bytes);
        let mut leaves = Vec::new();
        collect(sct, 1.0, &mut leaves);
        let n = leaves.len().max(1);
        leaves
            .iter()
            .enumerate()
            .map(|(i, &(flops, bytes, passes))| {
                let last = i + 1 == n;
                SctCost {
                    flops_per_unit: flops,
                    bytes_per_unit: bytes,
                    passes,
                    transfer_bytes_per_unit: full.transfer_bytes_per_unit / n as f64,
                    copy_bytes: if last { full.copy_bytes } else { 0.0 },
                    sync_points: if last { full.sync_points } else { 0 },
                    iter_factor: full.iter_factor,
                    chunk_cv: full.chunk_cv,
                }
            })
            .collect()
    }
}

/// Time (seconds, noise-free) for a CPU sub-device to execute `units` of the
/// SCT. `load_factor >= 1` scales for external CPU load (time sharing);
/// `chunk_units` is the AOT chunk granularity (per-launch overhead);
/// `n_slots` is the execution's total parallel-slot count (fork/join cost).
#[allow(clippy::too_many_arguments)]
pub fn cpu_partition_time(
    units: u64,
    sub: &SubDevice,
    cpu: &CpuSpec,
    cost: &SctCost,
    p: &CostParams,
    load_factor: f64,
    chunk_units: u64,
    n_slots: u32,
) -> f64 {
    if units == 0 {
        return 0.0;
    }
    let u = units as f64;
    let flops_pen = 1.0 + p.numa_flops_gamma * (sub.sockets_spanned as f64 - 1.0);
    let flops_t = u * cost.flops_per_unit * flops_pen
        / (sub.cores as f64
            * cpu.gflops_per_core
            * 1e9
            * p.cpu_eff
            * sub.compute_factor);

    let bw_share = cpu.mem_bw_gbps * 1e9 * p.cpu_bw_eff * sub.bw_factor * sub.cores as f64
        / cpu.total_cores() as f64;
    let numa_pen = 1.0 + p.numa_gamma * (1.0 - 1.0 / sub.sockets_spanned as f64);
    let ws = u * cost.bytes_per_unit;
    // Re-traversals hit cache if the working set fits the affinity domain.
    let repass = if ws <= (sub.cache_kib * 1024) as f64 {
        p.cache_repass
    } else {
        1.0
    };
    let traffic = ws * (1.0 + (cost.passes - 1.0).max(0.0) * repass);
    let mem_t = traffic * numa_pen / bw_share;

    // One clEnqueueNDRange per kernel pass over the partition: chunked
    // launches are an artifact of the Real-mode AOT menu, not of the
    // simulated OpenCL testbed.
    let _ = chunk_units;
    let launches = cost.passes.max(1.0);
    let overhead = cpu.launch_overhead_us * 1e-6 * launches
        + p.forkjoin_us * 1e-6 * n_slots as f64;
    // Note: the global-sync barrier penalty for CPU participation in a
    // Loop is charged at the machine level (it gates every device's
    // iteration, not just the CPU slot) — see SimMachine::execute.
    let sync = p.sync_us_per_slot * 1e-6 * cost.sync_points as f64;

    (flops_t.max(mem_t) + overhead + sync) * load_factor
}

/// Time (seconds, noise-free) for one GPU overlap slot to execute `units`.
/// `occ` is the kernel occupancy at the chosen work-group size; `overlap`
/// the device's overlap factor (hides (o-1)/o of PCIe transfer).
#[allow(clippy::too_many_arguments)]
pub fn gpu_partition_time(
    units: u64,
    gpu: &GpuSpec,
    cost: &SctCost,
    p: &CostParams,
    occ: f64,
    overlap: u32,
    chunk_units: u64,
) -> f64 {
    if units == 0 {
        return 0.0;
    }
    let u = units as f64;
    let occ_eff = p.gpu_occ_floor + (1.0 - p.gpu_occ_floor) * occ.clamp(0.0, 1.0);
    let comp = u * cost.flops_per_unit / (gpu.gflops * 1e9 * p.gpu_eff * occ_eff);
    let mem = u * cost.bytes_per_unit * cost.passes / (gpu.mem_bw_gbps * 1e9);

    // PCIe: partition traffic + COPY-mode replication; COPY re-transfers at
    // every global sync (Loop state flows back through the host).
    let copy_events = 1.0 + cost.sync_points as f64;
    let transfer = (u * cost.transfer_bytes_per_unit + cost.copy_bytes * copy_events)
        / (gpu.pcie_gbps * 1e9);

    let _ = chunk_units;
    let launches = cost.passes.max(1.0);
    let overhead = gpu.launch_overhead_us * 1e-6 * launches;
    let sync = p.sync_us_per_slot * 1e-6 * cost.sync_points as f64;

    // Multi-buffered pipeline: overlap hides transfer behind *compute* —
    // communication-bound kernels stay PCIe-bound no matter the overlap
    // (why the CPU boosts Saxpy/Segmentation most, Section 4.2.1).
    let compute = comp.max(mem);
    let o = overlap.max(1) as f64;
    let steady = compute.max(transfer * (o - 1.0) / o);
    steady + transfer / o + overhead + sync
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::cpu::{CpuPlatform, FissionLevel};
    use crate::platform::device::{i7_hd7950, opteron_6272_quad};
    use crate::sct::{KernelSpec, ParamSpec, Sct};

    fn streaming_kernel() -> KernelSpec {
        let mut k = KernelSpec::new("saxpy", vec![ParamSpec::VecIn], 1);
        k.flops_per_unit = 2.0;
        k.bytes_per_unit = 12.0;
        k.passes = 1.0;
        k
    }

    fn compute_kernel() -> KernelSpec {
        let mut k = KernelSpec::new("nbody", vec![ParamSpec::VecCopy], 1);
        k.flops_per_unit = 20.0 * 65536.0;
        k.bytes_per_unit = 16.0;
        k.passes = 1.0;
        k
    }

    #[test]
    fn fission_beats_no_fission_for_streaming_on_numa() {
        // Table 2 shape: memory-bound kernels gain from fission on the
        // 4-socket Opteron because NoFission pays cross-socket traffic.
        let m = opteron_6272_quad();
        let plat = CpuPlatform::new(m.cpu.clone());
        let cost = SctCost::from_sct(&Sct::kernel(streaming_kernel()), 0.0);
        let p = CostParams::default();
        let u = 10_000_000;

        // Whole-device time at a level = max over subdevices of per-sub time
        // with an even split.
        let t = |level: FissionLevel| {
            let n = plat.subdevice_count(level) as u64;
            let sub = plat.subdevice(level);
            cpu_partition_time(u / n, &sub, &m.cpu, &cost, &p, 1.0, 4096, n as u32)
        };
        assert!(
            t(FissionLevel::L2) < t(FissionLevel::NoFission) / 2.0,
            "L2={} none={}",
            t(FissionLevel::L2),
            t(FissionLevel::NoFission)
        );
    }

    #[test]
    fn cache_fit_rewards_repasses() {
        // A 3-pass kernel over a small working set should run faster on a
        // fission level whose cache holds the partition.
        let m = opteron_6272_quad();
        let plat = CpuPlatform::new(m.cpu.clone());
        let mut k = streaming_kernel();
        k.passes = 3.0;
        let cost = SctCost::from_sct(&Sct::kernel(k), 0.0);
        let p = CostParams::default();
        // 64 KiB partition fits the 2 MiB L2 domain; compare against a
        // cache-free variant by scaling bytes.
        let sub = plat.subdevice(FissionLevel::L2);
        let units = 5_000; // x12 B = 60 KB < 2 MiB
        let t_fit = cpu_partition_time(units, &sub, &m.cpu, &cost, &p, 1.0, 4096, 32);
        let mut sub_nocache = sub;
        sub_nocache.cache_kib = 1; // force misses
        let t_miss = cpu_partition_time(units, &sub_nocache, &m.cpu, &cost, &p, 1.0, 4096, 32);
        assert!(t_fit < t_miss);
    }

    #[test]
    fn gpu_overlap_hides_transfer() {
        let m = i7_hd7950(1);
        let cost = SctCost::from_sct(&Sct::kernel(streaming_kernel()), 0.0);
        let p = CostParams::default();
        let t1 = gpu_partition_time(1 << 22, &m.gpus[0], &cost, &p, 1.0, 1, 4096);
        let t4 = gpu_partition_time(1 << 22, &m.gpus[0], &cost, &p, 1.0, 4, 4096);
        assert!(t4 < t1, "overlap must reduce exposed transfer");
    }

    #[test]
    fn occupancy_scales_gpu_compute() {
        let m = i7_hd7950(1);
        let cost = SctCost::from_sct(&Sct::kernel(compute_kernel()), 1024.0 * 1024.0);
        let p = CostParams::default();
        let hi = gpu_partition_time(4096, &m.gpus[0], &cost, &p, 1.0, 4, 256);
        let lo = gpu_partition_time(4096, &m.gpus[0], &cost, &p, 0.2, 4, 256);
        assert!(hi < lo);
    }

    #[test]
    fn load_factor_scales_cpu_time() {
        let m = i7_hd7950(1);
        let plat = CpuPlatform::new(m.cpu.clone());
        let sub = plat.subdevice(FissionLevel::L2);
        let cost = SctCost::from_sct(&Sct::kernel(streaming_kernel()), 0.0);
        let p = CostParams::default();
        let t1 = cpu_partition_time(1 << 20, &sub, &m.cpu, &cost, &p, 1.0, 4096, 6);
        let t2 = cpu_partition_time(1 << 20, &sub, &m.cpu, &cost, &p, 2.0, 4096, 6);
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn loop_sync_charged_at_machine_level() {
        // NBody shape (Table 3): the global-sync loop barrier is charged at
        // the machine level when CPU sub-devices participate, gating every
        // device's iterations — see SimMachine::execute. Here we check the
        // cost profile carries the hooks the machine needs, and that the
        // per-slot time still includes the per-sync host cost.
        let sct = Sct::for_loop(Sct::kernel(compute_kernel()), 50, true);
        let cost = SctCost::from_sct(&sct, 65536.0 * 16.0);
        assert_eq!(cost.sync_points, 50);
        assert_eq!(cost.iter_factor, 50.0);
        let m = i7_hd7950(1);
        let plat = CpuPlatform::new(m.cpu.clone());
        let sub = plat.subdevice(FissionLevel::L2);
        let p = CostParams::default();
        let t_small = cpu_partition_time(64, &sub, &m.cpu, &cost, &p, 1.0, 256, 10);
        assert!(t_small > p.sync_us_per_slot * 1e-6 * 50.0 * 0.9);
    }

    #[test]
    fn stage_costs_partition_the_aggregate() {
        // 3-stage pipeline: per-stage flops/passes must sum to the
        // aggregate, transfer must split evenly, and the global-sync /
        // COPY terms must land on the last stage only.
        let mut a = streaming_kernel();
        a.family = "a".into();
        let mut b = streaming_kernel();
        b.family = "b".into();
        b.flops_per_unit = 8.0;
        let sct = Sct::for_loop(
            Sct::pipeline(vec![Sct::kernel(a), Sct::kernel(b)]),
            5,
            true,
        );
        let full = SctCost::from_sct(&sct, 1024.0);
        let stages = SctCost::stage_costs(&sct, 1024.0);
        assert_eq!(stages.len(), 2);
        let flops: f64 = stages.iter().map(|s| s.flops_per_unit).sum();
        assert!((flops - full.flops_per_unit).abs() < 1e-9);
        let transfer: f64 = stages.iter().map(|s| s.transfer_bytes_per_unit).sum();
        assert!((transfer - full.transfer_bytes_per_unit).abs() < 1e-9);
        assert_eq!(stages[0].sync_points, 0);
        assert_eq!(stages[1].sync_points, full.sync_points);
        assert_eq!(stages[0].copy_bytes, 0.0);
        assert_eq!(stages[1].copy_bytes, full.copy_bytes);
        assert_eq!(stages[0].iter_factor, 5.0);
        // A single-kernel tree yields one stage equal to the aggregate.
        let single = SctCost::stage_costs(&Sct::kernel(streaming_kernel()), 0.0);
        assert_eq!(single.len(), 1);
        assert!((single[0].flops_per_unit
            - SctCost::from_sct(&Sct::kernel(streaming_kernel()), 0.0).flops_per_unit)
            .abs()
            < 1e-9);
    }

    #[test]
    fn chunk_cv_aggregates_by_max_and_propagates_to_stages() {
        let mut a = streaming_kernel();
        a.family = "a".into();
        a.chunk_cv = 0.3;
        let mut b = streaming_kernel();
        b.family = "b".into();
        b.chunk_cv = 0.8;
        let sct = Sct::pipeline(vec![Sct::kernel(a), Sct::kernel(b)]);
        let full = SctCost::from_sct(&sct, 0.0);
        assert_eq!(full.chunk_cv, 0.8);
        for s in SctCost::stage_costs(&sct, 0.0) {
            assert_eq!(s.chunk_cv, 0.8);
        }
        // Regular kernels stay variance-free.
        let reg = SctCost::from_sct(&Sct::kernel(streaming_kernel()), 0.0);
        assert_eq!(reg.chunk_cv, 0.0);
    }

    #[test]
    fn zero_units_cost_nothing() {
        let m = i7_hd7950(1);
        let plat = CpuPlatform::new(m.cpu.clone());
        let sub = plat.subdevice(FissionLevel::L1);
        let cost = SctCost::from_sct(&Sct::kernel(streaming_kernel()), 0.0);
        let p = CostParams::default();
        assert_eq!(
            cpu_partition_time(0, &sub, &m.cpu, &cost, &p, 1.0, 4096, 6),
            0.0
        );
        assert_eq!(
            gpu_partition_time(0, &m.gpus[0], &cost, &p, 1.0, 4, 4096),
            0.0
        );
    }
}
