//! Dynamic load balancing (Section 3.3): execution monitoring, the
//! load-balancing threshold `lbt`, and the Adaptive Binary Search that
//! shifts work between device types under load fluctuations.

pub mod abs;
pub mod monitor;

pub use abs::AdaptiveBinarySearch;
pub use monitor::{BalanceStatus, Monitor};

use crate::error::Result;
use crate::scheduler::ExecEnv;
use crate::sct::Sct;
use crate::tuner::profile::FrameworkConfig;

/// The load-balancing process (box "Adjust workload distribution"):
/// monitors executions of a fixed (SCT, workload) under a configuration,
/// and when the monitor triggers, runs the adaptive binary search to move
/// load from the worst to the best performing device type.
pub struct LoadBalancer {
    pub monitor: Monitor,
    pub abs: AdaptiveBinarySearch,
    /// Number of times the balancing process was triggered.
    pub balance_ops: u32,
    /// Number of executions observed as unbalanced.
    pub unbalanced_runs: u32,
}

impl LoadBalancer {
    pub fn new(max_dev: f64, initial_share: f64) -> LoadBalancer {
        LoadBalancer {
            monitor: Monitor::new(max_dev),
            abs: AdaptiveBinarySearch::new(initial_share),
            balance_ops: 0,
            unbalanced_runs: 0,
        }
    }

    /// Run one execution and adapt if needed. Returns the (possibly updated)
    /// configuration and the observed outcome.
    pub fn step<E: ExecEnv>(
        &mut self,
        env: &mut E,
        sct: &Sct,
        total_units: u64,
        cfg: &mut FrameworkConfig,
    ) -> Result<crate::scheduler::ExecOutcome> {
        let out = env.execute(sct, total_units, cfg)?;
        let status = self.monitor.observe(&out.slot_times);
        if status.unbalanced {
            self.unbalanced_runs += 1;
        }
        if status.trigger {
            self.balance_ops += 1;
            let new_share = self.abs.propose(out.cpu_time, out.gpu_time);
            cfg.cpu_share = new_share;
            self.monitor.reset_lbt();
        } else {
            self.abs.track(cfg.cpu_share);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::cpu::FissionLevel;
    use crate::platform::device::i7_hd7950;
    use crate::scheduler::SimEnv;
    use crate::sct::{KernelSpec, ParamSpec};
    use crate::sim::cpuload::LoadProfile;
    use crate::sim::machine::SimMachine;

    fn saxpy() -> Sct {
        let mut k = KernelSpec::new("saxpy", vec![ParamSpec::VecIn], 1);
        k.flops_per_unit = 2.0;
        k.bytes_per_unit = 12.0;
        Sct::kernel(k)
    }

    #[test]
    fn stable_load_rarely_triggers() {
        let mut env = SimEnv::new(SimMachine::new(i7_hd7950(1), 3));
        // Balanced starting distribution obtained from the tuner's regime.
        let mut cfg = FrameworkConfig {
            fission: FissionLevel::L2,
            overlap: vec![4],
            wgs: 256,
            cpu_share: 0.25,
        };
        let mut lb = LoadBalancer::new(0.5, cfg.cpu_share);
        for _ in 0..60 {
            lb.step(&mut env, &saxpy(), 1 << 22, &mut cfg).unwrap();
        }
        assert!(
            lb.balance_ops <= 3,
            "stable conditions triggered {} ops",
            lb.balance_ops
        );
    }

    #[test]
    fn load_spike_triggers_rebalance_away_from_cpu() {
        let sim = SimMachine::new(i7_hd7950(1), 11)
            .with_load(LoadProfile::step_at(10, 12));
        let mut env = SimEnv::new(sim);
        let mut cfg = FrameworkConfig {
            fission: FissionLevel::L2,
            overlap: vec![4],
            wgs: 256,
            cpu_share: 0.30,
        };
        let initial = cfg.cpu_share;
        let mut lb = LoadBalancer::new(0.80, cfg.cpu_share);
        for _ in 0..80 {
            lb.step(&mut env, &saxpy(), 1 << 22, &mut cfg).unwrap();
        }
        assert!(lb.balance_ops >= 1, "spike must trigger balancing");
        assert!(
            cfg.cpu_share < initial,
            "share should shrink: {} -> {}",
            initial,
            cfg.cpu_share
        );
    }
}
