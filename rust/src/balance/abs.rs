//! Adaptive Binary Search (Section 3.3.1).
//!
//! A modified binary search for the load-balancing process: transfers a
//! percentage of the workload from the worst to the best performing device
//! type. Unlike the profiling-time search, the optimum may move (the CPUs'
//! load fluctuates), so the inspected interval may *shift sideways*; and to
//! speed up the shifting phase, the transferable partition **doubles** after
//! more than 2 consecutive shifts in the same direction.

/// Adaptive binary search over the CPU share in [0, 1].
#[derive(Clone, Debug)]
pub struct AdaptiveBinarySearch {
    share: f64,
    /// Current transferable fraction (the search step is transferable/2).
    transferable: f64,
    last_dir: i8,
    same_dir_count: u32,
    /// Minimum step (resolution floor).
    pub min_step: f64,
    /// Initial transferable fraction after a (re)start.
    pub initial_transferable: f64,
}

impl AdaptiveBinarySearch {
    pub fn new(current_share: f64) -> AdaptiveBinarySearch {
        AdaptiveBinarySearch {
            share: current_share.clamp(0.0, 1.0),
            transferable: 0.25,
            last_dir: 0,
            same_dir_count: 0,
            min_step: 1.0 / 512.0,
            initial_transferable: 0.25,
        }
    }

    /// Keep the search anchored at an externally-maintained share (no-op
    /// feedback while the system is balanced).
    pub fn track(&mut self, share: f64) {
        self.share = share.clamp(0.0, 1.0);
    }

    /// Propose the next CPU share given the per-device-type completion
    /// times of the last (unbalanced) execution.
    pub fn propose(&mut self, cpu_time: f64, gpu_time: f64) -> f64 {
        // Move work away from the worst performer.
        let dir: i8 = if cpu_time > gpu_time { -1 } else { 1 };
        if dir == self.last_dir {
            self.same_dir_count += 1;
            if self.same_dir_count > 2 {
                // Shifting phase: the optimum left the interval — double the
                // transferable partition to converge towards it faster.
                self.transferable = (self.transferable * 2.0).min(1.0);
            }
        } else {
            // Direction change: back to standard halving.
            self.same_dir_count = 1;
            if self.last_dir != 0 {
                self.transferable = (self.transferable / 2.0).max(self.min_step);
            }
        }
        self.last_dir = dir;
        let step = (self.transferable / 2.0).max(self.min_step);
        self.share = (self.share + dir as f64 * step).clamp(0.0, 1.0);
        self.share
    }

    pub fn share(&self) -> f64 {
        self.share
    }

    pub fn transferable(&self) -> f64 {
        self.transferable
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Simulated environment: completion times for a share under device
    /// rates; optimum at rc/(rc+rg).
    fn times(share: f64, rc: f64, rg: f64) -> (f64, f64) {
        (share / rc, (1.0 - share) / rg)
    }

    #[test]
    fn converges_when_optimum_in_interval() {
        let (rc, rg) = (1.0, 3.0);
        let mut abs = AdaptiveBinarySearch::new(0.4);
        let mut s = 0.4;
        for _ in 0..40 {
            let (ct, gt) = times(s, rc, rg);
            s = abs.propose(ct, gt);
        }
        assert!((s - 0.25).abs() < 0.02, "share {s}");
    }

    #[test]
    fn shifting_phase_doubles_transferable() {
        // Optimum far to the right of the current share: monotone shifts.
        let mut abs = AdaptiveBinarySearch::new(0.05);
        let t0 = abs.transferable();
        for _ in 0..5 {
            abs.propose(0.01, 1.0); // CPU far faster: push share up
        }
        assert!(
            abs.transferable() > t0,
            "transferable should grow in shifting phase"
        );
    }

    #[test]
    fn adapts_to_moved_optimum() {
        // Start balanced at 0.25 for rates (1,3); then CPU loses half its
        // speed (load spike) -> new optimum 1/7 ~ 0.143.
        let mut abs = AdaptiveBinarySearch::new(0.25);
        let mut s = 0.25;
        for _ in 0..12 {
            let (ct, gt) = times(s, 1.0, 3.0);
            s = abs.propose(ct, gt);
        }
        for _ in 0..40 {
            let (ct, gt) = times(s, 0.5, 3.0);
            s = abs.propose(ct, gt);
        }
        assert!((s - 1.0 / 7.0).abs() < 0.04, "share {s}");
    }

    #[test]
    fn clamps_to_unit_interval() {
        let mut abs = AdaptiveBinarySearch::new(0.01);
        for _ in 0..50 {
            let s = abs.propose(10.0, 0.1); // CPU always slower
            assert!((0.0..=1.0).contains(&s));
        }
        assert!(abs.share() < 0.01);
    }
}
