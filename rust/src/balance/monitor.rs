//! Execution monitoring and the load-balancing threshold (Section 3.3).
//!
//! Every SCT execution is monitored: per-slot completion times, their
//! deviation `dev`, and the EWMA threshold
//!
//!   lbt(n) = isUnbalanced(dev) * weight + lbt(n-1) * (1 - weight)
//!
//! with `weight` defaulting to 2/3 — so 3-4 consecutive unbalanced runs are
//! needed for the balancing process to kick in. `dev` is the best/worst
//! completion ratio over the concurrent parallel executions; "balanced"
//! means all executions are within `maxDev` of the best performing one (the
//! Table 4 semantics — see [`crate::util::stats::balance_dev`] for the
//! erratum note on the paper's formula).

use crate::util::stats::{balance_dev, ewma};

/// Default EWMA weight (paper: 2/3).
pub const DEFAULT_WEIGHT: f64 = 2.0 / 3.0;
/// lbt value treated as "~= 1" (trigger region).
pub const TRIGGER_LBT: f64 = 0.95;

/// One observation's verdict.
#[derive(Clone, Copy, Debug)]
pub struct BalanceStatus {
    pub dev: f64,
    pub unbalanced: bool,
    pub lbt: f64,
    /// lbt crossed the trigger region — run the balancing process.
    pub trigger: bool,
}

/// The per-(SCT, workload) execution monitor.
#[derive(Clone, Debug)]
pub struct Monitor {
    /// User-definable bound: executions are balanced when
    /// `dev / c_factor >= max_dev`.
    pub max_dev: f64,
    /// Correction factor for computations that run best slightly unbalanced.
    pub c_factor: f64,
    pub weight: f64,
    lbt: f64,
    /// All observed deviations (statistics output).
    pub devs: Vec<f64>,
}

impl Monitor {
    pub fn new(max_dev: f64) -> Monitor {
        Monitor {
            max_dev,
            c_factor: 1.0,
            weight: DEFAULT_WEIGHT,
            lbt: 0.0,
            devs: Vec::new(),
        }
    }

    /// Observe one execution's per-slot times.
    pub fn observe(&mut self, slot_times: &[f64]) -> BalanceStatus {
        let dev = balance_dev(slot_times);
        self.devs.push(dev);
        let unbalanced = dev / self.c_factor < self.max_dev;
        self.lbt = ewma(self.lbt, if unbalanced { 1.0 } else { 0.0 }, self.weight);
        BalanceStatus {
            dev,
            unbalanced,
            lbt: self.lbt,
            trigger: self.lbt >= TRIGGER_LBT,
        }
    }

    pub fn lbt(&self) -> f64 {
        self.lbt
    }

    /// After a balancing operation the history restarts (the new
    /// distribution deserves a fresh assessment).
    pub fn reset_lbt(&mut self) {
        self.lbt = 0.0;
    }

    /// Minimum observed deviation — Table 4's calibration output: the
    /// largest `maxDev` that would keep all observed runs balanced.
    pub fn min_dev(&self) -> f64 {
        self.devs.iter().copied().fold(f64::INFINITY, f64::min)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_runs_keep_lbt_low() {
        let mut m = Monitor::new(0.85);
        for _ in 0..50 {
            let s = m.observe(&[1.0, 0.99, 0.97, 1.01]);
            assert!(!s.trigger);
        }
        assert!(m.lbt() < 0.1);
    }

    #[test]
    fn three_to_four_consecutive_unbalanced_trigger() {
        let mut m = Monitor::new(0.85);
        let mut triggered_at = None;
        for i in 1..=6 {
            // dev = 0.5 -> clearly unbalanced.
            let s = m.observe(&[1.0, 0.5]);
            if s.trigger {
                triggered_at = Some(i);
                break;
            }
        }
        let at = triggered_at.expect("must trigger");
        assert!((3..=4).contains(&at), "triggered at {at}");
    }

    #[test]
    fn sporadic_unbalance_does_not_trigger() {
        let mut m = Monitor::new(0.85);
        for i in 0..40 {
            let times = if i % 7 == 0 {
                vec![1.0, 0.4]
            } else {
                vec![1.0, 0.98]
            };
            let s = m.observe(&times);
            assert!(!s.trigger, "sporadic unbalance triggered at {i}");
        }
    }

    #[test]
    fn c_factor_tolerates_inherent_unbalance(){
        // Computations that perform best slightly unbalanced use cFactor.
        let mut strict = Monitor::new(0.9);
        let mut lax = Monitor::new(0.9);
        lax.c_factor = 0.85;
        let s1 = strict.observe(&[1.0, 0.82]);
        let s2 = lax.observe(&[1.0, 0.82]);
        assert!(s1.unbalanced);
        assert!(!s2.unbalanced);
    }

    #[test]
    fn whole_request_busy_does_not_false_trigger_on_short_stages() {
        // Regression (DESIGN.md §2.7): a 3-stage request whose short
        // stages are each skewed in a different direction, while the
        // whole-request busy sums are perfectly balanced. Feeding the
        // monitor per-stage slot times — what a stage-by-stage drain
        // would observe — triggers the balancing process on pure stage
        // skew; the session must feed whole-request sums instead.
        let stage_times = [[1.0, 0.4], [0.2, 0.5], [0.3, 0.6]];
        let mut per_stage = Monitor::new(0.85);
        let mut triggered = false;
        for _ in 0..2 {
            for st in &stage_times {
                triggered |= per_stage.observe(&st[..]).trigger;
            }
        }
        assert!(triggered, "per-stage times must (wrongly) trigger the lbt");
        // Whole-request busy sums: 1.5 vs 1.5 — balanced, never triggers.
        let mut whole = Monitor::new(0.85);
        for _ in 0..10 {
            let s = whole.observe(&[1.0 + 0.2 + 0.3, 0.4 + 0.5 + 0.6]);
            assert!(!s.unbalanced && !s.trigger, "balanced sums must stay quiet");
        }
    }

    #[test]
    fn min_dev_tracks_calibration() {
        let mut m = Monitor::new(0.0); // never unbalanced; just record
        m.observe(&[1.0, 0.93]);
        m.observe(&[1.0, 0.89]);
        m.observe(&[1.0, 0.97]);
        assert!((m.min_dev() - 0.89).abs() < 1e-12);
    }

    #[test]
    fn reset_clears_history_effect() {
        let mut m = Monitor::new(0.85);
        for _ in 0..3 {
            m.observe(&[1.0, 0.5]);
        }
        assert!(m.lbt() > 0.9);
        m.reset_lbt();
        assert_eq!(m.lbt(), 0.0);
    }
}
