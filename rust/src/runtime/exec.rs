//! Real-mode partition executor: runs an SCT over one partition as a
//! sequence of AOT-chunk PJRT launches (the hot path of the system).
//!
//! A partition of `units` epu units executes as `units / chunk_units`
//! launches of the largest artifact chunk that divides it (super-chunk
//! selection amortizes the per-launch overhead; see EXPERIMENTS.md §Perf).
//! Intermediate vectors between pipeline stages stay in host buffers owned
//! by this runner — the locality-aware decomposition guarantees consecutive
//! kernels see identical partitionings, so no re-partitioning happens
//! between stages.
//!
//! Input marshalling goes through the buffer-residency pool
//! ([`crate::runtime::residency`], DESIGN.md §2.6): each (argument, chunk
//! range, version) is staged at most once per execution slot — repeated
//! chunk launches over the same range (Loop iterations, repeated requests
//! when the scheduler shares its pool) reuse the staged buffer instead of
//! re-slicing, and the pool's counters record what a device-resident
//! backend avoids re-uploading.

use std::sync::Arc;

use crate::data::vector::{ArgValue, ScalarTrait, VectorArg};
use crate::decompose::ExecSlot;
use crate::error::{Error, Result};
use crate::runtime::artifacts::Manifest;
use crate::runtime::client::{literal_f32, literal_i32, to_vec_f32, RtClient};
use crate::runtime::native::{NativeArg, NativeEngine};
use crate::runtime::residency::{ArgKey, ResidencyKey, ResidencyPool};
use crate::sct::{KernelSpec, ParamSpec, Sct};

/// Execution mode: real PJRT numerics or simulated (cost-model) timing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    Real,
    Simulated,
}

/// Request-level arguments: vectors (partitioned or COPY) and scalars, both
/// consumed positionally by the kernel parameter declarations.
#[derive(Clone, Debug, Default)]
pub struct RequestArgs {
    pub vectors: Vec<VectorArg>,
    pub scalars: Vec<f64>,
}

/// Chunk-looping executor over one PJRT client. Shared by reference across
/// the launcher's per-slot worker threads, so every counter is atomic and
/// the timing cache locks internally.
pub struct ChunkRunner<'a> {
    pub client: &'a RtClient,
    pub manifest: &'a Manifest,
    /// Counters for the perf pass (atomic: workers launch concurrently).
    pub launches: std::sync::atomic::AtomicU64,
    /// Adaptive chunk selection: measured (total seconds, total units) per
    /// artifact. Largest-chunk-first is only a prior — interpret-lowered
    /// grids make per-unit cost non-monotonic in chunk size, so the runner
    /// explores untimed candidates once and then picks the measured best
    /// (EXPERIMENTS.md §Perf, iteration 2). Shared so the knowledge
    /// persists across requests (the scheduler owns it).
    timings: TimingCache,
    /// Buffer-residency pool: staged input ranges keyed per slot. The
    /// scheduler shares its own so residency persists across requests.
    residency: Arc<ResidencyPool>,
    /// Request fingerprint the pool keys are scoped by (distinct requests
    /// over different data never alias).
    request_id: u64,
    /// Native CPU kernel backend (DESIGN.md §2.11). When set, chunk
    /// launches dispatch to specialized compiled-in kernels instead of
    /// the PJRT client — same chunk loop, same residency accounting,
    /// real FLOPs.
    native: Option<NativeExec>,
}

/// The native dispatch seam's configuration: the shared engine plus the
/// tuned work-group size the scheduler resolved for this request (the
/// specialization key input).
#[derive(Clone)]
pub struct NativeExec {
    pub engine: Arc<NativeEngine>,
    pub wgs: u32,
}

/// Shared per-artifact timing knowledge, keyed by artifact name.
pub type TimingCache =
    std::sync::Arc<std::sync::Mutex<std::collections::HashMap<String, (f64, u64)>>>;

/// The slot `run_tree` attributes residency to when the caller does not
/// say (single-slot use outside the scheduler, e.g. direct runner tests).
const DEFAULT_SLOT: ExecSlot = ExecSlot::CpuSub { idx: 0 };

impl<'a> ChunkRunner<'a> {
    pub fn new(client: &'a RtClient, manifest: &'a Manifest) -> ChunkRunner<'a> {
        ChunkRunner {
            client,
            manifest,
            launches: std::sync::atomic::AtomicU64::new(0),
            timings: TimingCache::default(),
            residency: Arc::new(ResidencyPool::new()),
            request_id: 0,
            native: None,
        }
    }

    /// Chunk launches performed so far.
    pub fn launch_count(&self) -> u64 {
        self.launches.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Share an existing timing cache (the scheduler passes its own so the
    /// adaptive chunk selection learns across requests).
    pub fn with_timings(mut self, timings: TimingCache) -> Self {
        self.timings = timings;
        self
    }

    /// Share an existing residency pool (the scheduler passes its own so
    /// resident ranges survive across requests) and scope its keys by the
    /// request fingerprint.
    pub fn with_residency(mut self, pool: Arc<ResidencyPool>, request_id: u64) -> Self {
        self.residency = pool;
        self.request_id = request_id;
        self
    }

    /// Dispatch chunk launches to the native CPU backend under the tuned
    /// work-group size instead of the PJRT client.
    pub fn with_native(mut self, engine: Arc<NativeEngine>, wgs: u32) -> Self {
        self.native = Some(NativeExec { engine, wgs });
        self
    }

    /// The runner's residency pool (counter access for tests/benches).
    pub fn residency(&self) -> &ResidencyPool {
        &self.residency
    }

    /// Execute an SCT over the unit range [start, start+units) on the
    /// default slot. See [`ChunkRunner::run_tree_on`].
    pub fn run_tree(
        &self,
        sct: &Sct,
        args: &RequestArgs,
        start_unit: u64,
        units: u64,
    ) -> Result<Vec<ArgValue>> {
        self.run_tree_on(DEFAULT_SLOT, sct, args, start_unit, units)
    }

    /// Execute an SCT over the unit range [start, start+units), attributing
    /// buffer residency to `slot`. Returns the final output buffers (one
    /// per kernel output), concatenated across chunks in unit order.
    ///
    /// Handles Kernel, Pipeline (stage chaining), Map (transparent) and
    /// non-global-sync Loop; request-level skeleton stages (global-sync
    /// loops, reductions, merging) belong to the scheduler.
    pub fn run_tree_on(
        &self,
        slot: ExecSlot,
        sct: &Sct,
        args: &RequestArgs,
        start_unit: u64,
        units: u64,
    ) -> Result<Vec<ArgValue>> {
        match sct {
            Sct::Kernel(k) => self.run_kernel(slot, k, args, None, start_unit, units),
            Sct::Map(inner) => self.run_tree_on(slot, inner, args, start_unit, units),
            Sct::Pipeline(stages) => {
                let mut carried: Option<ArgValue> = None;
                let mut cursor = ArgCursor::default();
                let mut outs = Vec::new();
                for stage in stages {
                    let k = match stage {
                        Sct::Kernel(k) => k,
                        _ => {
                            return Err(Error::Spec(
                                "nested non-kernel pipeline stages are executed \
                                 via scheduler-level traversal"
                                    .into(),
                            ))
                        }
                    };
                    outs = self.run_kernel_with_cursor(
                        slot,
                        k,
                        args,
                        carried.take(),
                        start_unit,
                        units,
                        &mut cursor,
                    )?;
                    carried = Some(outs[0].clone());
                }
                Ok(outs)
            }
            Sct::Loop { body, state } => {
                if state.global_sync {
                    return Err(Error::Spec(
                        "global-sync Loop must be driven by the scheduler".into(),
                    ));
                }
                let mut outs = Vec::new();
                let mut local = args.clone();
                for it in 0..state.max_iters {
                    outs = self.run_tree_on(slot, body, &local, start_unit, units)?;
                    if let Some(update) = &state.update {
                        let mut vecs: Vec<ArgValue> = local
                            .vectors
                            .iter()
                            .map(|v| v.value.clone())
                            .collect();
                        let go = update(it, &mut vecs, &outs);
                        for (v, nv) in local.vectors.iter_mut().zip(vecs) {
                            // Only rewritten args invalidate: resident
                            // ranges of changed contents must not be
                            // reused, while untouched args keep their
                            // residency across iterations — the
                            // Loop-iteration reuse the paper banks on.
                            let changed = !v.value.same_contents(&nv);
                            v.value = nv;
                            if changed {
                                v.bump_version();
                            }
                        }
                        if !go {
                            break;
                        }
                    }
                }
                Ok(outs)
            }
            Sct::MapReduce { map, .. } => {
                // Reduction handled at the request level by the scheduler;
                // per-partition we produce the map stage's partials.
                self.run_tree_on(slot, map, args, start_unit, units)
            }
        }
    }

    fn run_kernel(
        &self,
        slot: ExecSlot,
        k: &KernelSpec,
        args: &RequestArgs,
        carried: Option<ArgValue>,
        start_unit: u64,
        units: u64,
    ) -> Result<Vec<ArgValue>> {
        let mut cursor = ArgCursor::default();
        self.run_kernel_with_cursor(slot, k, args, carried, start_unit, units, &mut cursor)
    }

    /// Execute one flattened dataflow stage over a chunk (DESIGN.md §2.7).
    ///
    /// A kernel stage consumes request arguments from the cursor offsets
    /// the graph builder computed for it (`vec_off`/`scalar_off` — earlier
    /// stages already consumed theirs) and binds `carried` — the producer
    /// chunk's first output — to its first VecIn, exactly like the
    /// pipeline chaining in [`ChunkRunner::run_tree_on`]. Non-kernel
    /// stages run whole through the tree traversal (they never carry).
    #[allow(clippy::too_many_arguments)]
    pub fn run_stage_on(
        &self,
        slot: ExecSlot,
        stage: &Sct,
        args: &RequestArgs,
        carried: Option<ArgValue>,
        vec_off: usize,
        scalar_off: usize,
        start_unit: u64,
        units: u64,
    ) -> Result<Vec<ArgValue>> {
        match stage {
            Sct::Kernel(k) => {
                let mut cursor = ArgCursor {
                    vec: vec_off,
                    scalar: scalar_off,
                };
                self.run_kernel_with_cursor(slot, k, args, carried, start_unit, units, &mut cursor)
            }
            other => {
                debug_assert!(carried.is_none(), "only kernel stages chain intermediates");
                self.run_tree_on(slot, other, args, start_unit, units)
            }
        }
    }

    /// Stage a stage-chunk's request-vector inputs ahead of need (the
    /// prefetch pipeline, DESIGN.md §2.12): the same binding walk, chunk
    /// layout and residency keys as the launch loops, but nothing
    /// executes — data only lands in the pool as in-flight
    /// [`PendingUpload`](crate::runtime::residency::ResidencyPool)
    /// entries, to be promoted (and booked as overlapped) by the
    /// consuming acquire. Carried intermediates are produced on-device
    /// and scalars never cross the link, so both are skipped; non-kernel
    /// stages stage nothing (their inner kernels bind dynamically).
    #[allow(clippy::too_many_arguments)]
    pub fn prefetch_stage_on(
        &self,
        slot: ExecSlot,
        stage: &Sct,
        args: &RequestArgs,
        has_carried: bool,
        vec_off: usize,
        scalar_off: usize,
        start_unit: u64,
        units: u64,
    ) -> Result<()> {
        let Sct::Kernel(k) = stage else {
            return Ok(());
        };
        let mut cursor = ArgCursor {
            vec: vec_off,
            scalar: scalar_off,
        };
        let binds = self.bind_params(k, args, &mut cursor, has_carried)?;
        let info = self.pick_artifact(k, args, &binds, units)?;
        let chunk = info.chunk_units;
        let n_chunks = units / chunk;
        for c in 0..n_chunks {
            let off = start_unit + c * chunk;
            for (p, bind) in k.params.iter().zip(&binds) {
                match (p, bind) {
                    (ParamSpec::VecIn, Bind::Vector(i)) => {
                        let v = &args.vectors[*i];
                        let bytes = chunk * v.elems_per_unit * 4;
                        let key = ResidencyKey {
                            arg: ArgKey::Input {
                                request: self.request_id,
                                idx: *i as u32,
                            },
                            start_unit: off,
                            units: chunk,
                            version: v.version,
                        };
                        self.residency.prefetch_range(slot, key, bytes, |buf| {
                            v.fill_units(off, chunk, buf)
                        })?;
                    }
                    (ParamSpec::VecCopy, Bind::Vector(i)) => {
                        let v = &args.vectors[*i];
                        let bytes = v.value.len() as u64 * 4;
                        let key = ResidencyKey {
                            arg: ArgKey::Input {
                                request: self.request_id,
                                idx: *i as u32,
                            },
                            start_unit: 0,
                            units: v.units(),
                            version: v.version,
                        };
                        self.residency.prefetch_range(slot, key, bytes, |buf| {
                            buf.extend_from_slice(v.value.as_f32()?);
                            Ok(())
                        })?;
                    }
                    _ => {}
                }
            }
        }
        Ok(())
    }

    /// Execute one kernel leaf over the unit range, consuming request args
    /// through `cursor`. When `carried` is set (pipeline chaining), the
    /// kernel's first VecIn binds to it instead of a request vector.
    #[allow(clippy::too_many_arguments)]
    fn run_kernel_with_cursor(
        &self,
        slot: ExecSlot,
        k: &KernelSpec,
        args: &RequestArgs,
        carried: Option<ArgValue>,
        start_unit: u64,
        units: u64,
        cursor: &mut ArgCursor,
    ) -> Result<Vec<ArgValue>> {
        let mut carried = carried;

        // Pre-resolve which request vector each param uses (cursor order).
        let param_binds = self.bind_params(k, args, cursor, carried.is_some())?;

        // Pick the largest artifact chunk that divides the partition AND
        // whose fixed input shapes match the bound arguments (COPY-mode
        // vectors pin the artifact variant, e.g. nbody's body-set size).
        let info = self.pick_artifact(k, args, &param_binds, units)?;

        // The native dispatch seam: everything above (binding, artifact
        // selection) is backend-independent; from here the launch loop
        // either enters PJRT or the compiled-in kernels.
        if let Some(native) = self.native.clone() {
            return self.run_chunks_native(
                &native,
                slot,
                k,
                args,
                &param_binds,
                carried.as_ref(),
                info,
                start_unit,
                units,
            );
        }
        let exe = self.client.executable(info)?;
        let chunk = info.chunk_units;
        let n_chunks = units / chunk;
        // Preallocate the concatenated outputs from the partition size —
        // chunk appends never reallocate mid-drain.
        let mut outputs: Vec<Vec<f32>> = info
            .outputs
            .iter()
            .map(|o| Vec::with_capacity((o.elems() * n_chunks) as usize))
            .collect();

        for c in 0..n_chunks {
            let off = start_unit + c * chunk;
            let mut literals = Vec::with_capacity(k.params.len());
            for (p, bind) in k.params.iter().zip(&param_binds) {
                let lit = match (p, bind) {
                    (ParamSpec::VecIn, Bind::Carried) => {
                        let buf = carried.as_ref().unwrap().as_f32()?;
                        let epu = k.elems_per_unit as usize;
                        let local = (off - start_unit) as usize * epu;
                        let len = chunk as usize * epu;
                        let spec = &info.inputs[literals.len()];
                        // The producing stage left this range on-device —
                        // a device-resident backend never re-uploads a
                        // pipeline intermediate. Contents change on every
                        // invocation, so this is accounting only: the
                        // literal is rebuilt from the carried host buffer
                        // rather than cached under an `ArgKey::Stage` key.
                        self.residency.note_reuse(1, (len * 4) as u64);
                        literal_f32(&buf[local..local + len], &spec.shape)?
                    }
                    (ParamSpec::VecIn, Bind::Vector(i)) => {
                        let v = &args.vectors[*i];
                        let spec = &info.inputs[literals.len()];
                        let bytes = chunk * v.elems_per_unit * 4;
                        let key = ResidencyKey {
                            arg: ArgKey::Input {
                                request: self.request_id,
                                idx: *i as u32,
                            },
                            start_unit: off,
                            units: chunk,
                            version: v.version,
                        };
                        let staged = self
                            .residency
                            .acquire(slot, key, bytes, |buf| v.fill_units(off, chunk, buf))?;
                        literal_f32(&staged, &spec.shape)?
                    }
                    (ParamSpec::VecCopy, Bind::Vector(i)) => {
                        let v = &args.vectors[*i];
                        let spec = &info.inputs[literals.len()];
                        let bytes = v.value.len() as u64 * 4;
                        // COPY vectors are replicated whole: resident per
                        // slot after the first chunk touches them, instead
                        // of re-marshalled on every launch.
                        let key = ResidencyKey {
                            arg: ArgKey::Input {
                                request: self.request_id,
                                idx: *i as u32,
                            },
                            start_unit: 0,
                            units: v.units(),
                            version: v.version,
                        };
                        let staged = self.residency.acquire(slot, key, bytes, |buf| {
                            buf.extend_from_slice(v.value.as_f32()?);
                            Ok(())
                        })?;
                        literal_f32(&staged, &spec.shape)?
                    }
                    (ParamSpec::ScalarF32(tr), Bind::Scalar(i)) => {
                        let base = args.scalars.get(*i).copied().unwrap_or(0.0);
                        let val = scalar_value(*tr, base, off, chunk, k) as f32;
                        let spec = &info.inputs[literals.len()];
                        literal_f32(&[val], &spec.shape)?
                    }
                    (ParamSpec::ScalarI32(tr), Bind::Scalar(i)) => {
                        let base = args.scalars.get(*i).copied().unwrap_or(0.0);
                        let val = scalar_value(*tr, base, off, chunk, k) as i32;
                        let spec = &info.inputs[literals.len()];
                        literal_i32(&[val], &spec.shape)?
                    }
                    (p, b) => {
                        return Err(Error::Spec(format!(
                            "inconsistent binding {b:?} for param {p:?}"
                        )))
                    }
                };
                literals.push(lit);
            }
            let t0 = std::time::Instant::now();
            let outs = self.client.run(&exe, &literals)?;
            let dt = t0.elapsed().as_secs_f64();
            {
                let mut tm = self.timings.lock().unwrap();
                let e = tm.entry(info.name.clone()).or_insert((0.0, 0));
                e.0 += dt;
                e.1 += chunk;
            }
            self.launches
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            for (out, lit) in outputs.iter_mut().zip(&outs) {
                let host = to_vec_f32(lit)?;
                self.residency.note_download(host.len() as u64 * 4);
                out.extend_from_slice(&host);
            }
        }
        // NBody-style chunk offsets are relative to the partition for the
        // carried buffer but absolute for Offset scalars — handled above.
        let _ = carried.take();
        Ok(outputs.into_iter().map(ArgValue::F32).collect())
    }

    /// The native-backend twin of the PJRT chunk loop above: identical
    /// binding, chunking, residency accounting, timing-cache feedback and
    /// launch counting, but each chunk executes a specialized compiled-in
    /// kernel (DESIGN.md §2.11) instead of a PJRT executable. Staging is
    /// two-phase per chunk — first acquire/compute holders that keep the
    /// residency `Arc`s alive, then borrow them as flat `NativeArg` views
    /// — so staged buffers are shared with the pool, never re-copied for
    /// the launch.
    #[allow(clippy::too_many_arguments)]
    fn run_chunks_native(
        &self,
        native: &NativeExec,
        slot: ExecSlot,
        k: &KernelSpec,
        args: &RequestArgs,
        binds: &[Bind],
        carried: Option<&ArgValue>,
        info: &crate::runtime::artifacts::ArtifactInfo,
        start_unit: u64,
        units: u64,
    ) -> Result<Vec<ArgValue>> {
        enum Staged {
            Pool(Arc<Vec<f32>>),
            /// (local offset, len) into the carried stage output.
            Carried(usize, usize),
            F32(f32),
            I32(i32),
        }

        let carried_f32: Option<&[f32]> = match carried {
            Some(c) => Some(c.as_f32()?),
            None => None,
        };
        let chunk = info.chunk_units;
        let n_chunks = units / chunk;
        let mut outputs: Vec<Vec<f32>> = info
            .outputs
            .iter()
            .map(|o| Vec::with_capacity((o.elems() * n_chunks) as usize))
            .collect();

        // Staging holders live across chunks (the per-chunk contents are
        // rebuilt, the Vec itself is not re-allocated in the hot loop).
        let mut staged: Vec<Staged> = Vec::with_capacity(k.params.len());
        for c in 0..n_chunks {
            let off = start_unit + c * chunk;
            staged.clear();
            for (p, bind) in k.params.iter().zip(binds) {
                let s = match (p, bind) {
                    (ParamSpec::VecIn, Bind::Carried) => {
                        let epu = k.elems_per_unit as usize;
                        let local = (off - start_unit) as usize * epu;
                        let len = chunk as usize * epu;
                        // Accounting only, as in the PJRT loop: a carried
                        // intermediate is produced on-device and consumed
                        // in place.
                        self.residency.note_reuse(1, (len * 4) as u64);
                        Staged::Carried(local, len)
                    }
                    (ParamSpec::VecIn, Bind::Vector(i)) => {
                        let v = &args.vectors[*i];
                        let bytes = chunk * v.elems_per_unit * 4;
                        let key = ResidencyKey {
                            arg: ArgKey::Input {
                                request: self.request_id,
                                idx: *i as u32,
                            },
                            start_unit: off,
                            units: chunk,
                            version: v.version,
                        };
                        Staged::Pool(self.residency.acquire(slot, key, bytes, |buf| {
                            v.fill_units(off, chunk, buf)
                        })?)
                    }
                    (ParamSpec::VecCopy, Bind::Vector(i)) => {
                        let v = &args.vectors[*i];
                        let bytes = v.value.len() as u64 * 4;
                        let key = ResidencyKey {
                            arg: ArgKey::Input {
                                request: self.request_id,
                                idx: *i as u32,
                            },
                            start_unit: 0,
                            units: v.units(),
                            version: v.version,
                        };
                        Staged::Pool(self.residency.acquire(slot, key, bytes, |buf| {
                            buf.extend_from_slice(v.value.as_f32()?);
                            Ok(())
                        })?)
                    }
                    (ParamSpec::ScalarF32(tr), Bind::Scalar(i)) => {
                        let base = args.scalars.get(*i).copied().unwrap_or(0.0);
                        Staged::F32(scalar_value(*tr, base, off, chunk, k) as f32)
                    }
                    (ParamSpec::ScalarI32(tr), Bind::Scalar(i)) => {
                        let base = args.scalars.get(*i).copied().unwrap_or(0.0);
                        Staged::I32(scalar_value(*tr, base, off, chunk, k) as i32)
                    }
                    (p, b) => {
                        return Err(Error::Spec(format!(
                            "inconsistent binding {b:?} for param {p:?}"
                        )))
                    }
                };
                staged.push(s);
            }
            let nargs: Vec<NativeArg> = staged
                .iter()
                .map(|s| match s {
                    Staged::Pool(a) => NativeArg::F32(&a[..]),
                    Staged::Carried(local, len) => {
                        let buf = carried_f32.expect("Bind::Carried implies carried buffer");
                        NativeArg::F32(&buf[*local..*local + *len])
                    }
                    Staged::F32(v) => NativeArg::ScalarF32(*v),
                    Staged::I32(v) => NativeArg::ScalarI32(*v),
                })
                .collect();

            let t0 = std::time::Instant::now();
            let outs = native.engine.run_chunk(info, native.wgs, chunk, &nargs)?;
            let dt = t0.elapsed().as_secs_f64();
            {
                let mut tm = self.timings.lock().unwrap();
                let e = tm.entry(info.name.clone()).or_insert((0.0, 0));
                e.0 += dt;
                e.1 += chunk;
            }
            self.launches
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            for (out, host) in outputs.iter_mut().zip(outs) {
                self.residency.note_download(host.len() as u64 * 4);
                out.extend_from_slice(&host);
            }
        }
        Ok(outputs.into_iter().map(ArgValue::F32).collect())
    }

    /// Artifact selection under the chunk-menu constraint (DESIGN.md §1.2).
    fn pick_artifact(
        &self,
        k: &KernelSpec,
        args: &RequestArgs,
        binds: &[Bind],
        units: u64,
    ) -> Result<&crate::runtime::artifacts::ArtifactInfo> {
        let menu = self.manifest.family(&k.family)?;
        let mut valid: Vec<&crate::runtime::artifacts::ArtifactInfo> = Vec::new();
        'menu: for info in menu.iter().rev() {
            if units % info.chunk_units != 0 || units < info.chunk_units {
                continue;
            }
            for ((p, bind), spec) in k.params.iter().zip(binds).zip(&info.inputs) {
                let want = spec.elems();
                let ok = match (p, bind) {
                    (ParamSpec::VecIn, Bind::Carried) => {
                        want == info.chunk_units * k.elems_per_unit
                    }
                    (ParamSpec::VecIn, Bind::Vector(i)) => {
                        want == info.chunk_units * args.vectors[*i].elems_per_unit
                    }
                    (ParamSpec::VecCopy, Bind::Vector(i)) => {
                        want == args.vectors[*i].value.len() as u64
                    }
                    _ => true, // scalars: shape (1,) or small fixed vectors
                };
                if !ok {
                    continue 'menu;
                }
            }
            valid.push(info);
        }
        // Exploration: any untimed candidate (largest first) gets tried once;
        // exploitation: otherwise the measured-best per-unit cost wins.
        if !valid.is_empty() {
            let timings = self.timings.lock().unwrap();
            if let Some(untimed) = valid.iter().find(|i| !timings.contains_key(&i.name)) {
                return Ok(untimed);
            }
            return Ok(valid
                .iter()
                .min_by(|a, b| {
                    let pa = timings[&a.name];
                    let pb = timings[&b.name];
                    (pa.0 / pa.1 as f64)
                        .partial_cmp(&(pb.0 / pb.1 as f64))
                        .unwrap()
                })
                .unwrap());
        }
        Err(Error::Artifact(format!(
            "no artifact of family '{}' matches partition of {units} units \
             (menu: {:?})",
            k.family,
            menu.iter().map(|a| a.chunk_units).collect::<Vec<_>>()
        )))
    }

    fn bind_params(
        &self,
        k: &KernelSpec,
        args: &RequestArgs,
        cursor: &mut ArgCursor,
        has_carried: bool,
    ) -> Result<Vec<Bind>> {
        let mut binds = Vec::with_capacity(k.params.len());
        let mut first_vecin = true;
        for p in &k.params {
            let b = match p {
                ParamSpec::VecIn | ParamSpec::VecCopy => {
                    if matches!(p, ParamSpec::VecIn) && first_vecin && has_carried {
                        first_vecin = false;
                        Bind::Carried
                    } else {
                        if matches!(p, ParamSpec::VecIn) {
                            first_vecin = false;
                        }
                        let i = cursor.vec;
                        if i >= args.vectors.len() {
                            return Err(Error::Spec(format!(
                                "kernel {} needs vector arg #{i} but request \
                                 has {}",
                                k.family,
                                args.vectors.len()
                            )));
                        }
                        cursor.vec += 1;
                        Bind::Vector(i)
                    }
                }
                ParamSpec::ScalarF32(_) | ParamSpec::ScalarI32(_) => {
                    let i = cursor.scalar;
                    cursor.scalar += 1;
                    Bind::Scalar(i)
                }
            };
            binds.push(b);
        }
        Ok(binds)
    }
}

#[derive(Clone, Copy, Debug, Default)]
struct ArgCursor {
    vec: usize,
    scalar: usize,
}

#[derive(Clone, Copy, Debug)]
enum Bind {
    Vector(usize),
    Scalar(usize),
    Carried,
}

fn scalar_value(tr: ScalarTrait, base: f64, off: u64, chunk: u64, k: &KernelSpec) -> f64 {
    match tr {
        ScalarTrait::Bound => base,
        ScalarTrait::Size => (chunk * k.elems_per_unit) as f64,
        ScalarTrait::Offset => off as f64,
        ScalarTrait::SeededOffset => base + off as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_traits_resolve() {
        let k = KernelSpec::new("f", vec![], 512);
        assert_eq!(scalar_value(ScalarTrait::Bound, 3.5, 10, 8, &k), 3.5);
        assert_eq!(scalar_value(ScalarTrait::Size, 0.0, 10, 8, &k), 4096.0);
        assert_eq!(scalar_value(ScalarTrait::Offset, 0.0, 10, 8, &k), 10.0);
        assert_eq!(
            scalar_value(ScalarTrait::SeededOffset, 100.0, 10, 8, &k),
            110.0
        );
    }
}
