//! PJRT runtime: loads AOT-compiled HLO-text artifacts and executes them on
//! the CPU PJRT client. Python is never on this path — the Rust binary is
//! self-contained once `make artifacts` has produced `artifacts/`.

pub mod artifacts;
pub mod client;
pub mod exec;
pub mod native;
pub mod residency;
#[cfg(not(feature = "pjrt"))]
pub mod stub;

pub use artifacts::{ArtifactInfo, Manifest};
pub use client::RtClient;
pub use exec::{ChunkRunner, ExecMode};
pub use native::NativeEngine;
pub use residency::{ResidencyPool, ResidencyView, TransferStats};
