//! Native CPU ports of the AOT kernel menu (`python/compile/kernels/`),
//! monomorphized over the lane width `L` chosen by the specializer
//! (DESIGN.md §2.11). Every variant of a family computes the *identical*
//! f32 operation sequence per element — vectorization only ever splits
//! work across elements that the source kernels treat independently
//! (saxpy elements, filter pixels, segmentation voxels, n-body `i` rows,
//! whole FFTs) — so scalar and laned variants are bit-identical and the
//! backend-parity tests can compare exactly, with no reassociation
//! tolerance.
//!
//! Numerics mirror the JAX definitions closely enough to be their
//! reference: the same integer hash, the same f64->f32 twiddle rounding,
//! the same softened-distance epsilon, the same clamp bounds.

use super::{NativeArg, SpecKey};
use crate::runtime::artifacts::ArtifactInfo;
use crate::error::{Error, Result};

/// One specialized kernel entry point: `(artifact, spec, units, args)` ->
/// one `Vec<f32>` per artifact output. `units` is the partition-unit count
/// of this launch (== `artifact.chunk_units` except on a ragged tail).
pub type KernelFn = fn(&ArtifactInfo, &SpecKey, u64, &[NativeArg]) -> Result<Vec<Vec<f32>>>;

/// Families the native backend can execute, in manifest order. The
/// engine fingerprint hashes this list, so adding a port changes the
/// native manifest digest and re-keys learned profiles.
pub const FAMILIES: [&str; 11] = [
    "saxpy",
    "gaussian_noise",
    "solarize",
    "mirror",
    "filter_pipeline",
    "fft_roundtrip",
    "nbody_accel",
    "segmentation",
    "spmv_csr",
    "bfs_frontier",
    "mandelbrot",
];

/// Resolve a family to the monomorphized variant for `lanes`. The FFT is
/// lane-independent (its parallel axis is whole transforms; the butterfly
/// ladder itself is sequential), so every lane width shares one body.
pub fn select(family: &str, lanes: u32) -> Result<KernelFn> {
    macro_rules! laned {
        ($f:ident) => {
            match lanes {
                8 => $f::<8>,
                4 => $f::<4>,
                _ => $f::<1>,
            }
        };
    }
    Ok(match family {
        "saxpy" => laned!(saxpy_entry),
        "gaussian_noise" => laned!(gaussian_entry),
        "solarize" => laned!(solarize_entry),
        "mirror" => mirror_entry,
        "filter_pipeline" => laned!(filter_pipeline_entry),
        "fft_roundtrip" => fft_entry,
        "nbody_accel" => laned!(nbody_entry),
        "segmentation" => laned!(segmentation_entry),
        "spmv_csr" => laned!(spmv_entry),
        "bfs_frontier" => laned!(bfs_entry),
        "mandelbrot" => laned!(mandelbrot_entry),
        other => {
            return Err(Error::Artifact(format!(
                "native backend has no kernel for family '{other}'"
            )))
        }
    })
}

fn vec_arg<'a>(args: &'a [NativeArg], i: usize, family: &str) -> Result<&'a [f32]> {
    args.get(i)
        .ok_or_else(|| Error::Artifact(format!("{family}: missing arg {i}")))?
        .f32s()
}

fn scalar_f32(args: &[NativeArg], i: usize, family: &str) -> Result<f32> {
    args.get(i)
        .ok_or_else(|| Error::Artifact(format!("{family}: missing arg {i}")))?
        .scalar_f32()
}

fn scalar_i32(args: &[NativeArg], i: usize, family: &str) -> Result<i32> {
    args.get(i)
        .ok_or_else(|| Error::Artifact(format!("{family}: missing arg {i}")))?
        .scalar_i32()
}

/// Trailing dimension of the first input — the image/plane width.
fn width(info: &ArtifactInfo) -> usize {
    info.inputs[0].shape.last().copied().unwrap_or(1).max(1) as usize
}

// --- saxpy ----------------------------------------------------------------

/// `out = alpha * x + y`. Blocked so each tile of x/y/out passes through
/// cache together; the fixed-`L` stripe is the autovectorizer target.
fn saxpy_entry<const L: usize>(
    _info: &ArtifactInfo,
    key: &SpecKey,
    _units: u64,
    args: &[NativeArg],
) -> Result<Vec<Vec<f32>>> {
    let a = scalar_f32(args, 0, "saxpy")?;
    let x = vec_arg(args, 1, "saxpy")?;
    let y = vec_arg(args, 2, "saxpy")?;
    if x.len() != y.len() {
        return Err(Error::Artifact(format!(
            "saxpy: x has {} elems but y has {}",
            x.len(),
            y.len()
        )));
    }
    let n = x.len();
    let mut out = vec![0.0f32; n];
    let block = (key.block as usize).max(1) * L.max(1);
    for start in (0..n).step_by(block) {
        let end = (start + block).min(n);
        let mut i = start;
        while i + L <= end {
            for l in 0..L {
                out[i + l] = a * x[i + l] + y[i + l];
            }
            i += L;
        }
        while i < end {
            out[i] = a * x[i] + y[i];
            i += 1;
        }
    }
    Ok(vec![out])
}

// --- filters --------------------------------------------------------------

/// lowbias32-style avalanche hash — must match `kernels/filters.py`.
#[inline(always)]
fn hash_u32(mut x: u32) -> u32 {
    x ^= x >> 16;
    x = x.wrapping_mul(0x7feb_352d);
    x ^= x >> 15;
    x = x.wrapping_mul(0x846c_a68b);
    x ^= x >> 16;
    x
}

/// Map hash bits to (0, 1): 24-bit mantissa scale plus a half-ulp offset
/// keeping the value strictly positive for `ln`.
#[inline(always)]
fn uniform01(bits: u32) -> f32 {
    (bits >> 8) as f32 / 16_777_216.0 + 1.0 / 33_554_432.0
}

/// 2*pi rounded to f32, written at f32 precision to match the Python
/// kernel's `jnp.float32(2.0 * np.pi)`.
const TWO_PI: f32 = 6.283_185_5;

/// Box-Muller noise for one pixel, seeded by its *global* coordinates so
/// chunk decomposition cannot change the image.
#[inline(always)]
fn gauss_px(x: f32, local_row: u32, col: u32, seed: u32, row_off: u32, sigma: f32) -> f32 {
    let global_row = row_off.wrapping_add(local_row);
    let pix = global_row.wrapping_mul(65_521).wrapping_add(col);
    let u1 = uniform01(hash_u32(pix ^ seed));
    let u2 = uniform01(hash_u32(pix.wrapping_add(seed.wrapping_mul(2_654_435_761))));
    let mag = (-2.0f32 * u1.ln()).sqrt();
    let noise = mag * (TWO_PI * u2).cos() * sigma;
    (x + noise).clamp(0.0, 255.0)
}

/// Threshold inversion — must match `kernels/filters.py`.
#[inline(always)]
fn solarize_px(x: f32, thresh: f32) -> f32 {
    if x > thresh {
        255.0 - x
    } else {
        x
    }
}

const SIGMA: f32 = 8.0;

fn gaussian_entry<const L: usize>(
    info: &ArtifactInfo,
    _key: &SpecKey,
    _units: u64,
    args: &[NativeArg],
) -> Result<Vec<Vec<f32>>> {
    let img = vec_arg(args, 0, "gaussian_noise")?;
    let seed = scalar_i32(args, 1, "gaussian_noise")? as u32;
    let row_off = scalar_i32(args, 2, "gaussian_noise")? as u32;
    let w = width(info);
    let rows = img.len() / w;
    let mut out = vec![0.0f32; img.len()];
    for r in 0..rows {
        let base = r * w;
        let mut c = 0;
        while c + L <= w {
            for l in 0..L {
                out[base + c + l] = gauss_px(
                    img[base + c + l],
                    r as u32,
                    (c + l) as u32,
                    seed,
                    row_off,
                    SIGMA,
                );
            }
            c += L;
        }
        while c < w {
            out[base + c] = gauss_px(img[base + c], r as u32, c as u32, seed, row_off, SIGMA);
            c += 1;
        }
    }
    Ok(vec![out])
}

fn solarize_entry<const L: usize>(
    _info: &ArtifactInfo,
    _key: &SpecKey,
    _units: u64,
    args: &[NativeArg],
) -> Result<Vec<Vec<f32>>> {
    let img = vec_arg(args, 0, "solarize")?;
    let thresh = scalar_f32(args, 1, "solarize")?;
    let n = img.len();
    let mut out = vec![0.0f32; n];
    let mut i = 0;
    while i + L <= n {
        for l in 0..L {
            out[i + l] = solarize_px(img[i + l], thresh);
        }
        i += L;
    }
    while i < n {
        out[i] = solarize_px(img[i], thresh);
        i += 1;
    }
    Ok(vec![out])
}

/// Horizontal mirror: pure data movement, nothing to specialize.
fn mirror_entry(
    info: &ArtifactInfo,
    _key: &SpecKey,
    _units: u64,
    args: &[NativeArg],
) -> Result<Vec<Vec<f32>>> {
    let img = vec_arg(args, 0, "mirror")?;
    let w = width(info);
    let rows = img.len() / w;
    let mut out = vec![0.0f32; img.len()];
    for r in 0..rows {
        let base = r * w;
        for c in 0..w {
            out[base + w - 1 - c] = img[base + c];
        }
    }
    Ok(vec![out])
}

/// Fused noise -> solarize -> mirror in one pass: each output pixel is
/// produced from exactly one input pixel, so fusion is exact and saves
/// two intermediate images.
fn filter_pipeline_entry<const L: usize>(
    info: &ArtifactInfo,
    _key: &SpecKey,
    _units: u64,
    args: &[NativeArg],
) -> Result<Vec<Vec<f32>>> {
    let img = vec_arg(args, 0, "filter_pipeline")?;
    let seed = scalar_i32(args, 1, "filter_pipeline")? as u32;
    let row_off = scalar_i32(args, 2, "filter_pipeline")? as u32;
    let thresh = scalar_f32(args, 3, "filter_pipeline")?;
    let w = width(info);
    let rows = img.len() / w;
    let mut out = vec![0.0f32; img.len()];
    for r in 0..rows {
        let base = r * w;
        let mut c = 0;
        while c + L <= w {
            for l in 0..L {
                let v = gauss_px(
                    img[base + c + l],
                    r as u32,
                    (c + l) as u32,
                    seed,
                    row_off,
                    SIGMA,
                );
                out[base + w - 1 - (c + l)] = solarize_px(v, thresh);
            }
            c += L;
        }
        while c < w {
            let v = gauss_px(img[base + c], r as u32, c as u32, seed, row_off, SIGMA);
            out[base + w - 1 - c] = solarize_px(v, thresh);
            c += 1;
        }
    }
    Ok(vec![out])
}

// --- FFT ------------------------------------------------------------------

/// Iterative radix-2 DIT over one `n`-point signal, in place. Twiddle
/// steps are computed in f64 and rounded once per ladder rung — the same
/// rounding point as the JAX kernel's `jnp.float32(sign * 2pi / m)` — so
/// outputs match the AOT artifacts' numerics, not just their shape.
fn fft_inplace(re: &mut [f32], im: &mut [f32], inverse: bool) {
    let n = re.len();
    if n < 2 {
        return;
    }
    let bits = n.trailing_zeros();
    for i in 0..n {
        let j = ((i as u32).reverse_bits() >> (32 - bits)) as usize;
        if j > i {
            re.swap(i, j);
            im.swap(i, j);
        }
    }
    let sign: f64 = if inverse { 1.0 } else { -1.0 };
    let mut m = 2;
    while m <= n {
        let half = m / 2;
        let step = (sign * 2.0 * std::f64::consts::PI / m as f64) as f32;
        for base in (0..n).step_by(m) {
            for k in 0..half {
                let ang = step * k as f32;
                let (wr, wi) = (ang.cos(), ang.sin());
                let (odd_r, odd_i) = (re[base + half + k], im[base + half + k]);
                let tr = odd_r * wr - odd_i * wi;
                let ti = odd_r * wi + odd_i * wr;
                let (even_r, even_i) = (re[base + k], im[base + k]);
                re[base + k] = even_r + tr;
                im[base + k] = even_i + ti;
                re[base + half + k] = even_r - tr;
                im[base + half + k] = even_i - ti;
            }
        }
        m *= 2;
    }
    if inverse {
        // Division (not reciprocal-multiply) mirrors the JAX `re / n`.
        for v in re.iter_mut() {
            *v /= n as f32;
        }
        for v in im.iter_mut() {
            *v /= n as f32;
        }
    }
}

fn fft_entry(
    info: &ArtifactInfo,
    _key: &SpecKey,
    _units: u64,
    args: &[NativeArg],
) -> Result<Vec<Vec<f32>>> {
    let re_in = vec_arg(args, 0, "fft_roundtrip")?;
    let im_in = vec_arg(args, 1, "fft_roundtrip")?;
    let n = width(info);
    if !n.is_power_of_two() || re_in.len() % n != 0 || re_in.len() != im_in.len() {
        return Err(Error::Artifact(format!(
            "fft_roundtrip: bad plane shape ({} re, {} im, n={n})",
            re_in.len(),
            im_in.len()
        )));
    }
    let mut re = re_in.to_vec();
    let mut im = im_in.to_vec();
    for b in 0..re.len() / n {
        let (r, i) = (&mut re[b * n..(b + 1) * n], &mut im[b * n..(b + 1) * n]);
        fft_inplace(r, i, false);
        fft_inplace(r, i, true);
    }
    Ok(vec![re, im])
}

// --- n-body ---------------------------------------------------------------

/// Softening term: the Python kernel squares `1e-3` in f64 and narrows,
/// which is exactly f32 `1e-6`.
const EPS2: f32 = 1e-6;

/// All-pairs gravity for `units` bodies starting at `offset`, against the
/// whole (copied) body set. Lanes tile the `i` axis; each lane keeps its
/// own accumulator and walks `j` in ascending order, so any tiling
/// reproduces the scalar sums bit for bit.
fn nbody_entry<const L: usize>(
    _info: &ArtifactInfo,
    _key: &SpecKey,
    units: u64,
    args: &[NativeArg],
) -> Result<Vec<Vec<f32>>> {
    let pos = vec_arg(args, 0, "nbody_accel")?;
    let offset = scalar_i32(args, 1, "nbody_accel")?.max(0) as usize;
    let total = pos.len() / 4;
    let chunk = units as usize;
    if offset + chunk > total {
        return Err(Error::Artifact(format!(
            "nbody_accel: chunk [{offset}, {}) exceeds {total} bodies",
            offset + chunk
        )));
    }
    let mut out = vec![0.0f32; chunk * 3];
    let mut i = 0;
    while i + L <= chunk {
        let mut xi = [0.0f32; L];
        let mut yi = [0.0f32; L];
        let mut zi = [0.0f32; L];
        for l in 0..L {
            let b = (offset + i + l) * 4;
            xi[l] = pos[b];
            yi[l] = pos[b + 1];
            zi[l] = pos[b + 2];
        }
        let mut ax = [0.0f32; L];
        let mut ay = [0.0f32; L];
        let mut az = [0.0f32; L];
        for j in 0..total {
            let (px, py, pz, pm) = (pos[j * 4], pos[j * 4 + 1], pos[j * 4 + 2], pos[j * 4 + 3]);
            for l in 0..L {
                let dx = px - xi[l];
                let dy = py - yi[l];
                let dz = pz - zi[l];
                let r2 = dx * dx + dy * dy + dz * dz + EPS2;
                let w = pm * (1.0 / r2.sqrt()) / r2;
                ax[l] += w * dx;
                ay[l] += w * dy;
                az[l] += w * dz;
            }
        }
        for l in 0..L {
            out[(i + l) * 3] = ax[l];
            out[(i + l) * 3 + 1] = ay[l];
            out[(i + l) * 3 + 2] = az[l];
        }
        i += L;
    }
    while i < chunk {
        let b = (offset + i) * 4;
        let (xi, yi, zi) = (pos[b], pos[b + 1], pos[b + 2]);
        let (mut ax, mut ay, mut az) = (0.0f32, 0.0f32, 0.0f32);
        for j in 0..total {
            let dx = pos[j * 4] - xi;
            let dy = pos[j * 4 + 1] - yi;
            let dz = pos[j * 4 + 2] - zi;
            let r2 = dx * dx + dy * dy + dz * dz + EPS2;
            let w = pos[j * 4 + 3] * (1.0 / r2.sqrt()) / r2;
            ax += w * dx;
            ay += w * dy;
            az += w * dz;
        }
        out[i * 3] = ax;
        out[i * 3 + 1] = ay;
        out[i * 3 + 2] = az;
        i += 1;
    }
    Ok(vec![out])
}

// --- irregular tier (ROADMAP item 4) --------------------------------------
//
// These three families carry data-dependent cost: the work done per
// partition unit depends on the *contents* of the inputs (nonzeros per
// row, frontier membership, escape iteration), not just the shape. The
// native bodies stay bit-identical across lane widths because lanes only
// tile independent rows/nodes/pixels — each keeps its own scalar inner
// loop in source order.

/// ELL-style padded sparse row product: `out[r] = sum_k vals[r,K+k] *
/// x[cols[r,k]]`, where `cols` stores column indices as f32 (exact up to
/// 2^24) padded with -1.0. The per-row trip count follows the row-length
/// distribution — the canonical sparse skew.
fn spmv_entry<const L: usize>(
    info: &ArtifactInfo,
    _key: &SpecKey,
    units: u64,
    args: &[NativeArg],
) -> Result<Vec<Vec<f32>>> {
    let cols = vec_arg(args, 0, "spmv_csr")?;
    let vals = vec_arg(args, 1, "spmv_csr")?;
    let x = vec_arg(args, 2, "spmv_csr")?;
    let k_pad = width(info);
    let rows = units as usize;
    if cols.len() < rows * k_pad || vals.len() < rows * k_pad {
        return Err(Error::Artifact(format!(
            "spmv_csr: {rows} rows x {k_pad} pad needs {} elems, got cols={} vals={}",
            rows * k_pad,
            cols.len(),
            vals.len()
        )));
    }
    let row = |r: usize, out: &mut f32| -> Result<()> {
        let base = r * k_pad;
        let mut sum = 0.0f32;
        for k in 0..k_pad {
            let c = cols[base + k];
            if c < 0.0 {
                break;
            }
            let ci = c as usize;
            let xv = *x.get(ci).ok_or_else(|| {
                Error::Artifact(format!("spmv_csr: column {ci} out of x ({})", x.len()))
            })?;
            sum += vals[base + k] * xv;
        }
        *out = sum;
        Ok(())
    };
    let mut out = vec![0.0f32; rows];
    let mut r = 0;
    while r + L <= rows {
        for l in 0..L {
            let mut v = 0.0f32;
            row(r + l, &mut v)?;
            out[r + l] = v;
        }
        r += L;
    }
    while r < rows {
        let mut v = 0.0f32;
        row(r, &mut v)?;
        out[r] = v;
        r += 1;
    }
    Ok(vec![out])
}

/// One BFS frontier-expansion step over a padded adjacency list:
/// `out[v] = 1.0` iff any neighbour of `v` is in the current frontier
/// (f32 0/1 flags, COPY-replicated). Neighbour slots are -1.0-padded and
/// the scan breaks both on padding and on the first hit, so cost follows
/// degree and frontier structure.
fn bfs_entry<const L: usize>(
    info: &ArtifactInfo,
    _key: &SpecKey,
    units: u64,
    args: &[NativeArg],
) -> Result<Vec<Vec<f32>>> {
    let adj = vec_arg(args, 0, "bfs_frontier")?;
    let frontier = vec_arg(args, 1, "bfs_frontier")?;
    let deg_pad = width(info);
    let nodes = units as usize;
    if adj.len() < nodes * deg_pad {
        return Err(Error::Artifact(format!(
            "bfs_frontier: {nodes} nodes x {deg_pad} pad needs {} elems, got {}",
            nodes * deg_pad,
            adj.len()
        )));
    }
    let expand = |v: usize| -> Result<f32> {
        let base = v * deg_pad;
        for d in 0..deg_pad {
            let u = adj[base + d];
            if u < 0.0 {
                break;
            }
            let ui = u as usize;
            let f = *frontier.get(ui).ok_or_else(|| {
                Error::Artifact(format!(
                    "bfs_frontier: neighbour {ui} out of frontier ({})",
                    frontier.len()
                ))
            })?;
            if f > 0.0 {
                return Ok(1.0);
            }
        }
        Ok(0.0)
    };
    let mut out = vec![0.0f32; nodes];
    let mut v = 0;
    while v + L <= nodes {
        for l in 0..L {
            out[v + l] = expand(v + l)?;
        }
        v += L;
    }
    while v < nodes {
        out[v] = expand(v)?;
        v += 1;
    }
    Ok(vec![out])
}

/// Escape-time iteration count for `z <- z^2 + c` per pixel, the
/// divergence archetype: neighbouring pixels can differ by orders of
/// magnitude in trip count. Output is the iteration count as f32
/// (`max_iters` for points that never escape |z|^2 > 4).
fn mandelbrot_entry<const L: usize>(
    _info: &ArtifactInfo,
    _key: &SpecKey,
    _units: u64,
    args: &[NativeArg],
) -> Result<Vec<Vec<f32>>> {
    let c_re = vec_arg(args, 0, "mandelbrot")?;
    let c_im = vec_arg(args, 1, "mandelbrot")?;
    let max_iters = scalar_i32(args, 2, "mandelbrot")?.max(1) as u32;
    if c_re.len() != c_im.len() {
        return Err(Error::Artifact(format!(
            "mandelbrot: re has {} elems but im has {}",
            c_re.len(),
            c_im.len()
        )));
    }
    let escape = |cr: f32, ci: f32| -> f32 {
        let (mut zr, mut zi) = (0.0f32, 0.0f32);
        let mut it = 0u32;
        while it < max_iters {
            let r2 = zr * zr + zi * zi;
            if r2 > 4.0 {
                break;
            }
            let nzr = zr * zr - zi * zi + cr;
            zi = 2.0 * zr * zi + ci;
            zr = nzr;
            it += 1;
        }
        it as f32
    };
    let n = c_re.len();
    let mut out = vec![0.0f32; n];
    let mut i = 0;
    while i + L <= n {
        for l in 0..L {
            out[i + l] = escape(c_re[i + l], c_im[i + l]);
        }
        i += L;
    }
    while i < n {
        out[i] = escape(c_re[i], c_im[i]);
        i += 1;
    }
    Ok(vec![out])
}

// --- segmentation ---------------------------------------------------------

/// Two-threshold voxel classifier: below -> 0, above -> 255, else 128.
fn segmentation_entry<const L: usize>(
    _info: &ArtifactInfo,
    _key: &SpecKey,
    _units: u64,
    args: &[NativeArg],
) -> Result<Vec<Vec<f32>>> {
    let vol = vec_arg(args, 0, "segmentation")?;
    let thresholds = vec_arg(args, 1, "segmentation")?;
    if thresholds.len() < 2 {
        return Err(Error::Artifact(
            "segmentation: thresholds needs [lo, hi]".into(),
        ));
    }
    let (lo, hi) = (thresholds[0], thresholds[1]);
    let classify = |v: f32| {
        if v < lo {
            0.0
        } else if v > hi {
            255.0
        } else {
            128.0
        }
    };
    let n = vol.len();
    let mut out = vec![0.0f32; n];
    let mut i = 0;
    while i + L <= n {
        for l in 0..L {
            out[i + l] = classify(vol[i + l]);
        }
        i += L;
    }
    while i < n {
        out[i] = classify(vol[i]);
        i += 1;
    }
    Ok(vec![out])
}
