//! Per-worker core pinning for the native backend (DESIGN.md §2.11).
//!
//! Each CPU execution slot maps to one core: worker `CpuSub { idx }` pins
//! itself to core `idx % ncores` before draining. With the pin in place,
//! residency keys (which are per-slot) price *physical* cache/NUMA
//! locality — a steal that migrates a partition really does refill
//! another core's cache — instead of whatever core the OS scheduler
//! happened to land the thread on.
//!
//! Implemented as a raw `sched_setaffinity` syscall on linux/x86_64 (the
//! crate is dependency-free); everywhere else it is a no-op returning
//! `false`, and the backend still runs correctly — pinning is a locality
//! optimization, never a correctness requirement.

/// Pin the calling thread to `core` (modulo the visible core count).
/// Returns whether a pin was actually applied.
pub fn pin_current_thread(core: usize) -> bool {
    imp::pin(core)
}

/// First-touch `len` elements of a staging buffer on the *calling* thread
/// (DESIGN.md §2.12). Linux commits anonymous pages on the NUMA node of
/// the thread that first writes them, so touching the pages from the
/// pinned worker — before the fill copy — places the staged slice in the
/// worker's local memory. A buffer recycled from the per-slot arena
/// already has its pages committed (and local, since the same worker
/// touched them), so reuse is a no-op here. The buffer's length is
/// restored afterwards; only capacity is committed.
pub fn first_touch_pages(buf: &mut Vec<f32>, len: usize) {
    if buf.capacity() >= len {
        return;
    }
    buf.reserve(len - buf.len());
    let prev = buf.len();
    let cap = buf.capacity();
    buf.resize(cap.min(len.max(prev)), 0.0);
    buf.truncate(prev);
}

#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
mod imp {
    pub fn pin(core: usize) -> bool {
        let ncores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        // cpu_set_t here is 1024 bits = 16 u64 words; clamp for hosts
        // reporting more cores than that.
        let cpu = (core % ncores).min(1023);
        let mut mask = [0u64; 16];
        mask[cpu / 64] |= 1u64 << (cpu % 64);
        let mut ret: isize;
        unsafe {
            // sched_setaffinity(pid=0 -> calling thread, size, mask)
            core::arch::asm!(
                "syscall",
                inlateout("rax") 203isize => ret,
                in("rdi") 0,
                in("rsi") mask.len() * 8,
                in("rdx") mask.as_ptr(),
                lateout("rcx") _,
                lateout("r11") _,
                options(nostack),
            );
        }
        ret == 0
    }
}

#[cfg(not(all(target_os = "linux", target_arch = "x86_64")))]
mod imp {
    pub fn pin(_core: usize) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pinning_is_best_effort_and_never_panics() {
        // On linux/x86_64 this pins and reports true; elsewhere it is a
        // no-op reporting false. Either way the call must be safe.
        let _ = pin_current_thread(0);
        let _ = pin_current_thread(usize::MAX);
    }

    #[test]
    fn first_touch_commits_capacity_without_changing_contents() {
        let mut buf: Vec<f32> = Vec::new();
        first_touch_pages(&mut buf, 4096);
        assert!(buf.capacity() >= 4096);
        assert!(buf.is_empty(), "length must be restored after the touch");
        buf.extend_from_slice(&[1.0, 2.0]);
        let cap = buf.capacity();
        let ptr = buf.as_ptr();
        // A buffer that is already large enough is left alone entirely.
        first_touch_pages(&mut buf, 1024);
        assert_eq!(buf.capacity(), cap);
        assert_eq!(buf.as_ptr(), ptr);
        assert_eq!(buf.as_slice(), &[1.0, 2.0]);
    }
}
