//! Native compiled CPU kernel backend (DESIGN.md §2.11, ROADMAP item 3).
//!
//! Outside `pjrt` builds the "real" scheduler used to drain a functional
//! host stub — every BENCH number measured orchestration, never hardware.
//! This module closes that gap: the AOT kernel menu from
//! `python/compile/aot.py` is ported to Rust (`kernels`), specialized per
//! tuned config (work-group size -> cache block, vector width -> const
//! lane count) and dispatched straight from `ChunkRunner`'s hot path, so
//! worker threads, residency, stealing, and the tuner/KB chain all price
//! real FLOPs.
//!
//! Specialized variants live in a content-addressed registry keyed like
//! the PR 6 KB store: `SpecKey { family, chunk_units, block, lanes }`
//! hashes to a digest, and the engine `fingerprint()` — folded into
//! `RealScheduler::manifest_digest` — keeps native profiles in a distinct
//! key space from stub/sim/pjrt ones.

pub mod affinity;
pub mod kernels;

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{Arc, RwLock};

use crate::error::{Error, Result};
use crate::runtime::artifacts::{ArtifactInfo, IoSpec, Manifest};
use crate::util::hash::sha256_hex;

pub use kernels::KernelFn;

/// One staged kernel argument: a borrowed f32 plane (partition slice,
/// whole copy, or carried stage output) or an immediate scalar.
#[derive(Clone, Copy, Debug)]
pub enum NativeArg<'a> {
    F32(&'a [f32]),
    ScalarF32(f32),
    ScalarI32(i32),
}

impl<'a> NativeArg<'a> {
    pub fn f32s(&self) -> Result<&'a [f32]> {
        match self {
            NativeArg::F32(v) => Ok(v),
            other => Err(Error::Artifact(format!(
                "native arg: expected f32 plane, got {other:?}"
            ))),
        }
    }

    pub fn scalar_f32(&self) -> Result<f32> {
        match self {
            NativeArg::ScalarF32(v) => Ok(*v),
            other => Err(Error::Artifact(format!(
                "native arg: expected f32 scalar, got {other:?}"
            ))),
        }
    }

    pub fn scalar_i32(&self) -> Result<i32> {
        match self {
            NativeArg::ScalarI32(v) => Ok(*v),
            other => Err(Error::Artifact(format!(
                "native arg: expected i32 scalar, got {other:?}"
            ))),
        }
    }
}

/// Identity of a specialized kernel variant: the tuned parameters that
/// were baked into its code shape. Two dispatches with equal keys share
/// one registry entry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SpecKey {
    pub family: String,
    /// Partition units per launch of the artifact this variant serves.
    pub chunk_units: u64,
    /// Cache-block length (elements per tile), derived from the tuner's
    /// work-group size.
    pub block: u32,
    /// Const-generic lane width the body was monomorphized with.
    pub lanes: u32,
}

impl SpecKey {
    /// Content address, in the style of the KB store's profile keys.
    pub fn digest(&self) -> String {
        sha256_hex(
            format!(
                "native-spec\0{}\0{}\0{}\0{}",
                self.family, self.chunk_units, self.block, self.lanes
            )
            .as_bytes(),
        )
    }
}

/// A registered specialization: its key, content address, and the
/// monomorphized entry point.
pub struct SpecVariant {
    pub key: SpecKey,
    pub digest: String,
    pub run: KernelFn,
}

/// The native backend: resolves `(family, tuned config)` to specialized
/// variants and executes them. Cheap to share (`Arc`), internally
/// synchronized; worker threads dispatch concurrently through `&self`.
pub struct NativeEngine {
    /// When set, every dispatch uses the lane-1/block-1 variant — the
    /// single-thread-scalar reference the parity tests and BENCH_pr8's
    /// baseline leg run against.
    scalar_only: bool,
    /// Content-addressed variant registry (digest -> variant), the
    /// in-process analogue of the KB store's object directory.
    registry: RwLock<BTreeMap<String, Arc<SpecVariant>>>,
}

impl Default for NativeEngine {
    fn default() -> NativeEngine {
        NativeEngine::new()
    }
}

impl NativeEngine {
    /// The production engine: lane/block specialization enabled.
    pub fn new() -> NativeEngine {
        NativeEngine {
            scalar_only: false,
            registry: RwLock::new(BTreeMap::new()),
        }
    }

    /// The scalar reference engine: every family pinned to lanes=1,
    /// block=1. Used as the bit-exact baseline for parity tests and the
    /// single-thread-scalar leg of BENCH_pr8.
    pub fn scalar_reference() -> NativeEngine {
        NativeEngine {
            scalar_only: true,
            registry: RwLock::new(BTreeMap::new()),
        }
    }

    pub fn is_scalar_reference(&self) -> bool {
        self.scalar_only
    }

    /// Map a tuned work-group size to (lanes, block) and return the
    /// registered variant, monomorphizing on first use.
    pub fn specialize(&self, family: &str, chunk_units: u64, wgs: u32) -> Result<Arc<SpecVariant>> {
        let lanes = if self.scalar_only {
            1
        } else if wgs >= 256 {
            8
        } else if wgs >= 64 {
            4
        } else {
            1
        };
        let block = if self.scalar_only { 1 } else { wgs.max(1) };
        let key = SpecKey {
            family: family.to_string(),
            chunk_units,
            block,
            lanes,
        };
        let digest = key.digest();
        if let Some(v) = self.registry.read().unwrap().get(&digest) {
            return Ok(v.clone());
        }
        let run = kernels::select(family, lanes)?;
        let variant = Arc::new(SpecVariant {
            key,
            digest: digest.clone(),
            run,
        });
        let mut reg = self.registry.write().unwrap();
        Ok(reg.entry(digest).or_insert(variant).clone())
    }

    /// Execute one launch: `units` partition units of `info`'s family
    /// under the tuned work-group size `wgs`. Returns one plane per
    /// artifact output.
    pub fn run_chunk(
        &self,
        info: &ArtifactInfo,
        wgs: u32,
        units: u64,
        args: &[NativeArg],
    ) -> Result<Vec<Vec<f32>>> {
        let variant = self.specialize(&info.family, info.chunk_units, wgs)?;
        (variant.run)(info, &variant.key, units, args)
    }

    /// Number of distinct specializations materialized so far.
    pub fn variants(&self) -> usize {
        self.registry.read().unwrap().len()
    }

    /// Digest of the kernel set this engine executes. Folded into the
    /// scheduler's manifest digest so native profiles never collide with
    /// stub/sim/pjrt ones, and scalar-reference runs never warm-start a
    /// vectorized fleet.
    pub fn fingerprint(&self) -> String {
        sha256_hex(
            format!(
                "native-kernels-v1\0{}\0scalar_only={}",
                kernels::FAMILIES.join(","),
                self.scalar_only
            )
            .as_bytes(),
        )
    }
}

fn io(name: &str, shape: &[u64], dtype: &str) -> IoSpec {
    IoSpec {
        name: name.to_string(),
        shape: shape.to_vec(),
        dtype: dtype.to_string(),
    }
}

#[allow(clippy::too_many_arguments)]
fn art(
    name: String,
    family: &str,
    inputs: Vec<IoSpec>,
    outputs: Vec<IoSpec>,
    chunk_units: u64,
    flops: f64,
    bytes: f64,
) -> ArtifactInfo {
    ArtifactInfo {
        file: PathBuf::from(format!("{name}.native")),
        name,
        family: family.to_string(),
        inputs,
        outputs,
        chunk_units,
        flops,
        bytes,
    }
}

/// The native artifact menu — the same families, shapes, chunk menus and
/// analytic costs `python/compile/aot.py` emits for the PJRT path, so
/// decomposition, the simulator's cost model, and `pick_artifact` behave
/// identically under either backend. `dir` is a marker path; native
/// artifacts have no on-disk HLO.
pub fn builtin_manifest() -> Manifest {
    let mut by_family: BTreeMap<String, Vec<ArtifactInfo>> = BTreeMap::new();
    let mut add = |a: ArtifactInfo| by_family.entry(a.family.clone()).or_default().push(a);

    for n in [4096u64, 32_768, 262_144] {
        add(art(
            format!("saxpy_n{n}"),
            "saxpy",
            vec![
                io("alpha", &[1], "f32"),
                io("x", &[n], "f32"),
                io("y", &[n], "f32"),
            ],
            vec![io("out", &[n], "f32")],
            n,
            2.0 * n as f64,
            12.0 * n as f64,
        ));
    }

    for rows in [8u64, 64] {
        for w in [256u64, 512, 1024] {
            let px = (rows * w) as f64;
            add(art(
                format!("filter_pipeline_r{rows}_w{w}"),
                "filter_pipeline",
                vec![
                    io("img", &[rows, w], "f32"),
                    io("seed", &[1], "i32"),
                    io("row_off", &[1], "i32"),
                    io("thresh", &[1], "f32"),
                ],
                vec![io("out", &[rows, w], "f32")],
                rows,
                60.0 * px,
                8.0 * px,
            ));
        }
    }

    {
        let (rows, w) = (8u64, 512u64);
        let px = (rows * w) as f64;
        add(art(
            format!("gaussian_noise_r{rows}_w{w}"),
            "gaussian_noise",
            vec![
                io("img", &[rows, w], "f32"),
                io("seed", &[1], "i32"),
                io("row_off", &[1], "i32"),
            ],
            vec![io("out", &[rows, w], "f32")],
            rows,
            44.0 * px,
            8.0 * px,
        ));
        add(art(
            format!("solarize_r{rows}_w{w}"),
            "solarize",
            vec![io("img", &[rows, w], "f32"), io("thresh", &[1], "f32")],
            vec![io("out", &[rows, w], "f32")],
            rows,
            2.0 * px,
            8.0 * px,
        ));
        add(art(
            format!("mirror_r{rows}_w{w}"),
            "mirror",
            vec![io("img", &[rows, w], "f32")],
            vec![io("out", &[rows, w], "f32")],
            rows,
            0.0,
            8.0 * px,
        ));
    }

    for b in [4u64, 32] {
        let n = 512u64;
        add(art(
            format!("fft_roundtrip_b{b}_n{n}"),
            "fft_roundtrip",
            vec![io("re", &[b, n], "f32"), io("im", &[b, n], "f32")],
            vec![io("re_out", &[b, n], "f32"), io("im_out", &[b, n], "f32")],
            b,
            2.0 * (b * 5 * n * 9) as f64,
            16.0 * (b * n) as f64,
        ));
    }

    // Every body count carries a chunk equal to the family quantum (128):
    // the partitioner aligns task sizes to the smallest chunk of the
    // *family*, while `pick_artifact`'s COPY shape check filters by body
    // count — so each N needs a quantum-sized artifact to stay pickable.
    for (total, chunk) in [(512u64, 128u64), (2048, 128), (2048, 256)] {
        add(art(
            format!("nbody_accel_N{total}_c{chunk}"),
            "nbody_accel",
            vec![io("pos", &[total, 4], "f32"), io("offset", &[1], "i32")],
            vec![io("acc", &[chunk, 3], "f32")],
            chunk,
            20.0 * (chunk * total) as f64,
            16.0 * total as f64 + 12.0 * chunk as f64,
        ));
    }

    for d in [8u64, 64] {
        let (h, w) = (32u64, 32u64);
        let vox = (d * h * w) as f64;
        add(art(
            format!("segmentation_d{d}_h{h}_w{w}"),
            "segmentation",
            vec![
                io("vol", &[d, h, w], "f32"),
                io("thresholds", &[2], "f32"),
            ],
            vec![io("out", &[d, h, w], "f32")],
            d,
            2.0 * vox,
            8.0 * vox,
        ));
    }

    // Irregular tier (ROADMAP item 4): shapes carry the *padded* storage;
    // actual cost is data-dependent, so the analytic flops/bytes here are
    // upper bounds and the KB's per-class models absorb the spread.
    for rows in [256u64, 1024] {
        let (k_pad, n_cols) = (16u64, 4096u64);
        add(art(
            format!("spmv_csr_r{rows}_k{k_pad}"),
            "spmv_csr",
            vec![
                io("cols", &[rows, k_pad], "f32"),
                io("vals", &[rows, k_pad], "f32"),
                io("x", &[n_cols], "f32"),
            ],
            vec![io("out", &[rows], "f32")],
            rows,
            2.0 * (rows * k_pad) as f64,
            12.0 * (rows * k_pad) as f64,
        ));
    }

    for nodes in [256u64, 1024] {
        let (deg_pad, n_nodes) = (8u64, 4096u64);
        add(art(
            format!("bfs_frontier_n{nodes}_d{deg_pad}"),
            "bfs_frontier",
            vec![
                io("adj", &[nodes, deg_pad], "f32"),
                io("frontier", &[n_nodes], "f32"),
            ],
            vec![io("out", &[nodes], "f32")],
            nodes,
            (nodes * deg_pad) as f64,
            8.0 * (nodes * deg_pad) as f64,
        ));
    }

    for px in [4096u64, 32_768] {
        add(art(
            format!("mandelbrot_p{px}"),
            "mandelbrot",
            vec![
                io("c_re", &[px], "f32"),
                io("c_im", &[px], "f32"),
                io("max_iters", &[1], "i32"),
            ],
            vec![io("out", &[px], "f32")],
            px,
            // Mean-iteration estimate; the true count is per-pixel.
            10.0 * 8.0 * px as f64,
            12.0 * px as f64,
        ));
    }

    Manifest {
        by_family,
        dir: PathBuf::from("<native-builtin>"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specialization_is_content_addressed_and_cached() {
        let eng = NativeEngine::new();
        let a = eng.specialize("saxpy", 4096, 256).unwrap();
        let b = eng.specialize("saxpy", 4096, 256).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "same key must share one variant");
        let c = eng.specialize("saxpy", 4096, 64).unwrap();
        assert_ne!(a.digest, c.digest, "different wgs -> different variant");
        assert_eq!(eng.variants(), 2);
        assert_eq!(a.key.lanes, 8);
        assert_eq!(c.key.lanes, 4);
    }

    #[test]
    fn scalar_reference_pins_lane_and_block() {
        let eng = NativeEngine::scalar_reference();
        let v = eng.specialize("nbody_accel", 256, 256).unwrap();
        assert_eq!((v.key.lanes, v.key.block), (1, 1));
        assert_ne!(
            eng.fingerprint(),
            NativeEngine::new().fingerprint(),
            "scalar reference must live in its own digest space"
        );
    }

    #[test]
    fn builtin_manifest_covers_all_native_families() {
        let m = builtin_manifest();
        for f in kernels::FAMILIES {
            assert!(m.family(f).is_ok(), "missing family {f}");
        }
        // Chunk menus must be ascending so best_chunk's reverse scan
        // picks the largest divisor.
        for arts in m.by_family.values() {
            for pair in arts.windows(2) {
                assert!(pair[0].chunk_units <= pair[1].chunk_units);
            }
        }
        assert_eq!(m.family("saxpy").unwrap().len(), 3);
        assert_eq!(m.family("fft_roundtrip").unwrap()[1].outputs.len(), 2);
    }

    #[test]
    fn unknown_family_is_a_clean_error() {
        let eng = NativeEngine::new();
        assert!(eng.specialize("sparse_spmv", 64, 256).is_err());
    }
}
