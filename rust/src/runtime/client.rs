//! PJRT client wrapper with an executable cache.
//!
//! HLO *text* is the interchange format (see /opt/xla-example/README.md):
//! jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; `HloModuleProto::from_text_file` reassigns
//! ids and round-trips cleanly.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use crate::error::{Error, Result};
use crate::runtime::artifacts::ArtifactInfo;

// Without the `pjrt` feature the stub shim supplies the same API surface:
// functional host literals, unavailable client (see `runtime::stub`).
#[cfg(not(feature = "pjrt"))]
use crate::runtime::stub as xla;

/// A compiled-executable cache keyed by artifact name over one PJRT CPU
/// client. Compilation happens once per artifact per process (measured in
/// the perf pass: ~10-200 ms each, far too slow for the request path).
pub struct RtClient {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    /// Serializes compile/execute entry when the native binding is not
    /// thread-safe: the concurrent launcher's workers take this gate (via
    /// [`RtClient::exclusive`]) around every task in `pjrt` builds. Owned
    /// by the client — not a scheduler — so any number of schedulers or
    /// sessions sharing one client contend on the *same* lock.
    gate: Mutex<()>,
}

// The concurrent launcher shares one `RtClient` across its per-slot worker
// threads. In `pjrt` builds every chunk-launch path (`ChunkRunner`) holds
// the client's own gate while it compiles or executes, so the native
// binding is never entered concurrently through the runtime. Callers that
// bypass `ChunkRunner` and drive `run`/`compile_file` from multiple
// threads themselves must take `exclusive()` first — that is the client's
// threading contract. The stub build's client is a plain host-side struct.
#[cfg(feature = "pjrt")]
unsafe impl Send for RtClient {}
#[cfg(feature = "pjrt")]
unsafe impl Sync for RtClient {}

impl RtClient {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<RtClient> {
        Ok(RtClient {
            client: xla::PjRtClient::cpu()?,
            cache: Mutex::new(HashMap::new()),
            gate: Mutex::new(()),
        })
    }

    /// A client for backends that never execute through PJRT (the native
    /// CPU backend, DESIGN.md §2.11). In stub builds this constructs the
    /// host-side placeholder directly — `ChunkRunner` still wants a client
    /// for its pjrt paths, but the native dispatch seam branches before
    /// any compile/execute call, so the placeholder is never entered. In
    /// `pjrt` builds the real CPU client doubles as the offline one.
    pub fn offline() -> Result<RtClient> {
        #[cfg(feature = "pjrt")]
        {
            RtClient::cpu()
        }
        #[cfg(not(feature = "pjrt"))]
        {
            Ok(RtClient {
                client: xla::PjRtClient,
                cache: Mutex::new(HashMap::new()),
                gate: Mutex::new(()),
            })
        }
    }

    /// Exclusive access to the native binding (see the Send/Sync note
    /// above). Hold the returned guard across compile/execute sequences
    /// that must not interleave with other threads.
    pub fn exclusive(&self) -> std::sync::MutexGuard<'_, ()> {
        self.gate.lock().unwrap()
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text file (uncached).
    pub fn compile_file(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Artifact(format!("bad path {path:?}")))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(exe)
    }

    /// Get (or compile and cache) the executable for an artifact.
    pub fn executable(
        &self,
        info: &ArtifactInfo,
    ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        {
            let cache = self.cache.lock().unwrap();
            if let Some(e) = cache.get(&info.name) {
                return Ok(e.clone());
            }
        }
        let exe = std::sync::Arc::new(self.compile_file(&info.file)?);
        self.cache
            .lock()
            .unwrap()
            .insert(info.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Number of executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Execute with literal inputs; returns the flattened output tuple.
    /// (AOT lowering uses `return_tuple=True`, so the root is always a
    /// tuple — unpacked here into its leaves.)
    pub fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let result = exe.execute::<xla::Literal>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        let tuple = lit.to_tuple()?;
        Ok(tuple)
    }
}

/// Build an f32 literal of the given logical shape from a host slice.
pub fn literal_f32(data: &[f32], shape: &[u64]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    let reshaped = lit.reshape(&dims)?;
    Ok(reshaped)
}

/// Build an i32 literal of the given logical shape.
pub fn literal_i32(data: &[i32], shape: &[u64]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    let reshaped = lit.reshape(&dims)?;
    Ok(reshaped)
}

/// Extract an f32 buffer from a literal.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    let v = lit.to_vec::<f32>()?;
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Real-PJRT tests live in rust/tests/runtime_integration.rs (they need
    // `make artifacts`); here we only cover the literal helpers.

    #[test]
    fn literal_roundtrip_f32() {
        let data = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = literal_f32(&data, &[2, 3]).unwrap();
        assert_eq!(to_vec_f32(&lit).unwrap(), data.to_vec());
    }

    #[test]
    fn literal_roundtrip_i32() {
        let lit = literal_i32(&[7, 8], &[2]).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![7, 8]);
    }

    #[test]
    fn literal_shape_mismatch_fails() {
        assert!(literal_f32(&[1.0, 2.0, 3.0], &[2, 2]).is_err());
    }
}
