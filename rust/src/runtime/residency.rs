//! Buffer-residency layer (DESIGN.md §2.6): keeps partition data
//! device-resident across chunk launches, pipeline stages, `Loop`
//! iterations and — because the pool outlives a request — repeated requests
//! over the same workload.
//!
//! The paper attributes a large share of its gains to exactly this
//! property: consecutive kernels see identical partitionings, so a
//! partition's data is uploaded once and never moves between devices
//! (Section 3.1). The pool makes that contract explicit: each execution
//! slot owns a map of resident ranges keyed by `(argument, unit range,
//! version)`. An upload is performed at most once per key per slot;
//! host-side updates invalidate by bumping the version (stale entries are
//! evicted lazily or via [`ResidencyPool::invalidate_arg`]).
//!
//! Two backends share the layer:
//!  * the real chunk runner caches the *staged* host buffer per key, so
//!    repeated launches skip the slice-copy and the accounting mirrors what
//!    a device-resident backend avoids re-uploading;
//!  * the simulator books the same uploads / reuses / migrations against
//!    its analytic clock, so Sim and Real agree in shape.
//!
//! The pool is also the oracle for locality-aware stealing: a thief prices
//! a candidate steal by the victim task's resident bytes
//! ([`ResidencyView::resident_range_bytes`]) and books the migration when
//! it goes through ([`ResidencyView::note_migration`]).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::decompose::ExecSlot;
use crate::error::Result;

/// Identity of one argument stream inside the pool.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ArgKey {
    /// Request input vector `idx` (`request` is the workload fingerprint:
    /// hash of SCT id, domain size and argument data — see
    /// [`request_fingerprint`]).
    Input { request: u64, idx: u32 },
    /// Pipeline-stage intermediate: output `out` of stage `stage`.
    Stage { request: u64, stage: u32, out: u32 },
}

/// One resident range: `(argument, unit range, version)`. Bumping the
/// version makes every older entry unreachable (host updates after a
/// global-sync `Loop` iteration invalidate this way).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ResidencyKey {
    pub arg: ArgKey,
    pub start_unit: u64,
    pub units: u64,
    pub version: u64,
}

/// Transfer accounting of one request (or one pool lifetime). All counters
/// are monotonic; per-request numbers are deltas between two snapshots.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TransferStats {
    /// Bytes actually shipped host -> device.
    pub bytes_uploaded: u64,
    /// Bytes shipped device -> host (result readback).
    pub bytes_downloaded: u64,
    /// Uploads performed (distinct transfer events).
    pub uploads: u64,
    /// Uploads skipped because the range was already resident (chunk
    /// re-launches, pipeline intermediates, Loop iterations, repeated
    /// requests).
    pub uploads_avoided: u64,
    /// Bytes those avoided uploads would have shipped.
    pub uploads_avoided_bytes: u64,
    /// Uploads that still crossed the link but were hidden under compute
    /// by the prefetch pipeline (DESIGN.md §2.12) — off the critical path.
    pub uploads_overlapped: u64,
    /// Bytes of those overlapped uploads.
    pub uploads_overlapped_bytes: u64,
    /// Steals that moved a task away from data it had resident (booked by
    /// the locality-aware launcher).
    pub steal_migrations: u64,
    /// Bytes those migrations forfeited (they must re-upload at the thief).
    pub migrated_bytes: u64,
    /// Steal attempts the launcher rejected because the estimated
    /// migration cost exceeded the expected wait.
    pub steals_skipped: u64,
}

impl TransferStats {
    /// Delta of `self` since `earlier` (both snapshots of one pool).
    pub fn minus(&self, earlier: &TransferStats) -> TransferStats {
        TransferStats {
            bytes_uploaded: self.bytes_uploaded - earlier.bytes_uploaded,
            bytes_downloaded: self.bytes_downloaded - earlier.bytes_downloaded,
            uploads: self.uploads - earlier.uploads,
            uploads_avoided: self.uploads_avoided - earlier.uploads_avoided,
            uploads_avoided_bytes: self.uploads_avoided_bytes - earlier.uploads_avoided_bytes,
            uploads_overlapped: self.uploads_overlapped - earlier.uploads_overlapped,
            uploads_overlapped_bytes: self.uploads_overlapped_bytes
                - earlier.uploads_overlapped_bytes,
            steal_migrations: self.steal_migrations - earlier.steal_migrations,
            migrated_bytes: self.migrated_bytes - earlier.migrated_bytes,
            steals_skipped: self.steals_skipped - earlier.steals_skipped,
        }
    }

    /// Fold another request's counters in.
    pub fn accumulate(&mut self, other: &TransferStats) {
        self.bytes_uploaded += other.bytes_uploaded;
        self.bytes_downloaded += other.bytes_downloaded;
        self.uploads += other.uploads;
        self.uploads_avoided += other.uploads_avoided;
        self.uploads_avoided_bytes += other.uploads_avoided_bytes;
        self.uploads_overlapped += other.uploads_overlapped;
        self.uploads_overlapped_bytes += other.uploads_overlapped_bytes;
        self.steal_migrations += other.steal_migrations;
        self.migrated_bytes += other.migrated_bytes;
        self.steals_skipped += other.steals_skipped;
    }

    /// Conservation quantity of the transfer accounting: every byte a
    /// request's working set needs on-device is either shipped on the
    /// critical path (`bytes_uploaded`), already resident
    /// (`uploads_avoided_bytes`) or shipped hidden under compute
    /// (`uploads_overlapped_bytes`). For a fixed request this sum is
    /// invariant across drain modes and prefetch depths — prefetch and
    /// residency move bytes *between* the three buckets, never in or out.
    pub fn accounted_upload_bytes(&self) -> u64 {
        self.bytes_uploaded + self.uploads_avoided_bytes + self.uploads_overlapped_bytes
    }
}

/// Estimated seconds to move `bytes` across a `link_gbps` GB/s link — the
/// shared migration-cost estimate used by the steal policy and the
/// simulator (one formula so Sim and Real agree in shape).
pub fn migration_secs(bytes: u64, link_gbps: f64) -> f64 {
    bytes as f64 / (link_gbps.max(1e-9) * 1e9)
}

/// The read side the work-stealing launcher needs: how much of a task's
/// data is resident on its home slot, and the hook to book a migration.
pub trait ResidencyView: Sync {
    /// Bytes of `[start_unit, start_unit+units)` resident on `slot`.
    fn resident_range_bytes(&self, slot: ExecSlot, start_unit: u64, units: u64) -> u64;

    /// Record that a steal moved the range off `from` (its residency there
    /// is forfeited and must re-upload at the thief). Returns the bytes
    /// the move forfeited.
    fn note_migration(&self, from: ExecSlot, to: ExecSlot, start_unit: u64, units: u64) -> u64;

    /// Record a steal attempt rejected on migration cost.
    fn note_steal_skipped(&self);
}

/// One resident entry: size, the staged host buffer (real runner only), an
/// LRU tick, and a consumer-refcount pin. Pinned entries (produced
/// intermediates whose consumer chunks have not all retired yet —
/// DESIGN.md §2.7) are exempt from LRU eviction: an intermediate must
/// never be dropped while a task still needs it on that device.
struct Resident {
    bytes: u64,
    staged: Option<Arc<Vec<f32>>>,
    tick: u64,
    pins: u32,
}

/// One in-flight prefetched range (DESIGN.md §2.12): the upload was issued
/// ahead of need under another node's compute and has not been consumed
/// yet. Pending entries count toward LRU capacity (the bytes are on the
/// device either way) but are never eviction candidates themselves; a
/// consuming `acquire` promotes the entry into the normal resident
/// lifecycle and books it as an *overlapped* upload, a steal of the
/// consumer cancels it without booking anything.
struct PendingUpload {
    bytes: u64,
    staged: Arc<Vec<f32>>,
}

/// Cap on per-slot recycled staging buffers (the bump-arena half of the
/// native locality work: hot chunk loops stop re-allocating).
const FREE_LIST_CAP: usize = 8;

#[derive(Default)]
struct SlotPool {
    entries: HashMap<ResidencyKey, Resident>,
    total_bytes: u64,
    pending: HashMap<ResidencyKey, PendingUpload>,
    pending_bytes: u64,
    /// Recycled staging buffers. Pages were first-touched by this slot's
    /// pinned worker, so reuse keeps the NUMA placement.
    free: Vec<Vec<f32>>,
}

impl SlotPool {
    /// Return a retired staging buffer to the arena if it has no other
    /// owners; otherwise let it drop.
    fn reclaim(free: &mut Vec<Vec<f32>>, staged: Option<Arc<Vec<f32>>>) {
        if free.len() >= FREE_LIST_CAP {
            return;
        }
        if let Some(arc) = staged {
            if let Ok(mut buf) = Arc::try_unwrap(arc) {
                buf.clear();
                free.push(buf);
            }
        }
    }
}

/// The per-slot residency pool. Shared by reference across the launcher's
/// worker threads; every counter is atomic and the maps lock internally.
pub struct ResidencyPool {
    slots: Mutex<HashMap<ExecSlot, SlotPool>>,
    /// When disabled, every acquire re-uploads (the ablation baseline).
    enabled: AtomicBool,
    /// Per-slot byte budget; 0 = unbounded. LRU eviction on overflow.
    capacity_bytes: AtomicU64,
    tick: AtomicU64,
    bytes_uploaded: AtomicU64,
    bytes_downloaded: AtomicU64,
    uploads: AtomicU64,
    uploads_avoided: AtomicU64,
    uploads_avoided_bytes: AtomicU64,
    uploads_overlapped: AtomicU64,
    uploads_overlapped_bytes: AtomicU64,
    steal_migrations: AtomicU64,
    migrated_bytes: AtomicU64,
    steals_skipped: AtomicU64,
}

impl Default for ResidencyPool {
    fn default() -> ResidencyPool {
        ResidencyPool::new()
    }
}

impl ResidencyPool {
    pub fn new() -> ResidencyPool {
        ResidencyPool {
            slots: Mutex::new(HashMap::new()),
            enabled: AtomicBool::new(true),
            capacity_bytes: AtomicU64::new(0),
            tick: AtomicU64::new(0),
            bytes_uploaded: AtomicU64::new(0),
            bytes_downloaded: AtomicU64::new(0),
            uploads: AtomicU64::new(0),
            uploads_avoided: AtomicU64::new(0),
            uploads_avoided_bytes: AtomicU64::new(0),
            uploads_overlapped: AtomicU64::new(0),
            uploads_overlapped_bytes: AtomicU64::new(0),
            steal_migrations: AtomicU64::new(0),
            migrated_bytes: AtomicU64::new(0),
            steals_skipped: AtomicU64::new(0),
        }
    }

    /// Bound each slot's resident set (bytes); LRU-evicts on overflow.
    pub fn with_capacity(self, bytes: u64) -> ResidencyPool {
        self.capacity_bytes.store(bytes, Ordering::Relaxed);
        self
    }

    /// Toggle the layer (off = every acquire uploads; the A/B baseline).
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    fn next_tick(&self) -> u64 {
        self.tick.fetch_add(1, Ordering::Relaxed)
    }

    fn count_upload(&self, bytes: u64) {
        self.uploads.fetch_add(1, Ordering::Relaxed);
        self.bytes_uploaded.fetch_add(bytes, Ordering::Relaxed);
    }

    fn count_avoided(&self, bytes: u64) {
        self.uploads_avoided.fetch_add(1, Ordering::Relaxed);
        self.uploads_avoided_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    fn count_overlapped(&self, bytes: u64) {
        self.uploads_overlapped.fetch_add(1, Ordering::Relaxed);
        self.uploads_overlapped_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Reclassify `count` already-booked uploads (of `bytes` total) as
    /// overlapped — the simulator's hook: it books uploads first through
    /// the shared `ensure_resident` path, then moves the portion its
    /// occupancy model proves hidden under compute into the overlapped
    /// bucket. The conservation sum
    /// ([`TransferStats::accounted_upload_bytes`]) is unchanged.
    pub fn reclassify_overlapped(&self, count: u64, bytes: u64) {
        if count == 0 && bytes == 0 {
            return;
        }
        let prev_u = self.uploads.fetch_sub(count, Ordering::Relaxed);
        let prev_b = self.bytes_uploaded.fetch_sub(bytes, Ordering::Relaxed);
        debug_assert!(
            prev_u >= count && prev_b >= bytes,
            "reclassify_overlapped must not exceed booked uploads"
        );
        self.uploads_overlapped.fetch_add(count, Ordering::Relaxed);
        self.uploads_overlapped_bytes
            .fetch_add(bytes, Ordering::Relaxed);
    }

    /// Accounting-only residency check (the simulator's path): records an
    /// upload when the key is absent, an avoided upload when present.
    /// Returns whether the range was already resident.
    pub fn ensure_resident(&self, slot: ExecSlot, key: ResidencyKey, bytes: u64) -> bool {
        if !self.enabled() {
            self.count_upload(bytes);
            return false;
        }
        let tick = self.next_tick();
        let capacity = self.capacity_bytes.load(Ordering::Relaxed);
        let resident = {
            let mut slots = self.slots.lock().unwrap();
            let pool = slots.entry(slot).or_default();
            if let Some(e) = pool.entries.get_mut(&key) {
                e.tick = tick;
                true
            } else {
                pool.entries.insert(
                    key,
                    Resident {
                        bytes,
                        staged: None,
                        tick,
                        pins: 0,
                    },
                );
                pool.total_bytes += bytes;
                Self::evict_over_capacity(pool, capacity);
                false
            }
        };
        if resident {
            self.count_avoided(bytes);
        } else {
            self.count_upload(bytes);
        }
        resident
    }

    /// Staged-buffer acquire (the real chunk runner's path): returns the
    /// cached host-staged buffer for `key` on `slot`, or stages it by
    /// running `fill` into a (recycled, first-touched) buffer and records
    /// the upload. A cache hit counts as an avoided upload — the range is
    /// already resident on the slot. A hit on an in-flight prefetch
    /// promotes the `PendingUpload` into the resident set and books the
    /// transfer as *overlapped* — it crossed the link, but under compute.
    pub fn acquire<F>(
        &self,
        slot: ExecSlot,
        key: ResidencyKey,
        bytes: u64,
        fill: F,
    ) -> Result<Arc<Vec<f32>>>
    where
        F: FnOnce(&mut Vec<f32>) -> Result<()>,
    {
        if !self.enabled() {
            self.count_upload(bytes);
            let mut buf = Vec::new();
            fill(&mut buf)?;
            return Ok(Arc::new(buf));
        }
        let tick = self.next_tick();
        enum Hit {
            Resident(Arc<Vec<f32>>),
            Prefetched(Arc<Vec<f32>>),
            Miss(Vec<f32>),
        }
        let hit = {
            let mut slots = self.slots.lock().unwrap();
            let pool = slots.entry(slot).or_default();
            if let Some(staged) = pool.entries.get_mut(&key).and_then(|e| {
                e.tick = tick;
                e.staged.clone()
            }) {
                Hit::Resident(staged)
            } else if let Some(p) = pool.pending.remove(&key) {
                // Promote the in-flight prefetch into the normal resident
                // lifecycle: the bytes were already on the device.
                pool.pending_bytes -= p.bytes;
                let staged = p.staged;
                if pool
                    .entries
                    .insert(
                        key,
                        Resident {
                            bytes: p.bytes,
                            staged: Some(staged.clone()),
                            tick,
                            pins: 0,
                        },
                    )
                    .is_none()
                {
                    pool.total_bytes += p.bytes;
                }
                Hit::Prefetched(staged)
            } else {
                Hit::Miss(pool.free.pop().unwrap_or_default())
            }
        };
        let mut buf = match hit {
            Hit::Resident(staged) => {
                self.count_avoided(bytes);
                return Ok(staged);
            }
            Hit::Prefetched(staged) => {
                self.count_overlapped(bytes);
                return Ok(staged);
            }
            Hit::Miss(buf) => buf,
        };
        // First-touch the buffer's pages on the calling (pinned) worker's
        // core before filling, so the staged slice lands NUMA-local.
        crate::runtime::native::affinity::first_touch_pages(&mut buf, (bytes / 4) as usize);
        fill(&mut buf)?;
        let staged = Arc::new(buf);
        {
            let mut slots = self.slots.lock().unwrap();
            let pool = slots.entry(slot).or_default();
            if pool
                .entries
                .insert(
                    key,
                    Resident {
                        bytes,
                        staged: Some(staged.clone()),
                        tick,
                        pins: 0,
                    },
                )
                .is_none()
            {
                pool.total_bytes += bytes;
            }
            Self::evict_over_capacity(pool, self.capacity_bytes.load(Ordering::Relaxed));
        }
        self.count_upload(bytes);
        Ok(staged)
    }

    /// Stage `key` ahead of need (the prefetch pipeline, DESIGN.md §2.12):
    /// fills a recycled buffer and parks it as a `PendingUpload` on `slot`.
    /// Nothing is booked here — the accounting happens when a consuming
    /// [`ResidencyPool::acquire`] promotes the entry (overlapped) or a
    /// cancellation drops it (free). Returns whether a prefetch was
    /// actually issued; already-resident, already-pending and disabled
    /// pools are all no-ops.
    pub fn prefetch_range<F>(
        &self,
        slot: ExecSlot,
        key: ResidencyKey,
        bytes: u64,
        fill: F,
    ) -> Result<bool>
    where
        F: FnOnce(&mut Vec<f32>) -> Result<()>,
    {
        if !self.enabled() {
            return Ok(false);
        }
        let mut buf = {
            let mut slots = self.slots.lock().unwrap();
            let pool = slots.entry(slot).or_default();
            if pool.entries.contains_key(&key) || pool.pending.contains_key(&key) {
                return Ok(false);
            }
            pool.free.pop().unwrap_or_default()
        };
        crate::runtime::native::affinity::first_touch_pages(&mut buf, (bytes / 4) as usize);
        fill(&mut buf)?;
        let staged = Arc::new(buf);
        let mut slots = self.slots.lock().unwrap();
        let pool = slots.entry(slot).or_default();
        if pool.entries.contains_key(&key) || pool.pending.contains_key(&key) {
            // Raced with a concurrent stage of the same range: keep theirs,
            // recycle ours.
            SlotPool::reclaim(&mut pool.free, Some(staged));
            return Ok(false);
        }
        pool.pending.insert(key, PendingUpload { bytes, staged });
        pool.pending_bytes += bytes;
        Self::evict_over_capacity(pool, self.capacity_bytes.load(Ordering::Relaxed));
        Ok(true)
    }

    /// In-flight prefetch entries across every slot (diagnostics + the
    /// no-leak drain invariant: must be 0 after a request retires).
    pub fn pending_count(&self) -> usize {
        self.slots
            .lock()
            .unwrap()
            .values()
            .map(|p| p.pending.len())
            .sum()
    }

    /// Drop every in-flight prefetch (end of a graph drain: speculative
    /// uploads that no task consumed — a `Loop` broke early, a steal moved
    /// the consumer — must not leak into the next request). Buffers return
    /// to the arena; nothing is booked.
    pub fn clear_pending(&self) {
        let mut slots = self.slots.lock().unwrap();
        for pool in slots.values_mut() {
            for (_, p) in pool.pending.drain() {
                SlotPool::reclaim(&mut pool.free, Some(p.staged));
            }
            pool.pending_bytes = 0;
        }
    }

    fn evict_over_capacity(pool: &mut SlotPool, capacity: u64) {
        if capacity == 0 {
            return;
        }
        // In-flight prefetches occupy device memory too, so they add to
        // the pressure — but only resident, unpinned entries are eviction
        // candidates: a pending entry is about to be consumed, a pinned
        // one still has live consumers.
        while pool.total_bytes + pool.pending_bytes > capacity && pool.entries.len() > 1 {
            let oldest = pool
                .entries
                .iter()
                .filter(|(_, e)| e.pins == 0)
                .min_by_key(|(_, e)| e.tick)
                .map(|(k, _)| *k);
            match oldest {
                Some(k) => {
                    if let Some(e) = pool.entries.remove(&k) {
                        pool.total_bytes -= e.bytes;
                        SlotPool::reclaim(&mut pool.free, e.staged);
                    }
                }
                None => break,
            }
        }
    }

    /// Record an intermediate *produced on-device* (a pipeline stage's
    /// output landing on `slot`): resident without an upload — it never
    /// crossed the link — and pinned by its consumer count. The entry
    /// makes the range visible to the steal pricing
    /// ([`ResidencyView::resident_range_bytes`]) and is exempt from LRU
    /// eviction until [`ResidencyPool::unpin`] drops the last pin.
    pub fn pin_range(&self, slot: ExecSlot, key: ResidencyKey, bytes: u64, pins: u32) {
        if !self.enabled() {
            return;
        }
        let tick = self.next_tick();
        let mut slots = self.slots.lock().unwrap();
        let pool = slots.entry(slot).or_default();
        match pool.entries.get_mut(&key) {
            Some(e) => {
                e.tick = tick;
                e.pins = e.pins.saturating_add(pins);
            }
            None => {
                pool.entries.insert(
                    key,
                    Resident {
                        bytes,
                        staged: None,
                        tick,
                        pins,
                    },
                );
                pool.total_bytes += bytes;
            }
        }
    }

    /// Drop one pin of `key` wherever it is resident (the producing slot is
    /// unknown to the caller when the consumer ran elsewhere). Entries stay
    /// resident once unpinned — they just become ordinary LRU candidates.
    pub fn unpin(&self, key: &ResidencyKey) {
        let mut slots = self.slots.lock().unwrap();
        for pool in slots.values_mut() {
            if let Some(e) = pool.entries.get_mut(key) {
                e.pins = e.pins.saturating_sub(1);
            }
        }
    }

    /// Record a result readback.
    pub fn note_download(&self, bytes: u64) {
        self.bytes_downloaded.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Record an un-keyed upload (e.g. COPY-state re-broadcast at a global
    /// sync point — always re-shipped, never resident).
    pub fn note_upload(&self, bytes: u64) {
        self.count_upload(bytes);
    }

    /// Record `count` uploads (of `bytes` total) that residency made
    /// unnecessary without a keyed lookup — pipeline intermediates staying
    /// on-device, Loop iterations re-reading unchanged inputs. With the
    /// layer disabled these become real uploads (the ablation baseline).
    pub fn note_reuse(&self, count: u64, bytes: u64) {
        if self.enabled() {
            self.uploads_avoided.fetch_add(count, Ordering::Relaxed);
            self.uploads_avoided_bytes.fetch_add(bytes, Ordering::Relaxed);
        } else {
            self.uploads.fetch_add(count, Ordering::Relaxed);
            self.bytes_uploaded.fetch_add(bytes, Ordering::Relaxed);
        }
    }

    /// Drop every resident range of `arg` on every slot (host rewrote the
    /// argument; version-bumped keys would never match again, this frees
    /// the memory eagerly).
    pub fn invalidate_arg(&self, arg: ArgKey) {
        let mut slots = self.slots.lock().unwrap();
        for pool in slots.values_mut() {
            let stale: Vec<ResidencyKey> = pool
                .entries
                .keys()
                .filter(|k| k.arg == arg)
                .copied()
                .collect();
            for k in stale {
                if let Some(e) = pool.entries.remove(&k) {
                    pool.total_bytes -= e.bytes;
                    SlotPool::reclaim(&mut pool.free, e.staged);
                }
            }
            // In-flight prefetches of the rewritten argument are stale
            // speculation: drop them unconsumed, nothing booked.
            let stale_pending: Vec<ResidencyKey> = pool
                .pending
                .keys()
                .filter(|k| k.arg == arg)
                .copied()
                .collect();
            for k in stale_pending {
                if let Some(p) = pool.pending.remove(&k) {
                    pool.pending_bytes -= p.bytes;
                    SlotPool::reclaim(&mut pool.free, Some(p.staged));
                }
            }
        }
    }

    /// Total bytes resident on every slot matching `pred` (e.g. the
    /// devices a reservation mask excludes — the migration term of the
    /// co-scheduling admission price, DESIGN.md §2.8).
    pub fn resident_bytes_where<F: Fn(ExecSlot) -> bool>(&self, pred: F) -> u64 {
        self.slots
            .lock()
            .unwrap()
            .iter()
            .filter(|(slot, _)| pred(**slot))
            .map(|(_, p)| p.total_bytes)
            .sum()
    }

    /// Bytes resident on `slot` in total.
    pub fn resident_bytes(&self, slot: ExecSlot) -> u64 {
        self.slots
            .lock()
            .unwrap()
            .get(&slot)
            .map(|p| p.total_bytes)
            .unwrap_or(0)
    }

    /// Counter snapshot.
    pub fn stats(&self) -> TransferStats {
        TransferStats {
            bytes_uploaded: self.bytes_uploaded.load(Ordering::Relaxed),
            bytes_downloaded: self.bytes_downloaded.load(Ordering::Relaxed),
            uploads: self.uploads.load(Ordering::Relaxed),
            uploads_avoided: self.uploads_avoided.load(Ordering::Relaxed),
            uploads_avoided_bytes: self.uploads_avoided_bytes.load(Ordering::Relaxed),
            uploads_overlapped: self.uploads_overlapped.load(Ordering::Relaxed),
            uploads_overlapped_bytes: self.uploads_overlapped_bytes.load(Ordering::Relaxed),
            steal_migrations: self.steal_migrations.load(Ordering::Relaxed),
            migrated_bytes: self.migrated_bytes.load(Ordering::Relaxed),
            steals_skipped: self.steals_skipped.load(Ordering::Relaxed),
        }
    }
}

impl ResidencyView for ResidencyPool {
    fn resident_range_bytes(&self, slot: ExecSlot, start_unit: u64, units: u64) -> u64 {
        let q_end = start_unit + units;
        let slots = self.slots.lock().unwrap();
        let Some(pool) = slots.get(&slot) else {
            return 0;
        };
        let mut bytes = 0u64;
        for (k, e) in &pool.entries {
            let e_end = k.start_unit + k.units;
            let lo = k.start_unit.max(start_unit);
            let hi = e_end.min(q_end);
            if hi > lo && k.units > 0 {
                // Proportional share of the entry overlapping the query.
                bytes += e.bytes * (hi - lo) / k.units;
            }
        }
        bytes
    }

    fn note_migration(&self, from: ExecSlot, to: ExecSlot, start_unit: u64, units: u64) -> u64 {
        let _ = to;
        let q_end = start_unit + units;
        let mut forfeited = 0u64;
        {
            let mut slots = self.slots.lock().unwrap();
            if let Some(pool) = slots.get_mut(&from) {
                // Only ranges fully contained in the stolen task's span
                // move with it. Wider entries — whole-vector COPY
                // replicas, ranges of other tasks that merely overlap
                // numerically — stay useful to the victim and survive.
                let stale: Vec<ResidencyKey> = pool
                    .entries
                    .keys()
                    .filter(|k| k.start_unit >= start_unit && k.start_unit + k.units <= q_end)
                    .copied()
                    .collect();
                for k in stale {
                    if let Some(e) = pool.entries.remove(&k) {
                        pool.total_bytes -= e.bytes;
                        forfeited += e.bytes;
                        SlotPool::reclaim(&mut pool.free, e.staged);
                    }
                }
                // Cancellation-on-steal (DESIGN.md §2.12): in-flight
                // prefetches for the migrated range target a consumer that
                // will now run elsewhere. Cancel them without booking —
                // they were speculative, not forfeited residency.
                let stale_pending: Vec<ResidencyKey> = pool
                    .pending
                    .keys()
                    .filter(|k| k.start_unit >= start_unit && k.start_unit + k.units <= q_end)
                    .copied()
                    .collect();
                for k in stale_pending {
                    if let Some(p) = pool.pending.remove(&k) {
                        pool.pending_bytes -= p.bytes;
                        SlotPool::reclaim(&mut pool.free, Some(p.staged));
                    }
                }
            }
        }
        if forfeited > 0 {
            self.steal_migrations.fetch_add(1, Ordering::Relaxed);
            self.migrated_bytes.fetch_add(forfeited, Ordering::Relaxed);
        }
        forfeited
    }

    fn note_steal_skipped(&self) {
        self.steals_skipped.fetch_add(1, Ordering::Relaxed);
    }
}

/// Stable fingerprint of one request identity: SCT id + domain size + a
/// cheap content probe of each vector argument (length plus head/tail
/// words). Two requests with the same fingerprint are assumed to carry the
/// same data, so their resident ranges are interchangeable; any host-side
/// rewrite in between must bump the argument version instead.
pub fn request_fingerprint(sct_id: &str, total_units: u64, vector_probes: &[u64]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut mix = |v: u64| {
        h ^= v;
        h = h.wrapping_mul(0x100000001b3);
    };
    for b in sct_id.as_bytes() {
        mix(*b as u64);
    }
    mix(total_units);
    for p in vector_probes {
        mix(*p);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gpu(slot: u32) -> ExecSlot {
        ExecSlot::GpuSlot { gpu: 0, slot }
    }

    fn key(idx: u32, start: u64, units: u64, version: u64) -> ResidencyKey {
        ResidencyKey {
            arg: ArgKey::Input { request: 1, idx },
            start_unit: start,
            units,
            version,
        }
    }

    #[test]
    fn second_ensure_is_avoided() {
        let pool = ResidencyPool::new();
        assert!(!pool.ensure_resident(gpu(0), key(0, 0, 128, 0), 512));
        assert!(pool.ensure_resident(gpu(0), key(0, 0, 128, 0), 512));
        let s = pool.stats();
        assert_eq!(s.uploads, 1);
        assert_eq!(s.bytes_uploaded, 512);
        assert_eq!(s.uploads_avoided, 1);
    }

    #[test]
    fn residency_is_per_slot() {
        let pool = ResidencyPool::new();
        pool.ensure_resident(gpu(0), key(0, 0, 128, 0), 512);
        assert!(!pool.ensure_resident(gpu(1), key(0, 0, 128, 0), 512));
        assert_eq!(pool.stats().uploads, 2);
    }

    #[test]
    fn version_bump_invalidates() {
        let pool = ResidencyPool::new();
        pool.ensure_resident(gpu(0), key(0, 0, 128, 0), 512);
        assert!(!pool.ensure_resident(gpu(0), key(0, 0, 128, 1), 512));
    }

    #[test]
    fn acquire_caches_staged_buffer() {
        let pool = ResidencyPool::new();
        let a = pool
            .acquire(gpu(0), key(0, 0, 4, 0), 16, |buf| {
                buf.extend_from_slice(&[1.0, 2.0, 3.0, 4.0]);
                Ok(())
            })
            .unwrap();
        let b = pool
            .acquire(gpu(0), key(0, 0, 4, 0), 16, |_| {
                panic!("must not re-stage a resident range")
            })
            .unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(a.as_slice(), &[1.0, 2.0, 3.0, 4.0]);
        let s = pool.stats();
        assert_eq!(s.uploads_avoided, 1);
        assert_eq!(s.uploads_avoided_bytes, 16);
    }

    #[test]
    fn prefetch_promotes_to_overlapped_on_acquire() {
        let pool = ResidencyPool::new();
        let issued = pool
            .prefetch_range(gpu(0), key(0, 0, 4, 0), 16, |buf| {
                buf.extend_from_slice(&[1.0, 2.0, 3.0, 4.0]);
                Ok(())
            })
            .unwrap();
        assert!(issued);
        assert_eq!(pool.pending_count(), 1);
        // Nothing booked while in flight.
        assert_eq!(pool.stats(), TransferStats::default());
        let a = pool
            .acquire(gpu(0), key(0, 0, 4, 0), 16, |_| {
                panic!("prefetched range must not re-stage")
            })
            .unwrap();
        assert_eq!(a.as_slice(), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(pool.pending_count(), 0);
        let s = pool.stats();
        assert_eq!(s.uploads, 0, "overlapped upload is off the critical path");
        assert_eq!(s.uploads_overlapped, 1);
        assert_eq!(s.uploads_overlapped_bytes, 16);
        // A second acquire is a plain residency hit.
        let b = pool
            .acquire(gpu(0), key(0, 0, 4, 0), 16, |_| {
                panic!("must not re-stage a resident range")
            })
            .unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(pool.stats().uploads_avoided, 1);
    }

    #[test]
    fn prefetch_is_idempotent_against_resident_and_pending() {
        let pool = ResidencyPool::new();
        pool.acquire(gpu(0), key(0, 0, 4, 0), 16, |buf| {
            buf.extend_from_slice(&[1.0; 4]);
            Ok(())
        })
        .unwrap();
        // Already resident: no prefetch.
        assert!(!pool
            .prefetch_range(gpu(0), key(0, 0, 4, 0), 16, |_| panic!(
                "must not stage over a resident range"
            ))
            .unwrap());
        // First prefetch of a new range goes through, the second is a no-op.
        assert!(pool
            .prefetch_range(gpu(0), key(1, 0, 4, 0), 16, |buf| {
                buf.extend_from_slice(&[2.0; 4]);
                Ok(())
            })
            .unwrap());
        assert!(!pool
            .prefetch_range(gpu(0), key(1, 0, 4, 0), 16, |_| panic!(
                "must not stage over a pending range"
            ))
            .unwrap());
        assert_eq!(pool.pending_count(), 1);
    }

    #[test]
    fn steal_cancels_inflight_prefetch_without_booking() {
        let pool = ResidencyPool::new();
        pool.prefetch_range(gpu(0), key(0, 0, 64, 0), 256, |buf| {
            buf.extend_from_slice(&[0.0; 64]);
            Ok(())
        })
        .unwrap();
        let moved = pool.note_migration(gpu(0), ExecSlot::CpuSub { idx: 0 }, 0, 64);
        assert_eq!(moved, 0, "a cancelled prefetch is not forfeited residency");
        assert_eq!(pool.pending_count(), 0);
        let s = pool.stats();
        assert_eq!(s.steal_migrations, 0);
        assert_eq!(s.uploads_overlapped, 0);
        assert_eq!(s.uploads, 0);
    }

    #[test]
    fn clear_pending_drops_inflight_prefetches() {
        let pool = ResidencyPool::new();
        pool.prefetch_range(gpu(0), key(0, 0, 64, 0), 256, |buf| {
            buf.extend_from_slice(&[0.0; 64]);
            Ok(())
        })
        .unwrap();
        pool.prefetch_range(gpu(1), key(1, 0, 64, 0), 256, |buf| {
            buf.extend_from_slice(&[0.0; 64]);
            Ok(())
        })
        .unwrap();
        assert_eq!(pool.pending_count(), 2);
        pool.clear_pending();
        assert_eq!(pool.pending_count(), 0);
        assert_eq!(pool.stats(), TransferStats::default());
        // The ranges are stageable again afterwards.
        assert!(pool
            .prefetch_range(gpu(0), key(0, 0, 64, 0), 256, |buf| {
                buf.extend_from_slice(&[0.0; 64]);
                Ok(())
            })
            .unwrap());
    }

    #[test]
    fn prefetch_pressure_never_evicts_pinned_entries() {
        let pool = ResidencyPool::new().with_capacity(1024);
        let stage_key = ResidencyKey {
            arg: ArgKey::Stage {
                request: 1,
                stage: 0,
                out: 0,
            },
            start_unit: 0,
            units: 64,
            version: 0,
        };
        pool.pin_range(gpu(0), stage_key, 600, 1);
        pool.ensure_resident(gpu(0), key(7, 0, 128, 0), 300);
        // A prefetch pushing the slot over budget evicts the unpinned
        // resident entry, never the pinned intermediate and never itself.
        pool.prefetch_range(gpu(0), key(8, 0, 128, 0), 600, |buf| {
            buf.extend_from_slice(&[0.0; 150]);
            Ok(())
        })
        .unwrap();
        assert_eq!(
            pool.resident_range_bytes(gpu(0), 0, 64),
            600,
            "pinned intermediate must survive prefetch pressure"
        );
        assert_eq!(pool.pending_count(), 1);
    }

    #[test]
    fn arena_recycles_staging_buffers() {
        let pool = ResidencyPool::new().with_capacity(1024);
        let a = pool
            .acquire(gpu(0), key(0, 0, 150, 0), 600, |buf| {
                buf.extend_from_slice(&[1.0; 150]);
                Ok(())
            })
            .unwrap();
        let p = a.as_ptr();
        drop(a); // the pool now holds the only reference
        // Capacity pressure evicts key 0; its buffer returns to the arena.
        pool.acquire(gpu(0), key(1, 0, 150, 0), 600, |buf| {
            buf.extend_from_slice(&[2.0; 150]);
            Ok(())
        })
        .unwrap();
        // The next stage on this slot reuses the recycled buffer.
        let c = pool
            .acquire(gpu(0), key(2, 0, 150, 0), 600, |buf| {
                buf.extend_from_slice(&[3.0; 150]);
                Ok(())
            })
            .unwrap();
        assert_eq!(c.as_ptr(), p, "staging buffer must be recycled in place");
        assert_eq!(c[0], 3.0);
    }

    #[test]
    fn reclassify_keeps_accounting_conserved() {
        let pool = ResidencyPool::new();
        pool.ensure_resident(gpu(0), key(0, 0, 128, 0), 512);
        pool.ensure_resident(gpu(0), key(1, 0, 128, 0), 512);
        let before = pool.stats();
        pool.reclassify_overlapped(1, 512);
        let after = pool.stats();
        assert_eq!(
            after.accounted_upload_bytes(),
            before.accounted_upload_bytes(),
            "reclassification moves bytes between buckets, never creates them"
        );
        assert_eq!(after.uploads, 1);
        assert_eq!(after.uploads_overlapped, 1);
        assert_eq!(after.uploads_overlapped_bytes, 512);
    }

    #[test]
    fn disabled_pool_always_uploads() {
        let pool = ResidencyPool::new();
        pool.set_enabled(false);
        pool.ensure_resident(gpu(0), key(0, 0, 128, 0), 512);
        pool.ensure_resident(gpu(0), key(0, 0, 128, 0), 512);
        let s = pool.stats();
        assert_eq!(s.uploads, 2);
        assert_eq!(s.uploads_avoided, 0);
    }

    #[test]
    fn capacity_evicts_lru() {
        let pool = ResidencyPool::new().with_capacity(1024);
        pool.ensure_resident(gpu(0), key(0, 0, 128, 0), 600);
        pool.ensure_resident(gpu(0), key(1, 0, 128, 0), 600); // evicts key 0
        assert!(!pool.ensure_resident(gpu(0), key(0, 0, 128, 0), 600));
        assert!(pool.resident_bytes(gpu(0)) <= 1024 + 600);
    }

    #[test]
    fn pinned_intermediates_survive_eviction_until_unpinned() {
        let pool = ResidencyPool::new().with_capacity(1024);
        let stage_key = ResidencyKey {
            arg: ArgKey::Stage {
                request: 1,
                stage: 0,
                out: 0,
            },
            start_unit: 0,
            units: 64,
            version: 0,
        };
        // A produced intermediate counts no upload and pins its entry.
        pool.pin_range(gpu(0), stage_key, 600, 1);
        assert_eq!(pool.stats().uploads, 0, "on-device output never uploads");
        assert_eq!(pool.resident_range_bytes(gpu(0), 0, 64), 600);
        // Pressure that would evict the (older) intermediate under plain
        // LRU must evict the newer unpinned entry instead.
        pool.ensure_resident(gpu(0), key(7, 0, 128, 0), 600);
        assert_eq!(
            pool.resident_range_bytes(gpu(0), 0, 64),
            600,
            "pinned intermediate must survive capacity pressure"
        );
        // Last consumer retired: the entry unpins and becomes evictable.
        pool.unpin(&stage_key);
        pool.ensure_resident(gpu(0), key(8, 0, 128, 0), 600);
        pool.ensure_resident(gpu(0), key(9, 0, 128, 0), 600);
        assert!(pool.resident_bytes(gpu(0)) <= 1024 + 600);
    }

    #[test]
    fn pin_accumulates_and_unpin_is_per_consumer() {
        let pool = ResidencyPool::new().with_capacity(1024);
        let k0 = key(0, 0, 32, 0);
        pool.pin_range(gpu(0), k0, 400, 2);
        pool.unpin(&k0);
        // One of two consumers retired: still pinned, so overflow evicts
        // the older *unpinned* neighbour instead.
        pool.ensure_resident(gpu(0), key(1, 0, 32, 0), 400);
        pool.ensure_resident(gpu(0), key(2, 0, 32, 0), 400);
        assert!(
            pool.resident_range_bytes(gpu(0), 0, 32) >= 400,
            "half-unpinned intermediate must still be resident"
        );
        // Last consumer retired: the next overflow may evict it.
        pool.unpin(&k0);
        pool.ensure_resident(gpu(0), key(3, 0, 32, 0), 400);
        assert!(pool.resident_bytes(gpu(0)) <= 1024 + 400);
    }

    #[test]
    fn range_bytes_are_proportional_to_overlap() {
        let pool = ResidencyPool::new();
        pool.ensure_resident(gpu(0), key(0, 0, 100, 0), 1000);
        assert_eq!(pool.resident_range_bytes(gpu(0), 0, 100), 1000);
        assert_eq!(pool.resident_range_bytes(gpu(0), 50, 50), 500);
        assert_eq!(pool.resident_range_bytes(gpu(0), 100, 50), 0);
        assert_eq!(
            pool.resident_range_bytes(ExecSlot::CpuSub { idx: 0 }, 0, 100),
            0
        );
    }

    #[test]
    fn migration_forfeits_residency_and_books_counters() {
        let pool = ResidencyPool::new();
        pool.ensure_resident(gpu(0), key(0, 0, 100, 0), 1000);
        let moved = pool.note_migration(gpu(0), ExecSlot::CpuSub { idx: 0 }, 0, 100);
        assert_eq!(moved, 1000);
        assert_eq!(pool.resident_range_bytes(gpu(0), 0, 100), 0);
        let s = pool.stats();
        assert_eq!(s.steal_migrations, 1);
        assert_eq!(s.migrated_bytes, 1000);
        // Re-acquiring after the migration re-uploads (at the thief).
        assert!(!pool.ensure_resident(ExecSlot::CpuSub { idx: 0 }, key(0, 0, 100, 0), 1000));
    }

    #[test]
    fn migration_keeps_wider_copy_replicas() {
        // A steal of the task spanning [0, 64) must not wipe the victim's
        // whole-vector COPY replica (keyed over the full range).
        let pool = ResidencyPool::new();
        pool.ensure_resident(gpu(0), key(0, 0, 64, 0), 256);
        pool.ensure_resident(gpu(0), key(1, 0, 1024, 0), 4096);
        let moved = pool.note_migration(gpu(0), ExecSlot::CpuSub { idx: 0 }, 0, 64);
        assert_eq!(moved, 256, "only the contained task range moves");
        assert!(
            pool.ensure_resident(gpu(0), key(1, 0, 1024, 0), 4096),
            "the COPY replica must survive the steal"
        );
    }

    #[test]
    fn invalidate_arg_drops_every_range() {
        let pool = ResidencyPool::new();
        pool.ensure_resident(gpu(0), key(0, 0, 64, 0), 256);
        pool.ensure_resident(gpu(1), key(0, 64, 64, 0), 256);
        pool.ensure_resident(gpu(0), key(1, 0, 64, 0), 256);
        pool.invalidate_arg(ArgKey::Input { request: 1, idx: 0 });
        assert!(!pool.ensure_resident(gpu(0), key(0, 0, 64, 0), 256));
        // Arg 1 untouched.
        assert!(pool.ensure_resident(gpu(0), key(1, 0, 64, 0), 256));
    }

    #[test]
    fn fingerprint_separates_workloads() {
        let a = request_fingerprint("pipeline(a,b)", 1024, &[7]);
        let b = request_fingerprint("pipeline(a,b)", 2048, &[7]);
        let c = request_fingerprint("pipeline(a,b)", 1024, &[8]);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_eq!(a, request_fingerprint("pipeline(a,b)", 1024, &[7]));
    }

    #[test]
    fn migration_estimate_scales_with_bytes() {
        assert!(migration_secs(1 << 30, 8.0) > migration_secs(1 << 20, 8.0));
        assert!((migration_secs(8_000_000_000, 8.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn stats_delta_and_accumulate() {
        let pool = ResidencyPool::new();
        pool.ensure_resident(gpu(0), key(0, 0, 128, 0), 512);
        let before = pool.stats();
        pool.ensure_resident(gpu(0), key(0, 0, 128, 0), 512);
        pool.note_download(64);
        let d = pool.stats().minus(&before);
        assert_eq!(d.uploads, 0);
        assert_eq!(d.uploads_avoided, 1);
        assert_eq!(d.bytes_downloaded, 64);
        let mut acc = TransferStats::default();
        acc.accumulate(&d);
        acc.accumulate(&d);
        assert_eq!(acc.uploads_avoided, 2);
    }
}
