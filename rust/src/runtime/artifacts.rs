//! Artifact manifest: the contract between the Python AOT pipeline and this
//! runtime (`artifacts/manifest.json`, written by `python/compile/aot.py`).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::Json;

/// Input/output tensor declaration.
#[derive(Clone, Debug, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<u64>,
    pub dtype: String,
}

impl IoSpec {
    pub fn elems(&self) -> u64 {
        self.shape.iter().product()
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            (
                "shape",
                Json::arr(self.shape.iter().map(|&d| Json::num(d as f64)).collect()),
            ),
            ("dtype", Json::str(&self.dtype)),
        ])
    }

    fn from_json(v: &Json) -> Result<IoSpec> {
        Ok(IoSpec {
            name: v.get("name")?.as_str().unwrap_or("").to_string(),
            shape: v
                .get("shape")?
                .as_arr()
                .ok_or_else(|| Error::Artifact("shape not array".into()))?
                .iter()
                .filter_map(|d| d.as_u64())
                .collect(),
            dtype: v.get("dtype")?.as_str().unwrap_or("f32").to_string(),
        })
    }
}

/// One AOT-lowered artifact (a fixed chunk shape of one kernel family).
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub family: String,
    pub file: PathBuf,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    /// Units of the partition domain consumed per launch.
    pub chunk_units: u64,
    /// Analytic cost counts for the simulator.
    pub flops: f64,
    pub bytes: f64,
}

/// The parsed manifest, indexed by family.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub by_family: BTreeMap<String, Vec<ArtifactInfo>>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {}/manifest.json ({e}); run `make artifacts`",
                dir.display()
            ))
        })?;
        Manifest::parse(&text, dir)
    }

    fn from_json(v: &Json, dir: &Path) -> Result<Manifest> {
        let format = v.get("format")?.as_u64().unwrap_or(0);
        if format != 1 {
            return Err(Error::Artifact(format!("unsupported format {format}")));
        }
        let mut by_family: BTreeMap<String, Vec<ArtifactInfo>> = BTreeMap::new();
        for a in v
            .get("artifacts")?
            .as_arr()
            .ok_or_else(|| Error::Artifact("artifacts not array".into()))?
        {
            let info = ArtifactInfo {
                name: a.get("name")?.as_str().unwrap_or("").to_string(),
                family: a.get("family")?.as_str().unwrap_or("").to_string(),
                file: dir.join(a.get("file")?.as_str().unwrap_or("")),
                inputs: a
                    .get("inputs")?
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(IoSpec::from_json)
                    .collect::<Result<Vec<_>>>()?,
                outputs: a
                    .get("outputs")?
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(IoSpec::from_json)
                    .collect::<Result<Vec<_>>>()?,
                chunk_units: a.get("chunk_units")?.as_u64().unwrap_or(1),
                flops: a.get("flops")?.as_f64().unwrap_or(0.0),
                bytes: a.get("bytes")?.as_f64().unwrap_or(0.0),
            };
            by_family.entry(info.family.clone()).or_default().push(info);
        }
        // Sort each family's menu by chunk size ascending.
        for v in by_family.values_mut() {
            v.sort_by_key(|a| a.chunk_units);
        }
        Ok(Manifest {
            by_family,
            dir: dir.to_path_buf(),
        })
    }

    /// Default repo location: `$MARROW_ARTIFACTS` or `./artifacts`.
    pub fn load_default() -> Result<Manifest> {
        let dir = std::env::var("MARROW_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"));
        Manifest::load(&dir)
    }

    /// Canonical JSON fingerprint of the artifact set (DESIGN.md §2.9):
    /// what the real scheduler folds into its KB-store manifest digest,
    /// so profiles measured against different kernel builds never
    /// exchange as exact warm-start hits. Families iterate sorted and
    /// artifacts chunk-ascending, making the bytes deterministic; the
    /// on-disk `dir` is deliberately excluded (the same build in a
    /// different checkout is the same platform).
    pub fn fingerprint_json(&self) -> Json {
        let families: Vec<Json> = self
            .by_family
            .iter()
            .map(|(family, arts)| {
                Json::obj(vec![
                    ("family", Json::str(family.as_str())),
                    (
                        "artifacts",
                        Json::arr(
                            arts.iter()
                                .map(|a| {
                                    Json::obj(vec![
                                        ("name", Json::str(a.name.as_str())),
                                        (
                                            "chunk_units",
                                            Json::num(a.chunk_units as f64),
                                        ),
                                        ("flops", Json::num(a.flops)),
                                        ("bytes", Json::num(a.bytes)),
                                        (
                                            "inputs",
                                            Json::num(a.inputs.len() as f64),
                                        ),
                                        (
                                            "outputs",
                                            Json::num(a.outputs.len() as f64),
                                        ),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect();
        Json::obj(vec![("families", Json::arr(families))])
    }

    /// Artifacts of a family, chunk-size ascending.
    pub fn family(&self, family: &str) -> Result<&[ArtifactInfo]> {
        self.by_family
            .get(family)
            .map(|v| v.as_slice())
            .ok_or_else(|| Error::Artifact(format!("no artifacts for family '{family}'")))
    }

    /// Smallest chunk of a family — the decomposition quantum contribution.
    pub fn chunk_quantum(&self, family: &str) -> Result<u64> {
        Ok(self.family(family)?[0].chunk_units)
    }

    /// The largest artifact of `family` whose chunk divides `units`, falling
    /// back to the smallest chunk (the executor loops it).
    pub fn best_chunk(&self, family: &str, units: u64) -> Result<&ArtifactInfo> {
        let menu = self.family(family)?;
        Ok(menu
            .iter()
            .rev()
            .find(|a| units >= a.chunk_units && units % a.chunk_units == 0)
            .unwrap_or(&menu[0]))
    }

    /// Serialize back to the manifest interchange format. Artifact files are
    /// emitted relative to the manifest directory, so
    /// parse -> `to_json` -> parse is the identity and the serialized form
    /// is stable under round-trips (the contract the Python AOT pipeline
    /// and golden tests rely on).
    pub fn to_json(&self) -> Json {
        let mut arts: Vec<Json> = Vec::new();
        for infos in self.by_family.values() {
            for a in infos {
                let file = a
                    .file
                    .strip_prefix(&self.dir)
                    .unwrap_or(&a.file)
                    .to_string_lossy()
                    .to_string();
                arts.push(Json::obj(vec![
                    ("name", Json::str(&a.name)),
                    ("family", Json::str(&a.family)),
                    ("file", Json::str(file)),
                    (
                        "inputs",
                        Json::arr(a.inputs.iter().map(IoSpec::to_json).collect()),
                    ),
                    (
                        "outputs",
                        Json::arr(a.outputs.iter().map(IoSpec::to_json).collect()),
                    ),
                    ("chunk_units", Json::num(a.chunk_units as f64)),
                    ("flops", Json::num(a.flops)),
                    ("bytes", Json::num(a.bytes)),
                ]));
            }
        }
        Json::obj(vec![
            ("format", Json::num(1.0)),
            ("artifacts", Json::arr(arts)),
        ])
    }

    /// Parse a manifest from already-loaded text (no filesystem access);
    /// artifact paths resolve against `dir`.
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let v = Json::parse(text)?;
        Manifest::from_json(&v, dir)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_from(text: &str, dir: &Path) -> Manifest {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        Manifest::load(dir).unwrap()
    }

    fn sample() -> String {
        r#"{"format": 1, "artifacts": [
            {"name": "saxpy_n4096", "family": "saxpy", "file": "a.hlo.txt",
             "chunk_units": 4096, "flops": 8192, "bytes": 49152,
             "inputs": [{"name": "alpha", "shape": [1], "dtype": "f32"}],
             "outputs": [{"name": "out", "shape": [4096], "dtype": "f32"}]},
            {"name": "saxpy_n32768", "family": "saxpy", "file": "b.hlo.txt",
             "chunk_units": 32768, "flops": 65536, "bytes": 393216,
             "inputs": [], "outputs": []}
        ]}"#
        .to_string()
    }

    #[test]
    fn loads_and_indexes_by_family() {
        let dir = std::env::temp_dir().join("marrow_test_manifest_1");
        let m = manifest_from(&sample(), &dir);
        assert_eq!(m.family("saxpy").unwrap().len(), 2);
        assert_eq!(m.chunk_quantum("saxpy").unwrap(), 4096);
        assert!(m.family("nope").is_err());
    }

    #[test]
    fn best_chunk_prefers_largest_dividing() {
        let dir = std::env::temp_dir().join("marrow_test_manifest_2");
        let m = manifest_from(&sample(), &dir);
        assert_eq!(m.best_chunk("saxpy", 65536).unwrap().chunk_units, 32768);
        assert_eq!(m.best_chunk("saxpy", 8192).unwrap().chunk_units, 4096);
        // Nothing divides 1000 -> fall back to smallest.
        assert_eq!(m.best_chunk("saxpy", 1000).unwrap().chunk_units, 4096);
    }

    #[test]
    fn real_manifest_loads_when_built() {
        // Integration-lite: if `make artifacts` has run, the real manifest
        // must parse and contain all five benchmark families.
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        for fam in [
            "saxpy",
            "filter_pipeline",
            "fft_roundtrip",
            "nbody_accel",
            "segmentation",
        ] {
            assert!(m.family(fam).is_ok(), "missing family {fam}");
        }
    }
}
