//! Offline stand-in for the `xla` PJRT binding (compiled when the `pjrt`
//! feature is off, which is the default in dependency-free environments).
//!
//! The stub keeps the whole real-mode code path *type-checking* without the
//! native XLA runtime: [`Literal`] is a fully functional host buffer (so the
//! literal helpers and their tests behave identically), while the client /
//! compilation entry points report themselves unavailable at runtime. The
//! [`crate::session::Session`] facade catches that error and falls back to
//! the simulated backend, so every example stays runnable.

use crate::error::{Error, Result};

fn unavailable<T>() -> Result<T> {
    Err(Error::Runtime(
        "PJRT runtime not compiled in (build with `--features pjrt` and an \
         `xla` dependency to run real numerics)"
            .into(),
    ))
}

/// Element types a stub literal can hold.
pub trait Element: Copy {
    fn make(data: Vec<Self>, dims: Vec<i64>) -> Literal;
    fn extract(lit: &Literal) -> Result<Vec<Self>>;
}

impl Element for f32 {
    fn make(data: Vec<f32>, dims: Vec<i64>) -> Literal {
        Literal::F32(data, dims)
    }

    fn extract(lit: &Literal) -> Result<Vec<f32>> {
        match lit {
            Literal::F32(v, _) => Ok(v.clone()),
            _ => Err(Error::Runtime("literal is not f32".into())),
        }
    }
}

impl Element for i32 {
    fn make(data: Vec<i32>, dims: Vec<i64>) -> Literal {
        Literal::I32(data, dims)
    }

    fn extract(lit: &Literal) -> Result<Vec<i32>> {
        match lit {
            Literal::I32(v, _) => Ok(v.clone()),
            _ => Err(Error::Runtime("literal is not i32".into())),
        }
    }
}

/// Host-side typed buffer with a logical shape.
#[derive(Clone, Debug)]
pub enum Literal {
    F32(Vec<f32>, Vec<i64>),
    I32(Vec<i32>, Vec<i64>),
}

impl Literal {
    pub fn vec1<T: Element>(data: &[T]) -> Literal {
        T::make(data.to_vec(), vec![data.len() as i64])
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = match self {
            Literal::F32(v, _) => v.len() as i64,
            Literal::I32(v, _) => v.len() as i64,
        };
        if want != have {
            return Err(Error::Runtime(format!(
                "cannot reshape {have} elements to {dims:?}"
            )));
        }
        Ok(match self {
            Literal::F32(v, _) => Literal::F32(v.clone(), dims.to_vec()),
            Literal::I32(v, _) => Literal::I32(v.clone(), dims.to_vec()),
        })
    }

    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>> {
        T::extract(self)
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable()
    }
}

/// Placeholder for a device buffer returned by an execution.
pub struct Buffer;

impl Buffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Placeholder for a parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// Placeholder for an XLA computation.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Placeholder for a compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[Literal]) -> Result<Vec<Vec<Buffer>>> {
        unavailable()
    }
}

/// Placeholder for the PJRT client; construction always fails so the real
/// scheduler is never reachable without the native runtime.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }

    #[test]
    fn literal_is_functional() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.reshape(&[3, 2]).is_err());
    }
}
