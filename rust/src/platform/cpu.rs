//! CPUExecutionPlatform: OpenCL device-fission semantics (Section 2.2, 3.2.2).
//!
//! Fission partitions the (possibly multi-socket) CPU OpenCL device into
//! sub-devices by cache affinity domain: `L1`, `L2`, `L3`, `NUMA` or no
//! fission at all. Each sub-device is an independent parallel execution slot
//! with its own work queue, which is how the paper leverages data locality
//! in CPU-directed executions.
//!
//! `configurations()` is the platform's iterator over candidate fission
//! levels, ordered from L1 to NO_FISSION as required by Algorithm 1's
//! discard-ordering.

use crate::platform::device::CpuSpec;

/// OpenCL affinity-domain fission level.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum FissionLevel {
    L1,
    L2,
    L3,
    Numa,
    NoFission,
}

impl FissionLevel {
    /// All levels in Algorithm 1's search order (L1 first).
    pub const ALL: [FissionLevel; 5] = [
        FissionLevel::L1,
        FissionLevel::L2,
        FissionLevel::L3,
        FissionLevel::Numa,
        FissionLevel::NoFission,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            FissionLevel::L1 => "L1",
            FissionLevel::L2 => "L2",
            FissionLevel::L3 => "L3",
            FissionLevel::Numa => "NUMA",
            FissionLevel::NoFission => "none",
        }
    }

    pub fn parse(s: &str) -> Option<FissionLevel> {
        match s.to_ascii_uppercase().as_str() {
            "L1" => Some(FissionLevel::L1),
            "L2" => Some(FissionLevel::L2),
            "L3" => Some(FissionLevel::L3),
            "NUMA" => Some(FissionLevel::Numa),
            "NONE" | "NO_FISSION" => Some(FissionLevel::NoFission),
            _ => None,
        }
    }
}

/// A fissioned CPU sub-device: `cores` cores sharing `cache_kib` of the
/// affinity level's cache.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SubDevice {
    pub cores: u32,
    pub cache_kib: u64,
    /// Does this sub-device span more than one socket (=> cross-NUMA traffic)?
    pub sockets_spanned: u32,
    /// Streaming-bandwidth efficiency of the affinity domain: threads pinned
    /// to a private L2 domain stream without cross-domain interference (the
    /// locality effect the paper measures); coarser domains contend.
    pub bw_factor: f64,
    /// Compute-scheduling efficiency: coarser domains suffer placement churn
    /// and shared-FPU contention under the OpenCL CPU runtime.
    pub compute_factor: f64,
}

/// The CPU execution platform.
#[derive(Clone, Debug)]
pub struct CpuPlatform {
    pub spec: CpuSpec,
}

impl CpuPlatform {
    pub fn new(spec: CpuSpec) -> CpuPlatform {
        CpuPlatform { spec }
    }

    /// Fission levels this device supports, in Algorithm 1 search order.
    /// Levels that would produce the same partitioning as a finer level are
    /// kept (the paper reports them separately), but levels meaningless for
    /// the topology (NUMA on single-socket) are dropped.
    pub fn configurations(&self) -> Vec<FissionLevel> {
        let mut levels = vec![FissionLevel::L1, FissionLevel::L2, FissionLevel::L3];
        if self.spec.numa_nodes > 1 {
            levels.push(FissionLevel::Numa);
        }
        levels.push(FissionLevel::NoFission);
        levels
    }

    /// Number of sub-devices produced by a fission level.
    pub fn subdevice_count(&self, level: FissionLevel) -> u32 {
        let c = &self.spec;
        match level {
            FissionLevel::L1 => c.total_cores(),
            FissionLevel::L2 => c.total_cores() / c.cores_per_l2.max(1),
            FissionLevel::L3 => c.total_cores() / c.cores_per_l3.max(1),
            FissionLevel::Numa => c.numa_nodes,
            FissionLevel::NoFission => 1,
        }
    }

    /// Shape of each sub-device at a fission level.
    pub fn subdevice(&self, level: FissionLevel) -> SubDevice {
        let c = &self.spec;
        let (cores, cache_kib, bw_factor, compute_factor) = match level {
            // L1 domains are too fine to amortize the runtime's per-domain
            // scheduling, but stream privately.
            FissionLevel::L1 => (1, c.l1_kib, 1.10, 0.96),
            // L2 affinity is the paper's sweet spot for streaming locality.
            FissionLevel::L2 => (c.cores_per_l2, c.l2_kib, 1.20, 1.00),
            FissionLevel::L3 => (c.cores_per_l3, c.l3_kib, 1.08, 0.985),
            FissionLevel::Numa => (
                c.total_cores() / c.numa_nodes.max(1),
                // NUMA domain owns all L3 groups inside it.
                c.l3_kib * (c.total_cores() / c.numa_nodes.max(1) / c.cores_per_l3.max(1)) as u64,
                1.00,
                0.955,
            ),
            FissionLevel::NoFission => (
                c.total_cores(),
                c.l3_kib * (c.total_cores() / c.cores_per_l3.max(1)) as u64,
                1.00,
                0.90,
            ),
        };
        let cores_per_socket = c.cores_per_socket.max(1);
        SubDevice {
            cores,
            cache_kib,
            sockets_spanned: cores.div_ceil(cores_per_socket),
            bw_factor,
            compute_factor,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::device::{i7_hd7950, opteron_6272_quad};

    #[test]
    fn opteron_subdevice_counts_match_paper_table2() {
        // Table 2: L2 -> 32 subdevices, L3 -> 8 subdevices.
        let p = CpuPlatform::new(opteron_6272_quad().cpu);
        assert_eq!(p.subdevice_count(FissionLevel::L1), 64);
        assert_eq!(p.subdevice_count(FissionLevel::L2), 32);
        assert_eq!(p.subdevice_count(FissionLevel::L3), 8);
        assert_eq!(p.subdevice_count(FissionLevel::Numa), 4);
        assert_eq!(p.subdevice_count(FissionLevel::NoFission), 1);
    }

    #[test]
    fn i7_subdevice_counts_match_paper_table3() {
        // Table 3 parallelism: L1/L2 -> 6 subdevices, L3 -> 1.
        let p = CpuPlatform::new(i7_hd7950(1).cpu);
        assert_eq!(p.subdevice_count(FissionLevel::L1), 6);
        assert_eq!(p.subdevice_count(FissionLevel::L2), 6);
        assert_eq!(p.subdevice_count(FissionLevel::L3), 1);
    }

    #[test]
    fn i7_has_no_numa_level() {
        let p = CpuPlatform::new(i7_hd7950(1).cpu);
        assert!(!p.configurations().contains(&FissionLevel::Numa));
        assert_eq!(
            p.configurations().last().copied(),
            Some(FissionLevel::NoFission)
        );
    }

    #[test]
    fn configurations_ordered_l1_first() {
        let p = CpuPlatform::new(opteron_6272_quad().cpu);
        assert_eq!(p.configurations()[0], FissionLevel::L1);
    }

    #[test]
    fn no_fission_spans_all_sockets() {
        let p = CpuPlatform::new(opteron_6272_quad().cpu);
        assert_eq!(p.subdevice(FissionLevel::NoFission).sockets_spanned, 4);
        assert_eq!(p.subdevice(FissionLevel::L2).sockets_spanned, 1);
    }

    #[test]
    fn numa_subdevice_owns_socket_cache() {
        let p = CpuPlatform::new(opteron_6272_quad().cpu);
        let sd = p.subdevice(FissionLevel::Numa);
        assert_eq!(sd.cores, 16);
        assert_eq!(sd.cache_kib, 6144 * 2); // two 8-core L3 groups
    }

    #[test]
    fn fission_label_roundtrip() {
        for l in FissionLevel::ALL {
            assert_eq!(FissionLevel::parse(l.label()), Some(l));
        }
    }
}
