//! GPUExecutionPlatform: overlapped (multi-buffered) executions
//! (Section 2.2, 3.2.2).
//!
//! The GPU platform supports the overlap of computation with communication:
//! an overlap factor `o` means each GPU runs `o` concurrent SCT executions
//! over distinct partitions, so the transfer of partition *k+1* hides behind
//! the compute of partition *k*. `configurations()` exposes the two ordered
//! candidate sets of Algorithm 1: overlap factors (natural order) and
//! work-group sizes (non-increasing occupancy).

use crate::platform::device::GpuSpec;
use crate::platform::occupancy::{self, KernelFootprint};

/// Maximum overlap factor explored by the profiler. The paper's search space
/// is [1, inf); in practice occupancy of the candidate list is cut off by
/// Algorithm 1's discard rule well before this bound.
pub const MAX_OVERLAP: u32 = 8;

/// The GPU execution platform for one device.
#[derive(Clone, Debug)]
pub struct GpuPlatform {
    pub spec: GpuSpec,
}

impl GpuPlatform {
    pub fn new(spec: GpuSpec) -> GpuPlatform {
        GpuPlatform { spec }
    }

    /// Ordered overlap-factor candidates (natural order, Section 3.2.2).
    pub fn overlap_candidates(&self) -> Vec<u32> {
        (1..=MAX_OVERLAP).collect()
    }

    /// Ordered work-group-size candidates for a kernel footprint, filtered
    /// by the occupancy threshold (default 0.8).
    pub fn wgs_candidates(&self, fp: &KernelFootprint, threshold: f64) -> Vec<u32> {
        occupancy::wgs_candidates(&self.spec, fp, threshold)
    }

    /// Occupancy for a particular work-group size.
    pub fn occupancy(&self, fp: &KernelFootprint, wgs: u32) -> f64 {
        occupancy::occupancy(&self.spec, fp, wgs)
    }

    /// Fraction of host<->device transfer time exposed (not hidden behind
    /// compute) at overlap factor `o`: the first buffer's transfer is always
    /// exposed; the remaining (o-1)/o of the stream overlaps compute.
    pub fn exposed_transfer_fraction(&self, overlap: u32) -> f64 {
        1.0 / overlap.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::device::i7_hd7950;

    fn plat() -> GpuPlatform {
        GpuPlatform::new(i7_hd7950(1).gpus[0].clone())
    }

    #[test]
    fn overlap_candidates_natural_order() {
        let c = plat().overlap_candidates();
        assert_eq!(c[0], 1);
        assert!(c.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn exposed_transfer_shrinks_with_overlap() {
        let p = plat();
        assert!((p.exposed_transfer_fraction(1) - 1.0).abs() < 1e-12);
        assert!((p.exposed_transfer_fraction(4) - 0.25).abs() < 1e-12);
        assert!(
            p.exposed_transfer_fraction(2) > p.exposed_transfer_fraction(4)
        );
    }

    #[test]
    fn wgs_candidates_non_empty() {
        let fp = KernelFootprint {
            local_mem_base: 0,
            local_mem_per_thread: 0,
            regs_per_thread: 24,
        };
        assert!(!plat().wgs_candidates(&fp, 0.8).is_empty());
    }
}
