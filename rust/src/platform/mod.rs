//! Execution platforms (Section 2.2): device descriptions, the CPU platform
//! with OpenCL-device-fission semantics, the GPU platform with overlapped
//! (multi-buffered) executions, and the occupancy calculator.

pub mod cpu;
pub mod device;
pub mod gpu;
pub mod occupancy;

pub use cpu::{CpuPlatform, FissionLevel};
pub use device::{CpuSpec, DeviceKind, GpuSpec, Machine};
pub use gpu::GpuPlatform;
