//! Device and machine descriptions.
//!
//! These describe the paper's two experimental testbeds; the simulator
//! ([`crate::sim`]) prices task executions against them. Numbers are from
//! the paper's Section 4 plus vendor datasheets for the parts the paper
//! leaves implicit (GFLOPS, bandwidths).

use crate::util::json::Json;

/// Kind of processing unit.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DeviceKind {
    Cpu,
    Gpu,
}

/// A (possibly multi-socket) CPU OpenCL device.
#[derive(Clone, Debug)]
pub struct CpuSpec {
    pub name: String,
    pub sockets: u32,
    pub cores_per_socket: u32,
    /// L1 data cache per core (KiB).
    pub l1_kib: u64,
    /// Unified L2 per group (KiB) and group size in cores.
    pub l2_kib: u64,
    pub cores_per_l2: u32,
    /// Unified L3 per group (KiB) and group size in cores.
    pub l3_kib: u64,
    pub cores_per_l3: u32,
    /// NUMA nodes (affinity-domain fission targets).
    pub numa_nodes: u32,
    /// Peak single-precision GFLOPS per core (vector units included).
    pub gflops_per_core: f64,
    /// Aggregate memory bandwidth (GB/s) across all sockets.
    pub mem_bw_gbps: f64,
    /// Per-kernel-launch host overhead (µs).
    pub launch_overhead_us: f64,
}

impl CpuSpec {
    pub fn total_cores(&self) -> u32 {
        self.sockets * self.cores_per_socket
    }
}

/// A discrete GPU attached via PCIe.
#[derive(Clone, Debug)]
pub struct GpuSpec {
    pub name: String,
    pub compute_units: u32,
    /// Threads per wavefront (AMD) / warp (NVIDIA).
    pub wavefront: u32,
    /// Max work-group size.
    pub max_wg: u32,
    /// Max resident wavefronts per compute unit.
    pub max_waves_per_cu: u32,
    /// Max resident work-groups per compute unit.
    pub max_wgs_per_cu: u32,
    /// Local memory per compute unit (KiB).
    pub local_mem_kib: u64,
    /// Scalar registers per compute unit (in units of 256 regs).
    pub vgpr_banks_per_cu: u32,
    /// Peak single-precision GFLOPS.
    pub gflops: f64,
    /// Device memory bandwidth (GB/s).
    pub mem_bw_gbps: f64,
    /// Effective host<->device PCIe bandwidth (GB/s).
    pub pcie_gbps: f64,
    /// Per-kernel-launch overhead (µs).
    pub launch_overhead_us: f64,
    /// Relative performance weight from the install-time SHOC-style run
    /// (Section 3.2): used for the static multi-GPU distribution.
    pub relative_perf: f64,
}

/// A machine = one CPU OpenCL device + zero or more GPUs.
#[derive(Clone, Debug)]
pub struct Machine {
    pub name: String,
    pub cpu: CpuSpec,
    pub gpus: Vec<GpuSpec>,
}

impl Machine {
    /// Static GPU workload weights, normalized (Section 3.2: relative
    /// performance order from the SHOC suite at installation time).
    pub fn gpu_weights(&self) -> Vec<f64> {
        let total: f64 = self.gpus.iter().map(|g| g.relative_perf).sum();
        self.gpus
            .iter()
            .map(|g| g.relative_perf / total.max(1e-12))
            .collect()
    }

    /// Canonical JSON description of the execution platform — the input
    /// of the KB store's machine manifest digest (DESIGN.md §2.9).
    /// Covers every field the cost models and tuner read, so two
    /// machines with equal manifests are interchangeable for learned
    /// profiles; keys serialize sorted, making the bytes deterministic.
    pub fn manifest_json(&self) -> Json {
        let cpu = &self.cpu;
        Json::obj(vec![
            ("name", Json::str(self.name.as_str())),
            (
                "cpu",
                Json::obj(vec![
                    ("name", Json::str(cpu.name.as_str())),
                    ("sockets", Json::num(cpu.sockets as f64)),
                    ("cores_per_socket", Json::num(cpu.cores_per_socket as f64)),
                    ("l1_kib", Json::num(cpu.l1_kib as f64)),
                    ("l2_kib", Json::num(cpu.l2_kib as f64)),
                    ("cores_per_l2", Json::num(cpu.cores_per_l2 as f64)),
                    ("l3_kib", Json::num(cpu.l3_kib as f64)),
                    ("cores_per_l3", Json::num(cpu.cores_per_l3 as f64)),
                    ("numa_nodes", Json::num(cpu.numa_nodes as f64)),
                    ("gflops_per_core", Json::num(cpu.gflops_per_core)),
                    ("mem_bw_gbps", Json::num(cpu.mem_bw_gbps)),
                    ("launch_overhead_us", Json::num(cpu.launch_overhead_us)),
                ]),
            ),
            (
                "gpus",
                Json::arr(
                    self.gpus
                        .iter()
                        .map(|g| {
                            Json::obj(vec![
                                ("name", Json::str(g.name.as_str())),
                                ("compute_units", Json::num(g.compute_units as f64)),
                                ("wavefront", Json::num(g.wavefront as f64)),
                                ("max_wg", Json::num(g.max_wg as f64)),
                                (
                                    "max_waves_per_cu",
                                    Json::num(g.max_waves_per_cu as f64),
                                ),
                                ("max_wgs_per_cu", Json::num(g.max_wgs_per_cu as f64)),
                                ("local_mem_kib", Json::num(g.local_mem_kib as f64)),
                                (
                                    "vgpr_banks_per_cu",
                                    Json::num(g.vgpr_banks_per_cu as f64),
                                ),
                                ("gflops", Json::num(g.gflops)),
                                ("mem_bw_gbps", Json::num(g.mem_bw_gbps)),
                                ("pcie_gbps", Json::num(g.pcie_gbps)),
                                (
                                    "launch_overhead_us",
                                    Json::num(g.launch_overhead_us),
                                ),
                                ("relative_perf", Json::num(g.relative_perf)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Testbed 1 (Section 4.1): four sixteen-core AMD Opteron 6272 @ 2.2 GHz,
/// 64 GiB RAM. Caches: 16 KiB L1/core, 2 MiB L2 per 2 cores, 6 MiB L3 per
/// 8 cores; 4 NUMA nodes (one per socket).
pub fn opteron_6272_quad() -> Machine {
    Machine {
        name: "4x Opteron 6272 (64 cores)".to_string(),
        cpu: CpuSpec {
            name: "AMD Opteron 6272".to_string(),
            sockets: 4,
            cores_per_socket: 16,
            l1_kib: 16,
            l2_kib: 2048,
            cores_per_l2: 2,
            l3_kib: 6144,
            cores_per_l3: 8,
            numa_nodes: 4,
            // 2.2 GHz, shared FPU per module, AVX: ~8 effective f32 FLOP/cycle.
            gflops_per_core: 17.6,
            mem_bw_gbps: 102.4, // 4 sockets x 25.6 GB/s DDR3-1600
            launch_overhead_us: 18.0,
        },
        gpus: Vec::new(),
    }
}

/// Testbed 2 (Section 4.2): hyper-threaded six-core i7-3930K @ 3.2 GHz
/// (L1/L2 per core, one shared L3) + `n_gpus` AMD HD 7950 on dedicated
/// PCIe x16, 32 GiB RAM.
pub fn i7_hd7950(n_gpus: usize) -> Machine {
    let gpu = GpuSpec {
        name: "AMD HD 7950".to_string(),
        compute_units: 28,
        wavefront: 64,
        max_wg: 256,
        max_waves_per_cu: 40,
        max_wgs_per_cu: 10,
        local_mem_kib: 64,
        vgpr_banks_per_cu: 1024, // 256 KiB VGPR file / CU = 1024 banks of 64x4B
        gflops: 2867.0,
        mem_bw_gbps: 240.0,
        pcie_gbps: 7.0, // effective PCIe 3.0 x16 after protocol overhead
        launch_overhead_us: 9.0,
        relative_perf: 1.0,
    };
    Machine {
        name: format!("i7-3930K + {n_gpus}x HD 7950"),
        cpu: CpuSpec {
            name: "Intel i7-3930K".to_string(),
            sockets: 1,
            cores_per_socket: 6,
            l1_kib: 32,
            l2_kib: 256,
            cores_per_l2: 1,
            l3_kib: 12288,
            cores_per_l3: 6,
            numa_nodes: 1,
            // 3.2 GHz, AVX 8-wide FMA-less SNB-E: ~16 f32 FLOP/cycle.
            gflops_per_core: 51.2,
            mem_bw_gbps: 51.2, // quad-channel DDR3-1600
            launch_overhead_us: 12.0,
        },
        gpus: (0..n_gpus).map(|_| gpu.clone()).collect(),
    }
}

/// The machine the process is actually running on, as far as the
/// standard library can see: core count from the scheduler-visible
/// parallelism, flat cache geometry (one core per L2 group, so L2-level
/// fission yields one execution slot per core), no GPUs. This is the
/// native backend's default machine — slots then map 1:1 onto pinnable
/// cores and BENCH numbers describe the host, not a paper testbed.
/// Cache sizes and per-core throughput are conservative defaults; they
/// feed the simulator's cost model, never native execution itself.
pub fn host_cpu() -> Machine {
    let cores = std::thread::available_parallelism()
        .map(|n| n.get() as u32)
        .unwrap_or(4);
    Machine {
        name: format!("host-cpu ({cores} cores)"),
        cpu: CpuSpec {
            name: "host".to_string(),
            sockets: 1,
            cores_per_socket: cores,
            l1_kib: 32,
            l2_kib: 512,
            cores_per_l2: 1,
            l3_kib: 16384,
            cores_per_l3: cores,
            numa_nodes: 1,
            gflops_per_core: 32.0,
            mem_bw_gbps: 40.0,
            launch_overhead_us: 5.0,
        },
        gpus: Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_cpu_is_cpu_only_with_one_slot_per_core() {
        let m = host_cpu();
        assert!(m.gpus.is_empty());
        assert!(m.cpu.total_cores() >= 1);
        assert_eq!(m.cpu.cores_per_l2, 1);
    }

    #[test]
    fn opteron_core_count() {
        let m = opteron_6272_quad();
        assert_eq!(m.cpu.total_cores(), 64);
        assert!(m.gpus.is_empty());
    }

    #[test]
    fn i7_machine_shape() {
        let m = i7_hd7950(2);
        assert_eq!(m.cpu.total_cores(), 6);
        assert_eq!(m.gpus.len(), 2);
    }

    #[test]
    fn gpu_weights_normalized() {
        let m = i7_hd7950(2);
        let w = m.gpu_weights();
        assert_eq!(w.len(), 2);
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((w[0] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn manifest_json_distinguishes_machines() {
        let a = i7_hd7950(1).manifest_json().to_string();
        let b = i7_hd7950(2).manifest_json().to_string();
        let c = opteron_6272_quad().manifest_json().to_string();
        assert_ne!(a, b);
        assert_ne!(a, c);
        // Deterministic for equal machines.
        assert_eq!(a, i7_hd7950(1).manifest_json().to_string());
    }

    #[test]
    fn heterogeneous_gpu_weights() {
        let mut m = i7_hd7950(2);
        m.gpus[1].relative_perf = 3.0;
        let w = m.gpu_weights();
        assert!((w[0] - 0.25).abs() < 1e-12);
        assert!((w[1] - 0.75).abs() < 1e-12);
    }
}
