//! GPU kernel-occupancy calculator (Section 3.1, ref. [19] of the paper).
//!
//! Occupancy is computed from the usual constraining factors: number of
//! work-groups per compute unit, local memory per work-group, and registers
//! (private memory) per thread. The GPU platform uses it to order candidate
//! work-group sizes by non-increasing occupancy and to filter candidates
//! below the configurable threshold (default 80%).

use crate::platform::device::GpuSpec;

/// Per-kernel resource requirements (from the kernel interface spec).
#[derive(Clone, Copy, Debug)]
pub struct KernelFootprint {
    /// Local (work-group shared) memory bytes per work-group, as a function
    /// of work-group size: `base + per_thread * wgs`.
    pub local_mem_base: u64,
    pub local_mem_per_thread: u64,
    /// Vector registers per thread.
    pub regs_per_thread: u32,
}

impl KernelFootprint {
    pub fn local_mem_bytes(&self, wgs: u32) -> u64 {
        self.local_mem_base + self.local_mem_per_thread * wgs as u64
    }
}

/// Fraction of the device's maximum resident wavefronts achieved by
/// work-group size `wgs` for a kernel with footprint `fp` (0, 1].
pub fn occupancy(gpu: &GpuSpec, fp: &KernelFootprint, wgs: u32) -> f64 {
    if wgs == 0 || wgs > gpu.max_wg {
        return 0.0;
    }
    let waves_per_wg = wgs.div_ceil(gpu.wavefront).max(1);

    // Limit 1: resident work-groups per CU.
    let wg_limit = gpu.max_wgs_per_cu;

    // Limit 2: local memory.
    let lm = fp.local_mem_bytes(wgs).max(1);
    let lm_limit = (gpu.local_mem_kib * 1024 / lm) as u32;

    // Limit 3: registers. VGPR file is vgpr_banks_per_cu banks of
    // wavefront x 4 B; a wave needs regs_per_thread banks.
    let waves_by_regs = if fp.regs_per_thread == 0 {
        gpu.max_waves_per_cu
    } else {
        gpu.vgpr_banks_per_cu / fp.regs_per_thread
    };
    let reg_limit = waves_by_regs / waves_per_wg;

    let wgs_per_cu = wg_limit.min(lm_limit).min(reg_limit);
    let waves = (wgs_per_cu * waves_per_wg).min(gpu.max_waves_per_cu);
    waves as f64 / gpu.max_waves_per_cu as f64
}

/// A light default footprint for kernel-free trees.
pub const DEFAULT_FOOTPRINT: KernelFootprint = KernelFootprint {
    local_mem_base: 0,
    local_mem_per_thread: 0,
    regs_per_thread: 24,
};

/// Occupancy of a multi-kernel SCT at work-group size `wgs`: the minimum
/// over the kernels' occupancies — the max-footprint kernel constrains the
/// whole tree (one wgs dimension per SCT in Algorithm 1). Which kernel is
/// the constraining one may change with `wgs`, so the minimum is evaluated
/// per size rather than fixing one footprint upfront.
pub fn sct_occupancy(gpu: &GpuSpec, fps: &[KernelFootprint], wgs: u32) -> f64 {
    let worst = fps
        .iter()
        .map(|fp| occupancy(gpu, fp, wgs))
        .fold(f64::INFINITY, f64::min);
    if worst.is_finite() {
        worst
    } else {
        occupancy(gpu, &DEFAULT_FOOTPRINT, wgs)
    }
}

/// Candidate work-group sizes (powers of two times the wavefront, bounded by
/// the device max), ordered by non-increasing occupancy as Algorithm 1
/// requires; ties keep larger sizes first (fewer launches).
pub fn wgs_candidates(gpu: &GpuSpec, fp: &KernelFootprint, threshold: f64) -> Vec<u32> {
    wgs_candidates_multi(gpu, std::slice::from_ref(fp), threshold)
}

/// [`wgs_candidates`] for a multi-kernel SCT: each candidate size is scored
/// by [`sct_occupancy`], so ordering and threshold filtering follow the
/// kernel that actually constrains residency at that size.
pub fn wgs_candidates_multi(
    gpu: &GpuSpec,
    fps: &[KernelFootprint],
    threshold: f64,
) -> Vec<u32> {
    let mut cands: Vec<u32> = {
        let mut v = Vec::new();
        let mut s = gpu.wavefront;
        while s <= gpu.max_wg {
            v.push(s);
            s *= 2;
        }
        v
    };
    cands.sort_by(|&a, &b| {
        let oa = sct_occupancy(gpu, fps, a);
        let ob = sct_occupancy(gpu, fps, b);
        ob.partial_cmp(&oa).unwrap().then(b.cmp(&a))
    });
    let above: Vec<u32> = cands
        .iter()
        .copied()
        .filter(|&w| sct_occupancy(gpu, fps, w) >= threshold)
        .collect();
    if above.is_empty() {
        // Paper footnote 2: fall back to the best-occupancy size.
        cands.into_iter().take(1).collect()
    } else {
        above
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::device::i7_hd7950;

    fn light() -> KernelFootprint {
        KernelFootprint {
            local_mem_base: 0,
            local_mem_per_thread: 0,
            regs_per_thread: 16,
        }
    }

    #[test]
    fn light_kernel_reaches_full_occupancy() {
        let gpu = &i7_hd7950(1).gpus[0];
        // 256-thread WGs: 4 waves/wg, 10 wgs allowed -> 40 waves = max.
        assert!((occupancy(gpu, &light(), 256) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn small_wg_limited_by_wg_slots() {
        let gpu = &i7_hd7950(1).gpus[0];
        // 64-thread WGs: 1 wave/wg, max 10 wgs -> 10 waves / 40 = 0.25.
        assert!((occupancy(gpu, &light(), 64) - 0.25).abs() < 1e-9);
    }

    #[test]
    fn local_memory_constrains() {
        let gpu = &i7_hd7950(1).gpus[0];
        let heavy = KernelFootprint {
            local_mem_base: 32 * 1024, // 32 KiB/WG -> 2 WGs per 64 KiB CU
            local_mem_per_thread: 0,
            regs_per_thread: 16,
        };
        // 256-thread WGs: 2 wgs x 4 waves = 8 waves -> 0.2.
        assert!((occupancy(gpu, &heavy, 256) - 0.2).abs() < 1e-9);
    }

    #[test]
    fn register_pressure_constrains() {
        let gpu = &i7_hd7950(1).gpus[0];
        let regs = KernelFootprint {
            local_mem_base: 0,
            local_mem_per_thread: 0,
            regs_per_thread: 128, // 1024/128 = 8 waves by regs
        };
        // 256-thread WG = 4 waves -> 2 wgs -> 8 waves -> 0.2.
        assert!((occupancy(gpu, &regs, 256) - 0.2).abs() < 1e-9);
    }

    #[test]
    fn candidates_ordered_by_occupancy() {
        let gpu = &i7_hd7950(1).gpus[0];
        let c = wgs_candidates(gpu, &light(), 0.8);
        assert_eq!(c[0], 256); // only full-occupancy candidate
        assert!(!c.is_empty());
        let occs: Vec<f64> = c.iter().map(|&w| occupancy(gpu, &light(), w)).collect();
        for pair in occs.windows(2) {
            assert!(pair[0] >= pair[1]);
        }
    }

    #[test]
    fn fallback_when_nothing_clears_threshold() {
        let gpu = &i7_hd7950(1).gpus[0];
        let heavy = KernelFootprint {
            local_mem_base: 60 * 1024,
            local_mem_per_thread: 0,
            regs_per_thread: 200,
        };
        let c = wgs_candidates(gpu, &heavy, 0.8);
        assert_eq!(c.len(), 1); // best-occupancy fallback
    }

    #[test]
    fn zero_and_oversize_wgs_rejected() {
        let gpu = &i7_hd7950(1).gpus[0];
        assert_eq!(occupancy(gpu, &light(), 0), 0.0);
        assert_eq!(occupancy(gpu, &light(), 1024), 0.0);
    }
}
