//! Profile specification (Section 3.2.1): everything needed to reproduce a
//! framework configuration for a given (SCT, workload) pair.

use crate::data::workload::Workload;
use crate::error::{Error, Result};
use crate::platform::cpu::{CpuPlatform, FissionLevel};
use crate::util::json::Json;

/// How a stored profile was obtained (profile field (f)).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProfileOrigin {
    /// Built from scratch by the profiling process (box "Build SCT profile").
    Built,
    /// Derived from the knowledge base (box "Derive work distribution").
    Derived,
    /// Refined by the dynamic load balancer after derivation.
    Refined,
}

impl ProfileOrigin {
    pub fn label(&self) -> &'static str {
        match self {
            ProfileOrigin::Built => "built",
            ProfileOrigin::Derived => "derived",
            ProfileOrigin::Refined => "refined",
        }
    }

    pub fn parse(s: &str) -> Option<ProfileOrigin> {
        match s {
            "built" => Some(ProfileOrigin::Built),
            "derived" => Some(ProfileOrigin::Derived),
            "refined" => Some(ProfileOrigin::Refined),
            _ => None,
        }
    }
}

/// The execution-platform configuration of one profile (profile fields (c)
/// and (d)): fission level, per-GPU overlap, work-group size, CPU share.
#[derive(Clone, Debug, PartialEq)]
pub struct FrameworkConfig {
    pub fission: FissionLevel,
    /// Overlap factor per GPU device.
    pub overlap: Vec<u32>,
    /// Work-group size for GPU-directed kernel launches.
    pub wgs: u32,
    /// Fraction of the workload assigned to the CPU device type.
    pub cpu_share: f64,
}

impl FrameworkConfig {
    /// CPU-only default at a fission level.
    pub fn cpu_only(fission: FissionLevel) -> FrameworkConfig {
        FrameworkConfig {
            fission,
            overlap: Vec::new(),
            wgs: 256,
            cpu_share: 1.0,
        }
    }

    /// The SCT's level of (coarse) parallelism (Section 3.2.2): fission
    /// sub-devices + the sum of the GPUs' overlap factors.
    pub fn parallelism(&self, cpu: &CpuPlatform) -> u32 {
        let subs = if self.cpu_share > 0.0 || self.overlap.is_empty() {
            cpu.subdevice_count(self.fission)
        } else {
            cpu.subdevice_count(self.fission)
        };
        subs + self.overlap.iter().sum::<u32>()
    }

    /// GPU share (1 - cpu_share), as the tables report "GPU/CPU".
    pub fn gpu_share(&self) -> f64 {
        1.0 - self.cpu_share
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("fission", Json::str(self.fission.label())),
            (
                "overlap",
                Json::arr(self.overlap.iter().map(|&o| Json::num(o as f64)).collect()),
            ),
            ("wgs", Json::num(self.wgs as f64)),
            ("cpu_share", Json::num(self.cpu_share)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<FrameworkConfig> {
        Ok(FrameworkConfig {
            fission: FissionLevel::parse(v.get("fission")?.as_str().unwrap_or(""))
                .ok_or_else(|| Error::Kb("bad fission level".into()))?,
            overlap: v
                .get("overlap")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|o| o.as_u64().map(|v| v as u32))
                .collect(),
            wgs: v.get("wgs")?.as_u64().unwrap_or(256) as u32,
            cpu_share: v.get("cpu_share")?.as_f64().unwrap_or(0.0),
        })
    }
}

/// A stored profile (Section 3.2.1, fields (a)-(f)).
#[derive(Clone, Debug)]
pub struct Profile {
    /// (a) SCT unique identifier.
    pub sct_id: String,
    /// (b) workload characterization.
    pub workload: Workload,
    /// (c) + (d) distribution & platform configuration.
    pub config: FrameworkConfig,
    /// (e) minimum execution time measured for this configuration (s).
    pub best_time: f64,
    /// (f) generation process.
    pub origin: ProfileOrigin,
}

impl Profile {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("sct_id", Json::str(&self.sct_id)),
            ("workload", self.workload.to_json()),
            ("config", self.config.to_json()),
            ("best_time", Json::num(self.best_time)),
            ("origin", Json::str(self.origin.label())),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Profile> {
        Ok(Profile {
            sct_id: v.get("sct_id")?.as_str().unwrap_or("").to_string(),
            workload: Workload::from_json(v.get("workload")?)?,
            config: FrameworkConfig::from_json(v.get("config")?)?,
            best_time: v.get("best_time")?.as_f64().unwrap_or(f64::INFINITY),
            origin: ProfileOrigin::parse(v.get("origin")?.as_str().unwrap_or(""))
                .ok_or_else(|| Error::Kb("bad origin".into()))?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::device::i7_hd7950;

    #[test]
    fn parallelism_matches_paper_table3() {
        // Filter 2048², 1 GPU: L2 fission + overlap 4 -> 6 + 4 = 10.
        let cpu = CpuPlatform::new(i7_hd7950(1).cpu);
        let cfg = FrameworkConfig {
            fission: FissionLevel::L2,
            overlap: vec![4],
            wgs: 256,
            cpu_share: 0.232,
        };
        assert_eq!(cfg.parallelism(&cpu), 10);
        // FFT 128 MB, 2 GPUs: L3/4 -> 1 + 8 = 9.
        let cfg2 = FrameworkConfig {
            fission: FissionLevel::L3,
            overlap: vec![4, 4],
            wgs: 256,
            cpu_share: 0.249,
        };
        assert_eq!(cfg2.parallelism(&cpu), 9);
    }

    #[test]
    fn config_json_roundtrip() {
        let cfg = FrameworkConfig {
            fission: FissionLevel::Numa,
            overlap: vec![3, 4],
            wgs: 128,
            cpu_share: 0.21,
        };
        let j = cfg.to_json();
        assert_eq!(FrameworkConfig::from_json(&j).unwrap(), cfg);
    }

    #[test]
    fn profile_json_roundtrip() {
        let p = Profile {
            sct_id: "pipeline(a,b)".into(),
            workload: Workload::d2(2048, 2048),
            config: FrameworkConfig::cpu_only(FissionLevel::L2),
            best_time: 0.125,
            origin: ProfileOrigin::Built,
        };
        let j = p.to_json();
        let back = Profile::from_json(&j).unwrap();
        assert_eq!(back.sct_id, p.sct_id);
        assert_eq!(back.workload, p.workload);
        assert_eq!(back.config, p.config);
        assert_eq!(back.origin, ProfileOrigin::Built);
    }
}
