//! Profile-based workload distribution (Section 3.2): profile records, the
//! workload-distribution generator (binary search over the transferable
//! partition), and the profile-building search of Algorithm 1.

pub mod builder;
pub mod profile;
pub mod wldg;

pub use builder::{build_profile, TunerOpts};
pub use profile::{FrameworkConfig, Profile, ProfileOrigin};
pub use wldg::Wldg;
