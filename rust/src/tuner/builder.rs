//! Profile construction — Algorithm 1 (Section 3.2.2).
//!
//! Searches the configuration space (CPU fission level x GPU overlap x
//! work-group size x CPU/GPU distribution) for the best-performing tuple.
//! The dimensions are ordered by likeliness to perform well (fission L1
//! first, overlap in natural order, wgs by non-increasing occupancy) and
//! each is pruned by a discard rule: when a candidate fails to improve on
//! its predecessor, all subsequent candidates of that dimension are
//! discarded.

use crate::error::Result;
use crate::platform::cpu::CpuPlatform;
use crate::platform::gpu::GpuPlatform;
use crate::scheduler::ExecEnv;
use crate::sct::Sct;
use crate::data::workload::Workload;
use crate::tuner::profile::{FrameworkConfig, Profile, ProfileOrigin};
use crate::tuner::wldg::Wldg;

/// Tuning options (Algorithm 1 inputs).
#[derive(Clone, Debug)]
pub struct TunerOpts {
    /// Minimum accepted GPU occupancy for wgs candidates.
    pub occupancy_threshold: f64,
    /// Precision value for the workload-distribution search (seconds).
    pub precision: f64,
    /// Quality factor: executions averaged per candidate distribution.
    pub number_executions: u32,
    /// Cap on WLDG iterations per platform configuration.
    pub max_dist_iters: u32,
}

impl Default for TunerOpts {
    fn default() -> Self {
        TunerOpts {
            occupancy_threshold: 0.8,
            precision: 0.01, // relative

            number_executions: 3,
            max_dist_iters: 12,
        }
    }
}

/// Execute `n` times and average (the algorithm's quality factor smooths
/// performance fluctuations).
fn exec_for_profile<E: ExecEnv>(
    env: &mut E,
    sct: &Sct,
    units: u64,
    cfg: &FrameworkConfig,
    n: u32,
) -> Result<(f64, f64, f64)> {
    let (mut t, mut ct, mut gt) = (0.0, 0.0, 0.0);
    for _ in 0..n.max(1) {
        let o = env.execute(sct, units, cfg)?;
        t += o.total;
        ct += o.cpu_time;
        gt += o.gpu_time;
    }
    let n = n.max(1) as f64;
    Ok((t / n, ct / n, gt / n))
}

/// Find the best workload distribution for a fixed platform configuration
/// via the WLDG binary search (Algorithm 1, steps 9-20).
fn best_distribution<E: ExecEnv>(
    env: &mut E,
    sct: &Sct,
    units: u64,
    base: &FrameworkConfig,
    opts: &TunerOpts,
) -> Result<(f64, f64)> {
    if base.overlap.is_empty() {
        // CPU-only machine: distribution is trivially all-CPU.
        let mut cfg = base.clone();
        cfg.cpu_share = 1.0;
        let (t, _, _) = exec_for_profile(env, sct, units, &cfg, opts.number_executions)?;
        return Ok((1.0, t));
    }
    let mut wldg = Wldg::new();
    let mut best = (wldg.candidate_cpu_share(), f64::INFINITY);
    let mut prev_time = f64::INFINITY;
    let resolution = 1.0 / units.max(1) as f64;
    for _ in 0..opts.max_dist_iters {
        let share = wldg.candidate_cpu_share();
        let mut cfg = base.clone();
        cfg.cpu_share = share;
        let (t, ct, gt) = exec_for_profile(env, sct, units, &cfg, opts.number_executions)?;
        if t < best.1 {
            best = (share, t);
        }
        wldg.feedback(ct, gt);
        // Step 17: stop this search direction when the delta flattens
        // (precision is relative to the measured time so small and large
        // workloads converge alike).
        if (prev_time - t).abs() < opts.precision * t.max(1e-12)
            || wldg.converged(resolution)
        {
            break;
        }
        prev_time = t;
    }
    // Always probe the GPU-only distribution: sub-quantum CPU partitions
    // carry no work, and Table 3 reports NBody as exactly 100/0.
    {
        let mut cfg = base.clone();
        cfg.cpu_share = 0.0;
        let (t, _, _) = exec_for_profile(env, sct, units, &cfg, opts.number_executions)?;
        if t <= best.1 {
            best = (0.0, t);
        }
    }
    Ok(best)
}

/// Algorithm 1: build the best-known profile for (SCT, workload).
pub fn build_profile<E: ExecEnv>(
    env: &mut E,
    sct: &Sct,
    workload: &Workload,
    total_units: u64,
    opts: &TunerOpts,
) -> Result<Profile> {
    let machine = env.machine().clone();
    let cpu_plat = CpuPlatform::new(machine.cpu.clone());
    let fission_levels = cpu_plat.configurations();

    let has_gpu = !machine.gpus.is_empty();
    let (overlaps, wgs_cands) = if has_gpu {
        let gp = GpuPlatform::new(machine.gpus[0].clone());
        // Candidate sizes are scored against the whole SCT (minimum over
        // per-kernel occupancies), not just the first leaf: the kernel that
        // constrains residency can differ per work-group size.
        let fps: Vec<_> = sct.kernels().iter().map(|k| k.footprint).collect();
        (
            gp.overlap_candidates(),
            crate::platform::occupancy::wgs_candidates_multi(
                &machine.gpus[0],
                &fps,
                opts.occupancy_threshold,
            ),
        )
    } else {
        (vec![], vec![256])
    };

    let mut best: Option<Profile> = None;
    let mut prev_fission_best = f64::INFINITY;

    'fission: for &fission in &fission_levels {
        let mut fission_best = f64::INFINITY;
        let overlap_iter: Vec<Option<u32>> = if has_gpu {
            overlaps.iter().map(|&o| Some(o)).collect()
        } else {
            vec![None]
        };
        let mut prev_overlap_best = f64::INFINITY;
        'overlap: for &ov in &overlap_iter {
            let mut overlap_best = f64::INFINITY;
            let mut prev_wgs_best = f64::INFINITY;
            for &wgs in &wgs_cands {
                let base = FrameworkConfig {
                    fission,
                    overlap: match ov {
                        Some(o) => vec![o; machine.gpus.len()],
                        None => vec![],
                    },
                    wgs,
                    cpu_share: 0.5,
                };
                let (share, t) = best_distribution(env, sct, total_units, &base, opts)?;
                if t < overlap_best {
                    overlap_best = t;
                }
                let better_than_stored =
                    best.as_ref().map(|b| t < b.best_time).unwrap_or(true);
                if better_than_stored {
                    let mut cfg = base.clone();
                    cfg.cpu_share = share;
                    best = Some(Profile {
                        sct_id: sct.id(),
                        workload: workload.clone(),
                        config: cfg,
                        best_time: t,
                        origin: ProfileOrigin::Built,
                    });
                }
                // Discard rule on the wgs dimension.
                if t > prev_wgs_best {
                    break;
                }
                prev_wgs_best = t;
            }
            if overlap_best < fission_best {
                fission_best = overlap_best;
            }
            // Discard rule on the overlap dimension.
            if overlap_best > prev_overlap_best {
                break 'overlap;
            }
            prev_overlap_best = overlap_best;
        }
        // Discard rule on the fission dimension.
        if fission_best > prev_fission_best {
            break 'fission;
        }
        prev_fission_best = fission_best;
    }

    best.ok_or_else(|| crate::Error::Tuner("empty configuration space".into()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::device::{i7_hd7950, opteron_6272_quad};
    use crate::scheduler::SimEnv;
    use crate::sct::{KernelSpec, ParamSpec};
    use crate::sim::machine::SimMachine;

    fn saxpy_sct() -> Sct {
        let mut k = KernelSpec::new("saxpy", vec![ParamSpec::VecIn], 1);
        k.flops_per_unit = 2.0;
        k.bytes_per_unit = 12.0;
        Sct::kernel(k)
    }

    fn filter_sct() -> Sct {
        let mut k = KernelSpec::new("filter_pipeline", vec![ParamSpec::VecIn], 2048);
        k.flops_per_unit = 60.0 * 2048.0;
        k.bytes_per_unit = 8.0 * 2048.0;
        k.passes = 3.0;
        k.work_per_thread = 2;
        Sct::kernel(k)
    }

    #[test]
    fn hybrid_profile_distributes_between_devices() {
        let mut env = SimEnv::new(SimMachine::new(i7_hd7950(1), 9));
        let w = Workload::d1(1 << 24);
        let p = build_profile(
            &mut env,
            &saxpy_sct(),
            &w,
            1 << 24,
            &TunerOpts::default(),
        )
        .unwrap();
        assert!(p.best_time.is_finite() && p.best_time > 0.0);
        // Streaming workload: both device types should participate, GPU
        // dominant (Table 3: saxpy ~75/25).
        assert!(p.config.cpu_share > 0.02, "cpu {}", p.config.cpu_share);
        assert!(p.config.cpu_share < 0.6, "cpu {}", p.config.cpu_share);
        assert!(!p.config.overlap.is_empty());
        assert_eq!(p.origin, ProfileOrigin::Built);
    }

    #[test]
    fn cpu_only_machine_profiles_fission() {
        let mut env = SimEnv::new(SimMachine::new(opteron_6272_quad(), 5));
        let w = Workload::d2(2048, 2048);
        let p = build_profile(
            &mut env,
            &filter_sct(),
            &w,
            2048,
            &TunerOpts::default(),
        )
        .unwrap();
        assert_eq!(p.config.cpu_share, 1.0);
        assert!(p.config.overlap.is_empty());
        // Fission should beat NoFission on the 4-socket box.
        assert_ne!(
            p.config.fission,
            crate::platform::cpu::FissionLevel::NoFission
        );
    }

    #[test]
    fn profile_id_matches_sct() {
        let mut env = SimEnv::new(SimMachine::new(i7_hd7950(1), 2));
        let w = Workload::d1(1 << 20);
        let p = build_profile(&mut env, &saxpy_sct(), &w, 1 << 20, &TunerOpts::default())
            .unwrap();
        assert_eq!(p.sct_id, "saxpy");
    }
}
