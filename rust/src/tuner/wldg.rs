//! The workload distribution generator (Section 3.2.2).
//!
//! An iterator that, at each invocation, outputs a CPU/GPU distribution
//! trying to even the completion time of each device type. Binary search:
//! the *transferable partition* starts as the whole workload; each iteration
//! splits it evenly between the device types and permanently binds one half
//! to the better performer; the remainder half is the next transferable
//! partition — `transferableSize(n, size) = size / 2^n`.

/// Binary-search workload distribution generator.
#[derive(Clone, Debug)]
pub struct Wldg {
    /// Fraction permanently bound to the CPU device type.
    bound_cpu: f64,
    /// Fraction permanently bound to the GPU device type.
    bound_gpu: f64,
    /// Fraction still under training.
    transferable: f64,
    iterations: u32,
}

impl Wldg {
    pub fn new() -> Wldg {
        Wldg {
            bound_cpu: 0.0,
            bound_gpu: 0.0,
            transferable: 1.0,
            iterations: 0,
        }
    }

    /// Current candidate distribution: the transferable partition is split
    /// evenly, so the CPU share to *test* is `bound_cpu + transferable/2`.
    pub fn candidate_cpu_share(&self) -> f64 {
        self.bound_cpu + self.transferable / 2.0
    }

    /// Feed back the per-device-type completion times measured at the
    /// candidate distribution; binds half the transferable partition to the
    /// better performer.
    pub fn feedback(&mut self, cpu_time: f64, gpu_time: f64) {
        let half = self.transferable / 2.0;
        if cpu_time <= gpu_time {
            // CPU finished first: it can take more work.
            self.bound_cpu += half;
        } else {
            self.bound_gpu += half;
        }
        self.transferable = half;
        self.iterations += 1;
    }

    /// `transferableSize(n, size) = size / 2^n` — the asymptotically
    /// vanishing training fraction.
    pub fn transferable(&self) -> f64 {
        self.transferable
    }

    pub fn iterations(&self) -> u32 {
        self.iterations
    }

    /// Converged when the transferable fraction can no longer change the
    /// distribution by more than `resolution` (e.g. one quantum / total).
    pub fn converged(&self, resolution: f64) -> bool {
        self.transferable / 2.0 < resolution.max(1e-9)
    }
}

impl Default for Wldg {
    fn default() -> Self {
        Wldg::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall;

    #[test]
    fn starts_even() {
        let w = Wldg::new();
        assert!((w.candidate_cpu_share() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn transferable_halves_each_iteration() {
        let mut w = Wldg::new();
        for n in 1..=10 {
            w.feedback(1.0, 2.0);
            assert!((w.transferable() - 1.0 / (1u64 << n) as f64).abs() < 1e-12);
        }
    }

    #[test]
    fn converges_to_equal_throughput_split() {
        // CPU processes at rate rc, GPU at rg; completion times for share s:
        // cpu = s/rc, gpu = (1-s)/rg. Optimal share = rc/(rc+rg).
        let (rc, rg) = (1.0, 3.0);
        let mut w = Wldg::new();
        for _ in 0..30 {
            let s = w.candidate_cpu_share();
            w.feedback(s / rc, (1.0 - s) / rg);
        }
        let expect = rc / (rc + rg);
        assert!(
            (w.candidate_cpu_share() - expect).abs() < 1e-6,
            "got {} want {expect}",
            w.candidate_cpu_share()
        );
    }

    #[test]
    fn gpu_always_faster_drives_share_to_zero() {
        let mut w = Wldg::new();
        for _ in 0..40 {
            w.feedback(10.0, 1.0); // CPU always slower
        }
        assert!(w.candidate_cpu_share() < 1e-9);
    }

    #[test]
    fn prop_shares_partition_unity() {
        forall(
            0x71d6,
            200,
            |r| {
                (0..12)
                    .map(|_| r.f64())
                    .collect::<Vec<f64>>()
            },
            |flips| {
                let mut w = Wldg::new();
                for &f in flips {
                    if f < 0.5 {
                        w.feedback(1.0, 2.0);
                    } else {
                        w.feedback(2.0, 1.0);
                    }
                    let total = w.bound_cpu + w.bound_gpu + w.transferable;
                    if (total - 1.0).abs() > 1e-9 {
                        return Err(format!("shares sum to {total}"));
                    }
                    let s = w.candidate_cpu_share();
                    if !(0.0..=1.0).contains(&s) {
                        return Err(format!("share {s} out of range"));
                    }
                }
                Ok(())
            },
        );
    }
}
