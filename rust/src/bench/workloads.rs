//! The five paper benchmarks (Section 4) as Marrow SCTs.
//!
//! Cost metadata (flops/bytes per epu unit, passes, COPY sizes) mirrors the
//! analytic counts the AOT manifest records for the real artifacts, so the
//! simulator and the real runtime price the same computation consistently.

use crate::data::workload::{Workload, WorkloadClass};
use crate::platform::occupancy::KernelFootprint;
use crate::sct::{KernelSpec, ParamSpec, Sct};
use crate::data::vector::ScalarTrait;

/// A benchmark instance: the SCT, its workload characterization, the domain
/// size in epu units, and COPY-mode bytes.
#[derive(Clone, Debug)]
pub struct Benchmark {
    pub name: String,
    pub sct: Sct,
    pub workload: Workload,
    pub total_units: u64,
    pub copy_bytes: f64,
}

fn fp(regs: u32, local_base: u64) -> KernelFootprint {
    KernelFootprint {
        local_mem_base: local_base,
        local_mem_per_thread: 0,
        regs_per_thread: regs,
    }
}

/// Saxpy (Map): `alpha*x + y` over `n` single-precision elements; epu = 1
/// element, one element per thread, no partitioning restrictions.
pub fn saxpy(n: u64) -> Benchmark {
    let mut k = KernelSpec::new(
        "saxpy",
        vec![
            ParamSpec::ScalarF32(ScalarTrait::Bound),
            ParamSpec::VecIn,
            ParamSpec::VecIn,
        ],
        1,
    );
    k.flops_per_unit = 2.0;
    k.bytes_per_unit = 12.0;
    k.passes = 1.0;
    k.footprint = fp(16, 0);
    Benchmark {
        name: format!("saxpy {n}"),
        sct: Sct::map(Sct::kernel(k)),
        workload: Workload::d1(n),
        total_units: n,
        copy_bytes: 0.0,
    }
}

/// Filter Pipeline: Gaussian Noise -> Solarize -> Mirror over an `h x w`
/// image; epu = 1 image line, 2 pixels per thread (Section 4).
///
/// `fused = true` builds the locality-aware single-leaf SCT (one fused HLO
/// artifact, intermediates persisted); `fused = false` builds the 3-stage
/// Pipeline of separate kernels (the ablation path: each stage re-traverses
/// memory).
pub fn filter_pipeline(h: u64, w: u64, fused: bool) -> Benchmark {
    let mk = |family: &str, flops_px: f64, passes: f64| {
        let mut k = KernelSpec::new(
            family,
            match family {
                "gaussian_noise" => vec![
                    ParamSpec::VecIn,
                    ParamSpec::ScalarI32(ScalarTrait::Bound), // seed
                    ParamSpec::ScalarI32(ScalarTrait::Offset), // row_off
                ],
                "solarize" => vec![ParamSpec::VecIn, ParamSpec::ScalarF32(ScalarTrait::Bound)],
                "mirror" => vec![ParamSpec::VecIn],
                _ => vec![
                    ParamSpec::VecIn,
                    ParamSpec::ScalarI32(ScalarTrait::Bound), // seed
                    ParamSpec::ScalarI32(ScalarTrait::Offset), // row_off
                    ParamSpec::ScalarF32(ScalarTrait::Bound), // thresh
                ],
            },
            w,
        );
        k.flops_per_unit = flops_px * w as f64;
        k.bytes_per_unit = 8.0 * w as f64;
        k.passes = passes;
        k.work_per_thread = 2;
        k.footprint = fp(32, 0);
        k
    };
    let sct = if fused {
        Sct::kernel(mk("filter_pipeline", 60.0, 3.0))
    } else {
        Sct::pipeline(vec![
            Sct::kernel(mk("gaussian_noise", 44.0, 1.0)),
            Sct::kernel(mk("solarize", 2.0, 1.0)),
            Sct::kernel(mk("mirror", 0.0, 1.0)),
        ])
    };
    Benchmark {
        name: format!("filter_pipeline {h}x{w}"),
        sct,
        workload: Workload::d2(h, w),
        total_units: h,
        copy_bytes: 0.0,
    }
}

/// FFT (Pipeline): fixed-size FFTs pipelined with their inversion, adapted
/// from SHOC; epu = one whole FFT (the paper's 512 KiB units map to our
/// 512-point complex FFTs — DESIGN.md §1.2). `mib` is the data-set size.
pub fn fft(mib: u64) -> Benchmark {
    const FFT_BYTES: u64 = 512 * 8; // 512 complex points, f32 re+im
    let n_ffts = mib * 1024 * 1024 / FFT_BYTES;
    let stages = 9.0; // log2(512)
    let mut k = KernelSpec::new(
        "fft_roundtrip",
        vec![ParamSpec::VecIn, ParamSpec::VecIn],
        1024, // 512 re + 512 im elements per unit
    );
    k.flops_per_unit = 2.0 * 5.0 * 512.0 * stages; // fwd + inv
    k.bytes_per_unit = FFT_BYTES as f64 * 2.0;
    // The butterfly stages run out of local memory (VMEM on the TPU
    // adaptation); only the forward and inverse kernels traverse DRAM.
    k.passes = 2.0;
    k.footprint = fp(64, 4096); // butterfly staging buffer
    Benchmark {
        name: format!("fft {mib}MB"),
        sct: Sct::pipeline(vec![Sct::kernel(k)]),
        workload: Workload::d1(mib * 1024 * 1024),
        total_units: n_ffts,
        copy_bytes: 0.0,
    }
}

/// NBody (Loop): direct-sum over `n` bodies for `iters` iterations; the
/// whole body set is COPY-replicated, distribution is at body level, with a
/// global synchronization point per iteration (Section 4).
pub fn nbody(n: u64, iters: u32) -> Benchmark {
    let mut k = KernelSpec::new(
        "nbody_accel",
        vec![
            ParamSpec::VecCopy,
            ParamSpec::ScalarI32(ScalarTrait::Offset),
        ],
        1,
    );
    k.flops_per_unit = 20.0 * n as f64;
    k.bytes_per_unit = 12.0 + 16.0; // acc out + body row in (amortized)
    k.passes = 1.0;
    k.footprint = fp(40, 16 * 1024); // body tile in local memory
    Benchmark {
        name: format!("nbody {n}"),
        sct: Sct::for_loop(Sct::kernel(k), iters, true),
        workload: Workload::d1(n),
        total_units: n,
        copy_bytes: 16.0 * n as f64,
    }
}

/// Segmentation (Map): 3-D gray-scale thresholding; epu = one XY plane of
/// 256x256 voxels, partitioning along the last dimension only (Section 4).
pub fn segmentation(mib: u64) -> Benchmark {
    const PLANE: u64 = 256 * 256; // voxels per plane
    let planes = (mib * 1024 * 1024 / (PLANE * 4)).max(1);
    let mut k = KernelSpec::new(
        "segmentation",
        vec![ParamSpec::VecIn, ParamSpec::VecCopy],
        PLANE,
    );
    k.flops_per_unit = 2.0 * PLANE as f64;
    k.bytes_per_unit = 8.0 * PLANE as f64;
    k.passes = 1.0;
    k.footprint = fp(12, 0);
    Benchmark {
        name: format!("segmentation {mib}MB"),
        sct: Sct::map(Sct::kernel(k)),
        workload: Workload::d3(256, 256, planes),
        total_units: planes,
        copy_bytes: 0.0,
    }
}

/// CSR SpMV (Map, irregular tier): one epu unit = one matrix row stored
/// ELL-style (16-slot padded, -1 column sentinel) against a COPY-replicated
/// dense vector of 4096 entries. Cost follows the row-length distribution,
/// so the kernel declares a per-chunk cost CV and the workload is tagged
/// `Sparse` for the KB's per-class model.
pub fn spmv(rows: u64) -> Benchmark {
    const K_PAD: u64 = 16;
    const N_COLS: u64 = 4096;
    let mut k = KernelSpec::new(
        "spmv_csr",
        vec![ParamSpec::VecIn, ParamSpec::VecIn, ParamSpec::VecCopy],
        K_PAD, // one row spans K_PAD elems of each partitioned vector
    );
    k.flops_per_unit = 2.0 * K_PAD as f64;
    k.bytes_per_unit = 12.0 * K_PAD as f64;
    k.passes = 1.0;
    k.footprint = fp(24, 0);
    k.chunk_cv = 0.6; // row-length skew
    Benchmark {
        name: format!("spmv {rows}"),
        sct: Sct::map(Sct::kernel(k)),
        workload: Workload::d1(rows).with_class(WorkloadClass::Sparse),
        total_units: rows,
        copy_bytes: 4.0 * N_COLS as f64,
    }
}

/// BFS frontier expansion (Map, irregular tier): one epu unit = one node
/// with an 8-slot padded adjacency row; the frontier flag vector (4096
/// nodes) is COPY-replicated. Cost follows degree/frontier structure —
/// class `Traversal`.
pub fn bfs(nodes: u64) -> Benchmark {
    const DEG_PAD: u64 = 8;
    const N_NODES: u64 = 4096;
    let mut k = KernelSpec::new(
        "bfs_frontier",
        vec![ParamSpec::VecIn, ParamSpec::VecCopy],
        DEG_PAD,
    );
    k.flops_per_unit = DEG_PAD as f64;
    k.bytes_per_unit = 8.0 * DEG_PAD as f64;
    k.passes = 1.0;
    k.footprint = fp(16, 0);
    k.chunk_cv = 0.5; // frontier/degree skew
    Benchmark {
        name: format!("bfs {nodes}"),
        sct: Sct::map(Sct::kernel(k)),
        workload: Workload::d1(nodes).with_class(WorkloadClass::Traversal),
        total_units: nodes,
        copy_bytes: 4.0 * N_NODES as f64,
    }
}

/// Mandelbrot escape iteration (Map, irregular tier): one epu unit = one
/// pixel, trip count varies per pixel up to `max_iters` — class
/// `Divergent`, the strongest per-chunk cost spread of the tier.
pub fn mandelbrot(px: u64, max_iters: u32) -> Benchmark {
    let mut k = KernelSpec::new(
        "mandelbrot",
        vec![
            ParamSpec::VecIn,
            ParamSpec::VecIn,
            ParamSpec::ScalarI32(ScalarTrait::Bound),
        ],
        1,
    );
    k.flops_per_unit = 8.0 * (max_iters as f64 / 4.0).max(1.0); // mean-trip guess
    k.bytes_per_unit = 12.0;
    k.passes = 1.0;
    k.footprint = fp(20, 0);
    k.chunk_cv = 0.8; // escape-time divergence
    Benchmark {
        name: format!("mandelbrot {px}"),
        sct: Sct::map(Sct::kernel(k)),
        workload: Workload::d1(px).with_class(WorkloadClass::Divergent),
        total_units: px,
        copy_bytes: 0.0,
    }
}

/// Table 2 / Section 4.1 parameterizations (CPU-only study).
pub fn table2_suite() -> Vec<Benchmark> {
    let mut v = Vec::new();
    for s in [1024u64, 2048, 4096, 8192] {
        v.push(filter_pipeline(s, s, true));
    }
    for mb in [128u64, 256, 512] {
        v.push(fft(mb));
    }
    for n in [8192u64, 16384, 32768, 65536] {
        v.push(nbody(n, 20));
    }
    for n in [1_000_000u64, 10_000_000, 50_000_000] {
        v.push(saxpy(n));
    }
    for mb in [1u64, 8, 60] {
        v.push(segmentation(mb));
    }
    v
}

/// Table 3 / Section 4.2 parameterizations (hybrid study).
pub fn table3_suite() -> Vec<Benchmark> {
    let mut v = Vec::new();
    for s in [2048u64, 4096, 8192] {
        v.push(filter_pipeline(s, s, true));
    }
    for mb in [128u64, 256, 512] {
        v.push(fft(mb));
    }
    for n in [16384u64, 32768, 65536] {
        v.push(nbody(n, 20));
    }
    for n in [1_000_000u64, 10_000_000, 100_000_000] {
        v.push(saxpy(n));
    }
    for mb in [1u64, 8, 60] {
        v.push(segmentation(mb));
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suites_cover_all_families() {
        let names: Vec<String> = table2_suite().iter().map(|b| b.name.clone()).collect();
        for fam in ["saxpy", "filter_pipeline", "fft", "nbody", "segmentation"] {
            assert!(
                names.iter().any(|n| n.starts_with(fam)),
                "missing {fam} in {names:?}"
            );
        }
        assert_eq!(table2_suite().len(), 17);
        assert_eq!(table3_suite().len(), 15);
    }

    #[test]
    fn irregular_benchmarks_declare_class_and_skew() {
        let s = spmv(1024);
        assert_eq!(s.workload.class, WorkloadClass::Sparse);
        assert_eq!(s.workload.id(), "1d:1024:f32:sparse");
        let b = bfs(1024);
        assert_eq!(b.workload.class, WorkloadClass::Traversal);
        let m = mandelbrot(32_768, 256);
        assert_eq!(m.workload.class, WorkloadClass::Divergent);
        for bench in [&s, &b, &m] {
            for k in bench.sct.kernels() {
                assert!(k.chunk_cv > 0.0, "{} must declare skew", bench.name);
            }
        }
        // The pinned paper suites stay untouched by the irregular tier.
        assert!(table2_suite().iter().all(|b| b
            .sct
            .kernels()
            .iter()
            .all(|k| k.chunk_cv == 0.0)));
    }

    #[test]
    fn fft_units_match_dataset_size() {
        let b = fft(128);
        assert_eq!(b.total_units, 128 * 1024 * 1024 / 4096);
    }

    #[test]
    fn nbody_is_global_sync_loop() {
        let b = nbody(16384, 20);
        assert_eq!(b.sct.sync_points(), 20);
        assert!(b.copy_bytes > 0.0);
    }

    #[test]
    fn fused_and_staged_filters_have_same_units() {
        let f = filter_pipeline(2048, 2048, true);
        let s = filter_pipeline(2048, 2048, false);
        assert_eq!(f.total_units, s.total_units);
        assert_eq!(s.sct.kernels().len(), 3);
        assert_eq!(f.sct.kernels().len(), 1);
    }
}
