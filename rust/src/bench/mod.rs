//! Benchmark harness and paper-evaluation regeneration (Section 4).
//!
//! [`workloads`] builds the five paper benchmarks as SCTs; [`harness`] is the
//! offline criterion replacement; [`eval`] regenerates every table and
//! figure of the paper's evaluation (Table 2-5, Fig 5-11) plus the ablation
//! studies called out in DESIGN.md §5.

pub mod eval;
pub mod harness;
pub mod workloads;

pub use harness::{BenchResult, Timer};
pub use workloads::Benchmark;
