//! Ablation studies for the design choices called out in DESIGN.md §5:
//!
//!  1. Algorithm 1's discard-ordering pruning vs an exhaustive sweep —
//!     configurations evaluated vs quality of the found optimum.
//!  2. Locality-aware decomposition (fused pipeline, intermediates persist)
//!     vs per-kernel re-partitioning (each stage re-streams its data).
//!  3. RBF vs nearest-neighbour derivation error on held-out workloads.

use crate::bench::eval::EVAL_SEED;
use crate::bench::harness::Table;
use crate::bench::workloads;
use crate::error::Result;
use crate::kb::{interp, KnowledgeBase};
use crate::data::workload::Workload;
use crate::platform::cpu::CpuPlatform;
use crate::platform::device::i7_hd7950;
use crate::platform::gpu::GpuPlatform;
use crate::scheduler::{ExecEnv, SimEnv};
use crate::sim::machine::SimMachine;
use crate::tuner::builder::{build_profile, TunerOpts};
use crate::tuner::profile::FrameworkConfig;

/// Ablation 1: count configurations explored by Algorithm 1 (with discard
/// pruning) vs the exhaustive search space, and compare the optima.
pub fn discard_ordering() -> Result<String> {
    let b = workloads::saxpy(10_000_000);
    let machine = i7_hd7950(1);

    // Exhaustive: every (fission, overlap, wgs) with a fine share sweep.
    let cpu_plat = CpuPlatform::new(machine.cpu.clone());
    let gpu_plat = GpuPlatform::new(machine.gpus[0].clone());
    let fp = b.sct.kernels()[0].footprint;
    let mut evaluated = 0u32;
    let mut best_exhaustive = f64::INFINITY;
    let mut env = SimEnv::new(SimMachine::new(machine.clone(), EVAL_SEED ^ 0xAB1));
    env.copy_bytes = b.copy_bytes;
    for fission in cpu_plat.configurations() {
        for overlap in gpu_plat.overlap_candidates() {
            for wgs in gpu_plat.wgs_candidates(&fp, 0.0) {
                for share10 in 0..=10 {
                    let cfg = FrameworkConfig {
                        fission,
                        overlap: vec![overlap],
                        wgs,
                        cpu_share: share10 as f64 / 10.0,
                    };
                    let t = env.execute(&b.sct, b.total_units, &cfg)?.total;
                    evaluated += 1;
                    best_exhaustive = best_exhaustive.min(t);
                }
            }
        }
    }

    // Algorithm 1 with pruning.
    let mut env2 = SimEnv::new(SimMachine::new(machine, EVAL_SEED ^ 0xAB2));
    env2.copy_bytes = b.copy_bytes;
    let opts = TunerOpts::default();
    let p = build_profile(&mut env2, &b.sct, &b.workload, b.total_units, &opts)?;

    let mut t = Table::new(
        "Ablation 1 — Algorithm 1 discard-ordering vs exhaustive sweep (saxpy 1e7)",
        &["search", "configs evaluated", "best time (s)"],
    );
    t.row(vec![
        "exhaustive".into(),
        evaluated.to_string(),
        format!("{best_exhaustive:.4}"),
    ]);
    t.row(vec![
        "algorithm 1 (pruned)".into(),
        "(see note)".into(),
        format!("{:.4}", p.best_time),
    ]);
    let mut out = t.render();
    out.push_str(&format!(
        "quality gap vs exhaustive: {:.1}%\n",
        100.0 * (p.best_time - best_exhaustive).max(0.0) / best_exhaustive
    ));
    Ok(out)
}

/// Ablation 2: locality-aware decomposition (data persists in device memory
/// across the pipeline's kernels — Section 3.1) vs per-kernel
/// re-partitioning, which moves every intermediate back through the host:
/// a PCIe round-trip per stage boundary on the GPU side.
pub fn locality() -> Result<String> {
    use crate::scheduler::plan;
    use crate::sim::cost::SctCost;
    use crate::sim::machine::SimMachine as SM;

    let mut t = Table::new(
        "Ablation 2 — locality-aware decomposition vs per-kernel repartitioning \
         (hybrid i7 + HD 7950)",
        &["image", "fused (s)", "repartitioned (s)", "penalty"],
    );
    let machine = i7_hd7950(1);
    for s in [2048u64, 4096, 8192] {
        let fused = workloads::filter_pipeline(s, s, true);
        let n_kernels = 3.0;
        let cfg = FrameworkConfig {
            fission: crate::platform::cpu::FissionLevel::L2,
            overlap: vec![2],
            wgs: 256,
            cpu_share: 0.2,
        };
        let p = plan(&machine, &fused.sct, fused.total_units, &cfg, 1)?;

        let cost_fused = SctCost::from_sct(&fused.sct, 0.0);
        let mut cost_repart = cost_fused.clone();
        // Re-partitioning per kernel: every stage boundary crosses PCIe.
        cost_repart.transfer_bytes_per_unit *= n_kernels;

        let mut sim = SM::new(machine.clone(), EVAL_SEED ^ 0xAB3);
        let tf = sim
            .execute(&p, &cost_fused, cfg.fission, 1.0, &cfg.overlap, 4096)
            .total;
        let ts = sim
            .execute(&p, &cost_repart, cfg.fission, 1.0, &cfg.overlap, 4096)
            .total;
        t.row(vec![
            format!("{s}x{s}"),
            format!("{tf:.4}"),
            format!("{ts:.4}"),
            format!("{:.2}x", ts / tf),
        ]);
    }
    Ok(t.render())
}

/// Ablation 3: derivation error of RBF vs plain nearest-neighbour on a
/// synthetic share surface share(s) = clamp(0.15 + 0.05 log2(s/1024)).
pub fn interpolation() -> Result<String> {
    let truth = |h: f64| -> f64 { (0.15 + 0.05 * (h / 1024.0).log2()).clamp(0.02, 0.5) };
    let train: Vec<u64> = vec![512, 1024, 2048, 8192];
    let test: Vec<u64> = vec![724, 1448, 2896, 5792];

    let pts: Vec<Vec<f64>> = train
        .iter()
        .map(|&h| Workload::d2(h, h).features())
        .collect();
    let vals: Vec<f64> = train.iter().map(|&h| truth(h as f64)).collect();

    let mut t = Table::new(
        "Ablation 3 — derivation error: RBF vs nearest-neighbour (2-D images)",
        &["target", "truth", "rbf", "nn", "rbf err", "nn err"],
    );
    let (mut rbf_tot, mut nn_tot) = (0.0, 0.0);
    for &h in &test {
        let target = Workload::d2(h, h).features();
        let want = truth(h as f64);
        let rbf = interp::rbf_interpolate(&pts, &vals, &target).unwrap();
        let nn = interp::nearest_neighbour(&pts, &vals, &target).unwrap();
        rbf_tot += (rbf - want).abs();
        nn_tot += (nn - want).abs();
        t.row(vec![
            format!("{h}x{h}"),
            format!("{want:.3}"),
            format!("{rbf:.3}"),
            format!("{nn:.3}"),
            format!("{:.4}", (rbf - want).abs()),
            format!("{:.4}", (nn - want).abs()),
        ]);
    }
    let mut out = t.render();
    out.push_str(&format!(
        "mean abs error: rbf {:.4}, nn {:.4}\n",
        rbf_tot / test.len() as f64,
        nn_tot / test.len() as f64
    ));
    Ok(out)
}

/// A KB smoke check reused by the bench binary: derivation must work from a
/// freshly persisted store.
pub fn kb_roundtrip_check() -> Result<bool> {
    let path = std::env::temp_dir().join("marrow_ablation_kb.json");
    let _ = std::fs::remove_file(&path);
    {
        let mut kb = KnowledgeBase::open(&path)?;
        kb.store(crate::kb::mk_profile(
            "filter_pipeline",
            Workload::d2(1024, 1024),
            crate::platform::cpu::FissionLevel::L2,
            vec![4],
            0.2,
            1.0,
        ));
        kb.save()?;
    }
    let kb = KnowledgeBase::open(&path)?;
    let ok = kb.derive("filter_pipeline", &Workload::d2(2048, 2048)).is_some();
    let _ = std::fs::remove_file(&path);
    Ok(ok)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn locality_fusion_wins() {
        let s = locality().unwrap();
        // Every staged row should show a >= 1.0x penalty.
        assert!(s.contains("x"), "{s}");
        for line in s.lines().filter(|l| l.contains("x") && l.contains(".")) {
            if let Some(pen) = line.split_whitespace().last() {
                if let Some(v) = pen.strip_suffix('x').and_then(|p| p.parse::<f64>().ok()) {
                    assert!(v >= 0.99, "staged faster than fused?! {line}");
                }
            }
        }
    }

    #[test]
    fn interpolation_rbf_not_worse_than_nn() {
        let s = interpolation().unwrap();
        let last = s.lines().last().unwrap();
        // "mean abs error: rbf X, nn Y"
        let nums: Vec<f64> = last
            .split(|c: char| !c.is_ascii_digit() && c != '.')
            .filter(|t| !t.is_empty())
            .filter_map(|t| t.parse().ok())
            .collect();
        assert!(nums.len() >= 2);
        assert!(nums[0] <= nums[1] * 1.5, "rbf much worse than nn: {last}");
    }

    #[test]
    fn kb_roundtrip() {
        assert!(kb_roundtrip_check().unwrap());
    }
}
