//! Regeneration of every table and figure in the paper's evaluation
//! (Section 4). Each module prints the same rows/series the paper reports;
//! all run in Simulated mode (the testbed substitution, DESIGN.md §1.1) and
//! state so in their headers. Absolute numbers differ from the authors'
//! hardware; the *shape* (who wins, rough factors, crossovers) is the
//! reproduction target.

pub mod ablations;
pub mod fig11;
pub mod table2;
pub mod table3;
pub mod table4;
pub mod table5;

/// Shared seed so every eval is reproducible run-to-run.
pub const EVAL_SEED: u64 = 0x3A77;
