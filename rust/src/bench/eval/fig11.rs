//! Fig 11: FFT 128 MB under CPU load fluctuations — the framework's
//! adaptation trace (Section 4.2.2).
//!
//! An external application spawns compute-heavy threads mid-experiment; the
//! load balancer detects the unbalance and shifts work to the GPU: an
//! abrupt-but-quick shifting phase (1-4 runs in the paper) followed by a
//! smoother in-depth binary search (~10 runs). The whole experiment runs
//! through the [`Session`] facade: profile under stable load, then repeated
//! `Session::run` requests on a loaded machine with the warm KB.

use crate::bench::eval::EVAL_SEED;
use crate::bench::harness::Table;
use crate::bench::workloads;
use crate::error::Result;
use crate::platform::device::i7_hd7950;
use crate::runtime::exec::RequestArgs;
use crate::session::{Computation, Session};
use crate::sim::cpuload::LoadProfile;
use crate::sim::machine::SimMachine;

/// The run index where the external load kicks in.
pub const LOAD_AT: u64 = 20;
/// Interfering compute threads (the i7 has 6 cores).
pub const LOAD_THREADS: u32 = 9;
pub const RUNS: u64 = 100;

/// One point of the adaptation trace.
#[derive(Clone, Debug)]
pub struct TracePoint {
    pub run: u64,
    pub gpu_share_pct: f64,
    pub time: f64,
    pub triggered: bool,
}

/// Run the experiment; returns the trace.
pub fn run() -> Result<Vec<TracePoint>> {
    let comp = Computation::from(workloads::fft(128));
    // Initial distribution from a stable-load profile (Table 3's ~75/25),
    // persisted in the session's knowledge base.
    let tuned = Session::simulated(i7_hd7950(1), EVAL_SEED ^ 0x11);
    tuned.profile(&comp)?;

    // Same facade on the loaded machine, warm KB: every request is a KB
    // hit and the monitor/ABS refine the stored distribution in place.
    let sim = SimMachine::new(i7_hd7950(1), EVAL_SEED ^ 0x12)
        .with_load(LoadProfile::step_at(LOAD_AT, LOAD_THREADS));
    let s = Session::sim(sim).with_kb(tuned.into_kb());

    let args = RequestArgs::default();
    let mut trace = Vec::new();
    for run in 0..RUNS {
        let out = s.run(&comp, &args)?;
        trace.push(TracePoint {
            run,
            gpu_share_pct: 100.0 * out.config.gpu_share(),
            time: out.exec.total,
            triggered: out.rebalanced,
        });
    }
    Ok(trace)
}

pub fn report() -> Result<String> {
    let trace = run()?;
    let mut t = Table::new(
        &format!(
            "Fig 11 — FFT 128 MB adaptation to a CPU load spike at run {LOAD_AT} \
             ({LOAD_THREADS} external threads, simulated)"
        ),
        &["run", "GPU share %", "exec time (s)", "balance op"],
    );
    for p in &trace {
        // Compact: print every 2nd point before the spike, all after.
        if p.run < LOAD_AT && p.run % 4 != 0 {
            continue;
        }
        t.row(vec![
            p.run.to_string(),
            format!("{:.1}", p.gpu_share_pct),
            format!("{:.3}", p.time),
            if p.triggered { "*".into() } else { "".into() },
        ]);
    }
    Ok(t.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adapts_by_shifting_work_to_gpu() {
        let trace = run().unwrap();
        let before = trace[LOAD_AT as usize - 1].gpu_share_pct;
        let after = trace.last().unwrap().gpu_share_pct;
        assert!(
            after > before + 3.0,
            "GPU share should grow under CPU load: {before}% -> {after}%"
        );
    }

    #[test]
    fn balancer_reacts_within_a_dozen_runs() {
        let trace = run().unwrap();
        let first_op = trace
            .iter()
            .filter(|p| p.run >= LOAD_AT && p.triggered)
            .map(|p| p.run)
            .next();
        let at = first_op.expect("load spike must trigger balancing");
        assert!(
            at < LOAD_AT + 15,
            "first balance op too late: run {at} (spike at {LOAD_AT})"
        );
    }

    #[test]
    fn stable_phase_holds_distribution() {
        let trace = run().unwrap();
        let shares: Vec<f64> = trace[..LOAD_AT as usize]
            .iter()
            .map(|p| p.gpu_share_pct)
            .collect();
        let spread = shares.iter().cloned().fold(0.0, f64::max)
            - shares.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(spread < 15.0, "pre-spike distribution drifted {spread} points");
    }
}
