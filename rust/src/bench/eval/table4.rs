//! Table 4: maxDev calibration — the largest deviation bound that lets 500
//! stable-load executions run without triggering the load balancer
//! (Section 4.2.2).

use crate::balance::monitor::Monitor;
use crate::bench::eval::EVAL_SEED;
use crate::bench::harness::Table;
use crate::bench::workloads::{self, Benchmark};
use crate::error::Result;
use crate::platform::device::i7_hd7950;
use crate::scheduler::{ExecEnv, SimEnv};
use crate::sim::machine::SimMachine;
use crate::tuner::builder::{build_profile, TunerOpts};

pub const RUNS: u32 = 500;

/// Calibrate maxDev for one benchmark: run 500 executions under the
/// profiled configuration and report the minimum observed deviation — any
/// `maxDev` at or below it never triggers balancing.
pub fn calibrate(b: &Benchmark, runs: u32) -> Result<f64> {
    let mut env = SimEnv::new(SimMachine::new(i7_hd7950(1), EVAL_SEED ^ 0x44));
    env.copy_bytes = b.copy_bytes;
    let profile = build_profile(
        &mut env,
        &b.sct,
        &b.workload,
        b.total_units,
        &TunerOpts::default(),
    )?;
    let mut monitor = Monitor::new(0.0); // record-only
    for _ in 0..runs {
        let out = env.execute(&b.sct, b.total_units, &profile.config)?;
        monitor.observe(&out.slot_times);
    }
    Ok(monitor.min_dev())
}

/// The paper's Table-4 benchmark subset.
pub fn suite() -> Vec<Benchmark> {
    vec![
        workloads::saxpy(1_000_000),
        workloads::saxpy(10_000_000),
        workloads::saxpy(50_000_000),
        workloads::segmentation(1),
        workloads::segmentation(8),
        workloads::segmentation(60),
        workloads::filter_pipeline(2048, 2048, true),
        workloads::filter_pipeline(4096, 4096, true),
        workloads::filter_pipeline(8192, 8192, true),
        workloads::fft(128),
        workloads::fft(256),
        workloads::fft(512),
    ]
}

pub fn report(runs: u32) -> Result<String> {
    let mut t = Table::new(
        &format!("Table 4 — maxDev calibration over {runs} stable executions (simulated)"),
        &["benchmark", "maxDev"],
    );
    let mut devs = Vec::new();
    for b in suite() {
        let d = calibrate(&b, runs)?;
        devs.push(d);
        t.row(vec![b.name.clone(), format!("{d:.3}")]);
    }
    let lo = devs.iter().copied().fold(f64::INFINITY, f64::min);
    let hi = devs.iter().copied().fold(0.0f64, f64::max);
    let mut out = t.render();
    out.push_str(&format!(
        "\nadequate general maxDev range: [{lo:.2}, {hi:.2}] (paper: [0.8, 0.85])\n"
    ));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_lands_near_paper_range() {
        // 60 runs is enough for the test; the bench uses 500.
        let d = calibrate(&workloads::saxpy(10_000_000), 60).unwrap();
        assert!(
            (0.70..0.995).contains(&d),
            "maxDev {d} outside plausible stable-load band"
        );
    }

    #[test]
    fn all_suite_benchmarks_calibrate_consistently() {
        let mut devs = Vec::new();
        for b in [
            workloads::saxpy(1_000_000),
            workloads::segmentation(8),
            workloads::fft(128),
        ] {
            devs.push(calibrate(&b, 40).unwrap());
        }
        for d in &devs {
            assert!(*d > 0.6, "dev {d} too unstable for stable-load runs");
        }
    }
}
