//! Table 2 + Fig 5 + Fig 6: CPU-only executions on the 4x Opteron 6272
//! testbed — best fission configuration vs no fission (Section 4.1).

use crate::bench::harness::Table;
use crate::bench::workloads::{self, Benchmark};
use crate::platform::cpu::{CpuPlatform, FissionLevel};
use crate::platform::device::opteron_6272_quad;
use crate::scheduler::{ExecEnv, SimEnv};
use crate::sim::machine::SimMachine;
use crate::tuner::profile::FrameworkConfig;
use crate::bench::eval::EVAL_SEED;
use crate::error::Result;

/// One Table-2 row.
#[derive(Clone, Debug)]
pub struct Row {
    pub benchmark: String,
    pub best_level: FissionLevel,
    pub subdevices: u32,
    pub t_best: f64,
    pub t_nofission: f64,
}

impl Row {
    pub fn speedup(&self) -> f64 {
        self.t_nofission / self.t_best
    }
}

/// Time one benchmark at a fission level (mean of `reps` sim executions).
fn time_at_level(env: &mut SimEnv, b: &Benchmark, level: FissionLevel, reps: u32) -> Result<f64> {
    env.copy_bytes = b.copy_bytes;
    let cfg = FrameworkConfig::cpu_only(level);
    let mut t = 0.0;
    for _ in 0..reps {
        t += env.execute(&b.sct, b.total_units, &cfg)?.total;
    }
    Ok(t / reps as f64)
}

/// Fission sweep for one benchmark: time per supported level (Fig 5 data).
pub fn fission_sweep(b: &Benchmark, seed: u64) -> Result<Vec<(FissionLevel, f64)>> {
    let mut env = SimEnv::new(SimMachine::new(opteron_6272_quad(), seed));
    let plat = CpuPlatform::new(env.machine().cpu.clone());
    let mut out = Vec::new();
    for level in plat.configurations() {
        out.push((level, time_at_level(&mut env, b, level, 3)?));
    }
    Ok(out)
}

/// Compute all Table-2 rows.
pub fn rows() -> Result<Vec<Row>> {
    let plat = CpuPlatform::new(opteron_6272_quad().cpu);
    let mut rows = Vec::new();
    for b in workloads::table2_suite() {
        let sweep = fission_sweep(&b, EVAL_SEED)?;
        let (best_level, t_best) = sweep
            .iter()
            .filter(|(l, _)| *l != FissionLevel::NoFission)
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .copied()
            .unwrap();
        let t_nofission = sweep
            .iter()
            .find(|(l, _)| *l == FissionLevel::NoFission)
            .unwrap()
            .1;
        rows.push(Row {
            benchmark: b.name.clone(),
            best_level,
            subdevices: plat.subdevice_count(best_level),
            t_best,
            t_nofission,
        });
    }
    Ok(rows)
}

/// Render Table 2 (+ Fig 6 speedups as the last column).
pub fn report() -> Result<String> {
    let mut t = Table::new(
        "Table 2 — CPU-only executions (4x Opteron 6272, simulated clock)",
        &[
            "benchmark",
            "fission",
            "subdevices",
            "time (s)",
            "no-fission (s)",
            "fig6 speedup",
        ],
    );
    for r in rows()? {
        t.row(vec![
            r.benchmark.clone(),
            r.best_level.label().to_string(),
            r.subdevices.to_string(),
            format!("{:.3}", r.t_best),
            format!("{:.3}", r.t_nofission),
            format!("{:.2}x", r.speedup()),
        ]);
    }
    let mut out = t.render();

    // Fig 5: execution times across fission configurations, FFT 256 MB.
    let fft = workloads::fft(256);
    let mut f5 = Table::new(
        "Fig 5 — fission sweep, FFT 256 MB",
        &["fission level", "subdevices", "time (s)"],
    );
    let plat = CpuPlatform::new(opteron_6272_quad().cpu);
    for (level, time) in fission_sweep(&fft, EVAL_SEED)? {
        f5.row(vec![
            level.label().to_string(),
            plat.subdevice_count(level).to_string(),
            format!("{time:.3}"),
        ]);
    }
    out.push_str(&f5.render());
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fission_always_helps_on_the_numa_box() {
        // Fig 6 shape: every benchmark speeds up with the best fission level.
        for r in rows().unwrap() {
            assert!(
                r.speedup() > 1.0,
                "{}: fission {} not faster ({} vs {})",
                r.benchmark,
                r.best_level.label(),
                r.t_best,
                r.t_nofission
            );
        }
    }

    #[test]
    fn speedups_in_paper_regime() {
        // Paper range: ~1.15x (small filter) to ~4x (FFT/NBody/saxpy).
        let rs = rows().unwrap();
        let max_sp = rs.iter().map(Row::speedup).fold(0.0, f64::max);
        let min_sp = rs.iter().map(Row::speedup).fold(f64::INFINITY, f64::min);
        assert!(max_sp > 2.0, "max speedup {max_sp} too small");
        assert!(max_sp < 10.0, "max speedup {max_sp} implausible");
        assert!(min_sp > 1.0 && min_sp < 2.0, "min speedup {min_sp}");
    }

    #[test]
    fn best_level_is_l2_or_l3_mostly() {
        // Table 2: best levels are L2 (majority) and L3 — affinity domains
        // with meaningful shared cache, not L1 or NUMA.
        let rs = rows().unwrap();
        let good = rs
            .iter()
            .filter(|r| {
                matches!(r.best_level, FissionLevel::L2 | FissionLevel::L3)
            })
            .count();
        assert!(
            good * 2 > rs.len(),
            "L2/L3 should dominate: {:?}",
            rs.iter()
                .map(|r| (r.benchmark.clone(), r.best_level.label()))
                .collect::<Vec<_>>()
        );
    }
}
