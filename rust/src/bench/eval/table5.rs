//! Table 5 + Fig 9 + Fig 10: profile construction vs KB derivation on the
//! Filter Pipeline over 8 images of different sizes (Section 4.2.2).
//!
//! Protocol: construct individual baselines per image; then, starting from
//! a KB holding only Image 0's profile (profile construction switched off),
//! apply the benchmark to images 1..7 — each derives its configuration from
//! the KB, runs 100 times with maxDev = 0.85 under the load balancer, and
//! persists the refined distribution.

use crate::balance::LoadBalancer;
use crate::bench::eval::EVAL_SEED;
use crate::bench::harness::Table;
use crate::bench::workloads;
use crate::data::workload::Workload;
use crate::error::Result;
use crate::kb::KnowledgeBase;
use crate::platform::device::i7_hd7950;
use crate::scheduler::{ExecEnv, SimEnv};
use crate::sim::machine::SimMachine;
use crate::tuner::builder::{build_profile, TunerOpts};
use crate::tuner::profile::{Profile, ProfileOrigin};

/// The paper's image set (Table 5).
pub const IMAGES: [(u64, u64); 8] = [
    (1024, 1024),
    (4288, 2848),
    (512, 512),
    (8192, 8192),
    (1800, 1125),
    (2048, 2048),
    (256, 512),
    (1440, 900),
];

pub const RUNS_PER_IMAGE: u32 = 100;
pub const MAX_DEV: f64 = 0.85;

/// Result for one derived image.
#[derive(Clone, Debug)]
pub struct Row {
    pub image: usize,
    pub size: (u64, u64),
    /// Construction baseline: GPU share and time.
    pub built_gpu_pct: f64,
    pub built_time: f64,
    /// Derived-from-KB starting distribution.
    pub derived_gpu_pct: f64,
    pub unbalanced: u32,
    pub balance_ops: u32,
    /// Persisted (post-balancing) distribution and its time.
    pub persisted_gpu_pct: f64,
    pub exec_time: f64,
}

fn env_for(seed: u64) -> SimEnv {
    SimEnv::new(SimMachine::new(i7_hd7950(1), seed))
}

/// Individual profile-construction baseline for one image.
pub fn build_baseline(h: u64, w: u64, seed: u64) -> Result<Profile> {
    let b = workloads::filter_pipeline(h, w, true);
    let mut env = env_for(seed);
    env.copy_bytes = b.copy_bytes;
    build_profile(
        &mut env,
        &b.sct,
        &b.workload,
        b.total_units,
        &TunerOpts::default(),
    )
}

/// Run the full Table-5 protocol.
pub fn run() -> Result<(Vec<Row>, Vec<Profile>)> {
    // Baselines (left-hand side of the table).
    let mut baselines = Vec::new();
    for (i, &(h, w)) in IMAGES.iter().enumerate() {
        baselines.push(build_baseline(h, w, EVAL_SEED ^ (i as u64) << 8)?);
    }

    // KB seeded with image 0 only.
    let mut kb = KnowledgeBase::in_memory();
    kb.store(baselines[0].clone());

    let mut rows = Vec::new();
    for (i, &(h, w)) in IMAGES.iter().enumerate().skip(1) {
        let b = workloads::filter_pipeline(h, w, true);
        let wl = Workload::d2(h, w);
        let mut cfg = kb
            .derive(&b.sct.id(), &wl)
            .expect("KB must derive for seen dimensionality");
        let derived_gpu_pct = 100.0 * cfg.gpu_share();

        let mut env = env_for(EVAL_SEED ^ 0x5000 ^ i as u64);
        env.copy_bytes = b.copy_bytes;
        let mut lb = LoadBalancer::new(MAX_DEV, cfg.cpu_share);
        let mut total = 0.0;
        for _ in 0..RUNS_PER_IMAGE {
            let out = lb.step(&mut env, &b.sct, b.total_units, &mut cfg)?;
            total += out.total;
        }
        let exec_time = total / RUNS_PER_IMAGE as f64;

        // Persist the refined configuration.
        kb.store(Profile {
            sct_id: b.sct.id(),
            workload: wl,
            config: cfg.clone(),
            best_time: exec_time,
            origin: ProfileOrigin::Refined,
        });

        rows.push(Row {
            image: i,
            size: (h, w),
            built_gpu_pct: 100.0 * baselines[i].config.gpu_share(),
            built_time: baselines[i].best_time,
            derived_gpu_pct,
            unbalanced: lb.unbalanced_runs,
            balance_ops: lb.balance_ops,
            persisted_gpu_pct: 100.0 * cfg.gpu_share(),
            exec_time,
        });
    }
    Ok((rows, baselines))
}

pub fn report() -> Result<String> {
    let (rows, baselines) = run()?;
    let mut t = Table::new(
        "Table 5 — profile construction vs derivation (Filter Pipeline, simulated)",
        &[
            "image",
            "size",
            "built GPU%",
            "built time",
            "derived GPU%",
            "unbalanced",
            "balance ops",
            "persisted GPU%",
            "exec time",
        ],
    );
    t.row(vec![
        "Image 0".into(),
        format!("{}x{}", IMAGES[0].0, IMAGES[0].1),
        format!("{:.1}", 100.0 * baselines[0].config.gpu_share()),
        format!("{:.3}", baselines[0].best_time),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
    for r in &rows {
        t.row(vec![
            format!("Image {}", r.image),
            format!("{}x{}", r.size.0, r.size.1),
            format!("{:.1}", r.built_gpu_pct),
            format!("{:.3}", r.built_time),
            format!("{:.1}", r.derived_gpu_pct),
            r.unbalanced.to_string(),
            r.balance_ops.to_string(),
            format!("{:.1}", r.persisted_gpu_pct),
            format!("{:.3}", r.exec_time),
        ]);
    }
    let mut out = t.render();

    // Fig 9: evolution of the distribution / performance error vs the
    // construction baseline.
    let mut f9 = Table::new(
        "Fig 9 — error of derived configuration vs construction (%)",
        &["image", "distribution error %", "performance error %"],
    );
    for r in &rows {
        let dist_err = (r.persisted_gpu_pct - r.built_gpu_pct).abs();
        let perf_err = 100.0 * (r.exec_time - r.built_time).max(0.0) / r.built_time;
        f9.row(vec![
            format!("Image {}", r.image),
            format!("{dist_err:.2}"),
            format!("{perf_err:.2}"),
        ]);
    }
    out.push_str(&f9.render());

    // Fig 10: unbalanced executions and balancing operations per image.
    let mut f10 = Table::new(
        "Fig 10 — load-balancing activity per image (100 runs each)",
        &["image", "unbalanced executions", "balance ops"],
    );
    for r in &rows {
        f10.row(vec![
            format!("Image {}", r.image),
            r.unbalanced.to_string(),
            r.balance_ops.to_string(),
        ]);
    }
    out.push_str(&f10.render());
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derivation_tracks_construction() {
        let (rows, _) = run().unwrap();
        assert_eq!(rows.len(), 7);
        // Paper: distribution error under ~3 points, performance error
        // under ~5% after the first images; we assert a loose envelope on
        // the persisted results.
        for r in &rows {
            assert!(
                (r.persisted_gpu_pct - r.built_gpu_pct).abs() < 12.0,
                "image {}: persisted {}% vs built {}%",
                r.image,
                r.persisted_gpu_pct,
                r.built_gpu_pct
            );
        }
        let avg_perf_err: f64 = rows
            .iter()
            .map(|r| ((r.exec_time - r.built_time) / r.built_time).max(0.0))
            .sum::<f64>()
            / rows.len() as f64;
        assert!(avg_perf_err < 0.12, "avg perf error {avg_perf_err}");
    }

    #[test]
    fn balancing_is_rare_under_stable_load() {
        let (rows, _) = run().unwrap();
        for r in &rows {
            assert!(
                r.balance_ops <= 12,
                "image {}: {} balance ops in 100 runs",
                r.image,
                r.balance_ops
            );
        }
    }
}
