//! Table 3 + Fig 7 + Fig 8: hybrid CPU+GPU executions on the i7-3930K +
//! HD 7950 testbed vs GPU-only baselines (Section 4.2).

use crate::bench::eval::EVAL_SEED;
use crate::bench::harness::Table;
use crate::bench::workloads::{self, Benchmark};
use crate::error::Result;
use crate::platform::cpu::CpuPlatform;
use crate::platform::device::i7_hd7950;
use crate::scheduler::{ExecEnv, SimEnv};
use crate::sim::machine::SimMachine;
use crate::tuner::builder::{build_profile, TunerOpts};
use crate::tuner::profile::{FrameworkConfig, Profile};

/// One Table-3 row (for a given GPU count).
#[derive(Clone, Debug)]
pub struct Row {
    pub benchmark: String,
    pub gpus: usize,
    /// GPU-only baseline time (s).
    pub baseline: f64,
    /// Profiled hybrid configuration and its time.
    pub profile: Profile,
    pub parallelism: u32,
}

impl Row {
    /// Fig 7 / Fig 8 speedup of CPU+GPU over GPU-only.
    pub fn speedup(&self) -> f64 {
        self.baseline / self.profile.best_time
    }
}

/// GPU-only baseline: best overlap with zero CPU share.
fn gpu_baseline(env: &mut SimEnv, b: &Benchmark) -> Result<f64> {
    env.copy_bytes = b.copy_bytes;
    let n = env.machine().gpus.len();
    let mut best = f64::INFINITY;
    for o in 1..=8u32 {
        let cfg = FrameworkConfig {
            fission: crate::platform::cpu::FissionLevel::L3,
            overlap: vec![o; n],
            wgs: 256,
            cpu_share: 0.0,
        };
        let mut t = 0.0;
        for _ in 0..3 {
            t += env.execute(&b.sct, b.total_units, &cfg)?.total;
        }
        best = best.min(t / 3.0);
    }
    Ok(best)
}

/// Compute the rows for one GPU count.
pub fn rows(n_gpus: usize) -> Result<Vec<Row>> {
    let machine = i7_hd7950(n_gpus);
    let cpu_plat = CpuPlatform::new(machine.cpu.clone());
    let mut out = Vec::new();
    for b in workloads::table3_suite() {
        let mut env = SimEnv::new(SimMachine::new(machine.clone(), EVAL_SEED ^ n_gpus as u64));
        env.copy_bytes = b.copy_bytes;
        let baseline = gpu_baseline(&mut env, &b)?;
        let profile = build_profile(
            &mut env,
            &b.sct,
            &b.workload,
            b.total_units,
            &TunerOpts::default(),
        )?;
        let parallelism = profile.config.parallelism(&cpu_plat);
        out.push(Row {
            benchmark: b.name.clone(),
            gpus: n_gpus,
            baseline,
            profile,
            parallelism,
        });
    }
    Ok(out)
}

/// Render Table 3 for both GPU counts + Fig 7/8 speedup series.
pub fn report() -> Result<String> {
    let mut out = String::new();
    for n in [1usize, 2] {
        let mut t = Table::new(
            &format!("Table 3 — CPU+{n} GPU executions (i7-3930K + HD 7950, simulated clock)"),
            &[
                "benchmark",
                "GPU-only (s)",
                "hybrid (s)",
                "fission/overlap",
                "parallelism",
                "GPU/CPU split",
                &format!("fig{} speedup", if n == 1 { 7 } else { 8 }),
            ],
        );
        for r in rows(n)? {
            let c = &r.profile.config;
            t.row(vec![
                r.benchmark.clone(),
                format!("{:.3}", r.baseline),
                format!("{:.3}", r.profile.best_time),
                format!(
                    "{}/{}",
                    if c.cpu_share > 0.0 { c.fission.label() } else { "-" },
                    c.overlap.first().copied().unwrap_or(0)
                ),
                r.parallelism.to_string(),
                format!(
                    "{:.1}/{:.1}",
                    100.0 * c.gpu_share(),
                    100.0 * c.cpu_share
                ),
                format!("{:.2}x", r.speedup()),
            ]);
        }
        out.push_str(&t.render());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows1() -> Vec<Row> {
        rows(1).unwrap()
    }

    #[test]
    fn hybrid_beats_or_matches_gpu_only() {
        // Fig 7 shape: speedup >= ~1 everywhere; NBody is the exception
        // allowed to sit at 1.0.
        for r in rows1() {
            assert!(
                r.speedup() > 0.97,
                "{}: hybrid {} worse than baseline {}",
                r.benchmark,
                r.profile.best_time,
                r.baseline
            );
        }
    }

    #[test]
    fn communication_bound_benchmarks_gain_most() {
        // Saxpy/segmentation should show clear gains with 1 GPU.
        let rs = rows1();
        let saxpy_gain = rs
            .iter()
            .filter(|r| r.benchmark.starts_with("saxpy"))
            .map(Row::speedup)
            .fold(0.0, f64::max);
        assert!(saxpy_gain > 1.15, "saxpy max speedup {saxpy_gain}");
    }

    #[test]
    fn nbody_goes_all_gpu() {
        // Table 3: NBody distribution is 100/0 — global-sync loop makes CPU
        // participation net-negative.
        for r in rows1().iter().filter(|r| r.benchmark.starts_with("nbody")) {
            assert!(
                r.profile.config.cpu_share < 0.05,
                "{}: cpu share {}",
                r.benchmark,
                r.profile.config.cpu_share
            );
        }
    }

    #[test]
    fn cpu_share_shrinks_with_more_gpus() {
        // Paper: "the load assigned to the CPU is inversely proportional to
        // the number of GPUs" — compare suite-average shares.
        let avg = |rs: &[Row]| {
            let xs: Vec<f64> = rs
                .iter()
                .filter(|r| !r.benchmark.starts_with("nbody"))
                .map(|r| r.profile.config.cpu_share)
                .collect();
            xs.iter().sum::<f64>() / xs.len() as f64
        };
        let a1 = avg(&rows1());
        let a2 = avg(&rows(2).unwrap());
        assert!(
            a2 < a1 + 0.02,
            "avg cpu share should not grow with GPUs: {a1} -> {a2}"
        );
    }
}
