//! Wall-clock timing harness — the offline stand-in for criterion.
//!
//! Warmup + fixed-iteration measurement with median/p95 reporting, an
//! aligned-table reporter shared by every `benches/*.rs` target, and a
//! [`Timer::time_session`] entry that benchmarks whole requests through the
//! [`crate::session::Session`] facade.

use std::time::Instant;

use crate::error::Result;
use crate::runtime::exec::RequestArgs;
use crate::scheduler::ExecEnv;
use crate::session::{Computation, Session};
use crate::util::stats::{max, mean, median, min, percentile};

/// One measured benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl BenchResult {
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>10} {:>12} {:>12} {:>12}",
            self.name,
            self.iters,
            fmt_time(self.median_s),
            fmt_time(self.mean_s),
            fmt_time(self.p95_s)
        )
    }

    pub fn header() -> String {
        format!(
            "{:<44} {:>10} {:>12} {:>12} {:>12}",
            "benchmark", "iters", "median", "mean", "p95"
        )
    }
}

/// Human time formatting (s / ms / µs / ns).
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// The measurement driver.
pub struct Timer {
    pub warmup: usize,
    pub iters: usize,
}

impl Default for Timer {
    fn default() -> Self {
        Timer {
            warmup: 2,
            iters: 10,
        }
    }
}

impl Timer {
    pub fn new(warmup: usize, iters: usize) -> Timer {
        Timer { warmup, iters }
    }

    /// Time a closure; the closure must perform one full operation.
    pub fn time<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        BenchResult {
            name: name.to_string(),
            iters: self.iters,
            mean_s: mean(&samples),
            median_s: median(&samples),
            p95_s: percentile(&samples, 95.0),
            min_s: min(&samples),
            max_s: max(&samples),
        }
    }

    /// Time repeated [`Session::run`] requests of one computation — the
    /// facade-level benchmark entry. The first request runs untimed so
    /// cold-start tuning happens before measurement; a failure in any
    /// request (including the timed ones) fails the whole benchmark rather
    /// than silently skewing the statistics.
    pub fn time_session<E: ExecEnv>(
        &self,
        name: &str,
        session: &Session<E>,
        comp: &Computation,
        args: &RequestArgs,
    ) -> Result<BenchResult> {
        session.run(comp, args)?;
        let mut failure = None;
        let result = self.time(name, || {
            if failure.is_none() {
                if let Err(e) = session.run(comp, args) {
                    failure = Some(e);
                }
            }
        });
        match failure {
            Some(e) => Err(e),
            None => Ok(result),
        }
    }
}

/// Fixed-width table printer for eval outputs.
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub widths: Vec<usize>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            widths: columns.iter().map(|c| c.len().max(8)).collect(),
            columns: columns.iter().map(|c| c.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        for (w, c) in self.widths.iter_mut().zip(&cells) {
            *w = (*w).max(c.len());
        }
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("\n=== {} ===\n", self.title));
        let hdr: Vec<String> = self
            .columns
            .iter()
            .zip(&self.widths)
            .map(|(c, w)| format!("{c:<w$}"))
            .collect();
        out.push_str(&hdr.join("  "));
        out.push('\n');
        out.push_str(&"-".repeat(hdr.join("  ").len()));
        out.push('\n');
        for row in &self.rows {
            let cells: Vec<String> = row
                .iter()
                .zip(&self.widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect();
            out.push_str(&cells.join("  "));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::workloads;
    use crate::platform::device::i7_hd7950;

    #[test]
    fn time_session_measures_facade_requests() {
        let comp = Computation::from(workloads::saxpy(1 << 16));
        let s = Session::simulated(i7_hd7950(1), 4);
        let r = Timer::new(0, 3)
            .time_session("saxpy via session", &s, &comp, &RequestArgs::default())
            .unwrap();
        assert_eq!(r.iters, 3);
        // 1 untimed + 3 timed requests went through the facade.
        assert_eq!(s.stats().runs, 4);
    }

    #[test]
    fn timing_produces_ordered_stats() {
        let t = Timer::new(1, 8);
        let r = t.time("spin", || {
            std::hint::black_box((0..2000).sum::<u64>());
        });
        assert!(r.min_s <= r.median_s);
        assert!(r.median_s <= r.p95_s + 1e-12);
        assert!(r.p95_s <= r.max_s + 1e-12);
        assert_eq!(r.iters, 8);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" µs"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["xx".into(), "123456789".into()]);
        let s = t.render();
        assert!(s.contains("=== T ==="));
        assert!(s.contains("123456789"));
    }
}
