//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls keep the crate dependency-free (no
//! `thiserror` offline); the `From<xla::Error>` conversion only exists when
//! the real PJRT runtime is compiled in.

use std::fmt;

/// Unified error type for every marrow subsystem.
#[derive(Debug)]
pub enum Error {
    /// Partitioning constraints of Section 3.1 cannot be satisfied.
    Decompose(String),

    /// A kernel/SCT specification is inconsistent.
    Spec(String),

    /// Artifact manifest or HLO loading problems.
    Artifact(String),

    /// PJRT / XLA runtime failure (or the runtime is not compiled in).
    Runtime(String),

    /// Knowledge-base lookup/persistence failure.
    Kb(String),

    /// Profiling / tuning failure.
    Tuner(String),

    /// JSON parse error (own parser: no serde offline).
    Json { offset: usize, msg: String },

    /// CLI usage error.
    Usage(String),

    /// Underlying I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Decompose(m) => write!(f, "decomposition error: {m}"),
            Error::Spec(m) => write!(f, "specification error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Kb(m) => write!(f, "knowledge base error: {m}"),
            Error::Tuner(m) => write!(f, "tuner error: {m}"),
            Error::Json { offset, msg } => {
                write!(f, "json error at byte {offset}: {msg}")
            }
            Error::Usage(m) => write!(f, "usage error: {m}"),
            Error::Io(e) => write!(f, "i/o error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(format!("{e:?}"))
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
