//! Crate-wide error type.

use thiserror::Error;

/// Unified error type for every marrow subsystem.
#[derive(Error, Debug)]
pub enum Error {
    /// Partitioning constraints of Section 3.1 cannot be satisfied.
    #[error("decomposition error: {0}")]
    Decompose(String),

    /// A kernel/SCT specification is inconsistent.
    #[error("specification error: {0}")]
    Spec(String),

    /// Artifact manifest or HLO loading problems.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// PJRT / XLA runtime failure.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Knowledge-base lookup/persistence failure.
    #[error("knowledge base error: {0}")]
    Kb(String),

    /// Profiling / tuning failure.
    #[error("tuner error: {0}")]
    Tuner(String),

    /// JSON parse error (own parser: no serde offline).
    #[error("json error at byte {offset}: {msg}")]
    Json { offset: usize, msg: String },

    /// CLI usage error.
    #[error("usage error: {0}")]
    Usage(String),

    #[error("i/o error: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(format!("{e:?}"))
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
