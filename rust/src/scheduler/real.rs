//! Real-mode scheduler: orchestrates an execution request end-to-end on the
//! PJRT runtime — decomposition, per-slot work queues, chunked execution,
//! partial-result merging, host-side Loop state updates and MapReduce
//! reductions (Sections 3.1 and 3.4).
//!
//! `RealScheduler` implements the widened [`ExecEnv`] trait, so the session
//! facade, the tuner and the load balancer drive it exactly like the
//! simulated backend — timing-only probes use [`ExecEnv::execute`] with the
//! bound tuning arguments, full requests go through
//! [`ExecEnv::run_request`].

use std::time::Instant;

use crate::data::vector::{ArgValue, Merge};
use crate::decompose::PartitionPlan;
use crate::error::{Error, Result};
use crate::platform::device::Machine;
use crate::runtime::artifacts::Manifest;
use crate::runtime::client::RtClient;
use crate::runtime::exec::{ChunkRunner, RequestArgs};
use crate::scheduler::queues::WorkQueues;
use crate::scheduler::{plan, ExecEnv, ExecOutcome, RunOutcome};
use crate::sct::{Reduction, Sct};
use crate::tuner::profile::FrameworkConfig;

/// Real (PJRT) scheduler over one machine description.
pub struct RealScheduler<'a> {
    pub machine: Machine,
    pub client: &'a RtClient,
    pub manifest: &'a Manifest,
    /// Chunk launches performed (perf-pass counter).
    pub launches: u64,
    /// Adaptive chunk-selection knowledge, shared across requests.
    pub timings: crate::runtime::exec::TimingCache,
    /// Arguments used by timing-only [`ExecEnv::execute`] probes (the tuner
    /// drives real kernels, so it needs real buffers to feed them).
    pub tuning_args: RequestArgs,
}

/// Backwards-compatible name for the outputs+timing of one request.
pub type RealOutcome = RunOutcome;

impl<'a> RealScheduler<'a> {
    pub fn new(
        machine: Machine,
        client: &'a RtClient,
        manifest: &'a Manifest,
    ) -> RealScheduler<'a> {
        RealScheduler {
            machine,
            client,
            manifest,
            launches: 0,
            timings: Default::default(),
            tuning_args: RequestArgs::default(),
        }
    }

    fn sct_chunk_quantum(&self, sct: &Sct) -> u64 {
        sct.kernels()
            .iter()
            .filter_map(|k| self.manifest.chunk_quantum(&k.family).ok())
            .max()
            .unwrap_or(1)
    }

    /// Execute a request: returns merged outputs and per-slot wall times.
    pub fn run_request(
        &mut self,
        sct: &Sct,
        args: &RequestArgs,
        total_units: u64,
        cfg: &FrameworkConfig,
    ) -> Result<RunOutcome> {
        let quantum = self.sct_chunk_quantum(sct);
        let p = plan(&self.machine, sct, total_units, cfg, quantum)?;
        match sct {
            Sct::Loop { body, state } if state.global_sync => {
                // Stage 1-3 per iteration (Section 3.1): body on devices,
                // state update on the host with a global sync point.
                let mut local = args.clone();
                let mut outputs = Vec::new();
                let mut slot_acc: Vec<f64> = Vec::new();
                for it in 0..state.max_iters {
                    let (outs, times) = self.run_plan(body, &local, &p)?;
                    accumulate(&mut slot_acc, &times);
                    outputs = outs;
                    if let Some(update) = &state.update {
                        let mut vecs: Vec<ArgValue> =
                            local.vectors.iter().map(|v| v.value.clone()).collect();
                        let go = update(it, &mut vecs, &outputs);
                        for (v, nv) in local.vectors.iter_mut().zip(vecs) {
                            v.value = nv;
                        }
                        if !go {
                            break;
                        }
                    }
                }
                Ok(self.outcome(&p, outputs, slot_acc))
            }
            Sct::MapReduce { map, reduce } => {
                let (partials, times) = self.run_plan_partials(map, args, &p)?;
                let merged = match reduce {
                    Reduction::Host(m) => fold_partials(&partials, *m)?,
                    Reduction::HostFn(f) => {
                        let firsts: Vec<ArgValue> =
                            partials.iter().map(|p| p[0].clone()).collect();
                        vec![f(&firsts)]
                    }
                    Reduction::Device(_) => {
                        // Device reduction: reduce each partition's partial
                        // on-device (already folded into partials by the map
                        // tree), then fold across partitions on the host.
                        fold_partials(&partials, Merge::Add)?
                    }
                };
                Ok(self.outcome(&p, merged, times))
            }
            _ => {
                let (outs, times) = self.run_plan(sct, args, &p)?;
                Ok(self.outcome(&p, outs, times))
            }
        }
    }

    /// Run a (loop-free) tree over every partition; concat outputs in unit
    /// order. Returns (outputs, per-active-slot times).
    fn run_plan(
        &mut self,
        sct: &Sct,
        args: &RequestArgs,
        p: &PartitionPlan,
    ) -> Result<(Vec<ArgValue>, Vec<f64>)> {
        let (partials, times) = self.run_plan_partials(sct, args, p)?;
        let n_out = partials.first().map(|o| o.len()).unwrap_or(0);
        let mut outputs: Vec<Vec<f32>> = vec![Vec::new(); n_out];
        for part in &partials {
            for (o, val) in outputs.iter_mut().zip(part) {
                o.extend_from_slice(val.as_f32()?);
            }
        }
        Ok((outputs.into_iter().map(ArgValue::F32).collect(), times))
    }

    /// Run a tree over every partition; keep per-partition partials.
    fn run_plan_partials(
        &mut self,
        sct: &Sct,
        args: &RequestArgs,
        p: &PartitionPlan,
    ) -> Result<(Vec<Vec<ArgValue>>, Vec<f64>)> {
        let mut queues = WorkQueues::from_plan(p);
        let tasks = queues.drain_round_robin();
        let runner =
            ChunkRunner::new(self.client, self.manifest).with_timings(self.timings.clone());
        // seq -> partial, preserving unit order for the merge.
        let mut partials: Vec<(usize, Vec<ArgValue>)> = Vec::with_capacity(tasks.len());
        let mut times = Vec::with_capacity(tasks.len());
        for task in tasks {
            let start = Instant::now();
            let outs = runner.run_tree(
                sct,
                args,
                task.partition.start_unit,
                task.partition.units,
            )?;
            times.push(start.elapsed().as_secs_f64());
            partials.push((task.seq, outs));
        }
        self.launches += runner.launches.get();
        partials.sort_by_key(|(seq, _)| *seq);
        Ok((partials.into_iter().map(|(_, o)| o).collect(), times))
    }

    fn outcome(&self, p: &PartitionPlan, outputs: Vec<ArgValue>, times: Vec<f64>) -> RunOutcome {
        // Active partitions in plan order correspond 1:1 with `times` after
        // the seq sort; classify by slot type.
        let mut cpu_t = 0.0f64;
        let mut gpu_t = 0.0f64;
        for (part, &t) in p.active().zip(&times) {
            if part.slot.is_cpu() {
                cpu_t = cpu_t.max(t);
            } else {
                gpu_t = gpu_t.max(t);
            }
        }
        RunOutcome {
            outputs,
            exec: ExecOutcome {
                total: cpu_t.max(gpu_t),
                cpu_time: cpu_t,
                gpu_time: gpu_t,
                slot_times: times,
            },
        }
    }
}

impl<'a> ExecEnv for RealScheduler<'a> {
    fn machine(&self) -> &Machine {
        &self.machine
    }

    fn chunk_quantum(&self, sct: &Sct) -> u64 {
        self.sct_chunk_quantum(sct)
    }

    fn execute(
        &mut self,
        sct: &Sct,
        total_units: u64,
        cfg: &FrameworkConfig,
    ) -> Result<ExecOutcome> {
        let args = self.tuning_args.clone();
        Ok(RealScheduler::run_request(self, sct, &args, total_units, cfg)?.exec)
    }

    fn run_request(
        &mut self,
        sct: &Sct,
        args: &RequestArgs,
        total_units: u64,
        cfg: &FrameworkConfig,
    ) -> Result<RunOutcome> {
        RealScheduler::run_request(self, sct, args, total_units, cfg)
    }

    fn bind_tuning_args(&mut self, args: &RequestArgs) {
        self.tuning_args = args.clone();
    }

    fn launch_count(&self) -> u64 {
        self.launches
    }
}

fn accumulate(acc: &mut Vec<f64>, times: &[f64]) {
    if acc.len() < times.len() {
        acc.resize(times.len(), 0.0);
    }
    for (a, t) in acc.iter_mut().zip(times) {
        *a += t;
    }
}

fn fold_partials(partials: &[Vec<ArgValue>], m: Merge) -> Result<Vec<ArgValue>> {
    let first = partials
        .first()
        .ok_or_else(|| Error::Spec("no partials to reduce".into()))?;
    let mut out: Vec<Vec<f32>> = first
        .iter()
        .map(|v| v.as_f32().map(|s| s.to_vec()))
        .collect::<Result<_>>()?;
    for part in &partials[1..] {
        for (acc, val) in out.iter_mut().zip(part) {
            let v = val.as_f32()?;
            // Elementwise fold over the shorter length (partition partials
            // of reductions are same-shaped).
            let n = acc.len().min(v.len());
            for i in 0..n {
                acc[i] = m.fold(acc[i], v[i]);
            }
        }
    }
    Ok(out.into_iter().map(ArgValue::F32).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fold_partials_adds_elementwise() {
        let a = vec![ArgValue::F32(vec![1.0, 2.0])];
        let b = vec![ArgValue::F32(vec![10.0, 20.0])];
        let out = fold_partials(&[a, b], Merge::Add).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[11.0, 22.0]);
    }

    #[test]
    fn accumulate_grows() {
        let mut acc = Vec::new();
        accumulate(&mut acc, &[1.0, 2.0]);
        accumulate(&mut acc, &[0.5, 0.5, 3.0]);
        assert_eq!(acc, vec![1.5, 2.5, 3.0]);
    }
}
