//! Real-mode scheduler: orchestrates an execution request end-to-end on the
//! PJRT runtime — decomposition, per-slot work queues drained concurrently
//! by the work-stealing launcher, partial-result merging, host-side Loop
//! state updates and MapReduce reductions (Sections 3.1 and 3.4).
//!
//! `RealScheduler` implements the widened [`ExecEnv`] trait, so the session
//! facade, the tuner and the load balancer drive it exactly like the
//! simulated backend — timing-only probes use [`ExecEnv::execute`] with the
//! bound tuning arguments, full requests go through
//! [`ExecEnv::run_request`].
//!
//! Concurrency contract: every queue drains on its own scoped worker thread
//! ([`crate::scheduler::launcher`]). Where the PJRT binding demands
//! single-threaded access (the `pjrt` build), tasks serialize behind the
//! *client's* gate ([`RtClient::exclusive`] — per client, so any number of
//! schedulers sharing one client contend on the same lock); per-task busy
//! time is measured inside the gate, so the balance monitor sees pure
//! execution time, never lock waits. Queue semantics, stealing and
//! per-slot accounting are identical in both builds; the stub build runs
//! fully parallel.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

use crate::data::vector::{ArgValue, Merge};
use crate::decompose::graph::{
    build_graph, flatten_stages, NodeKind, StageOp, TaskGraph, TaskNode,
};
use crate::decompose::PartitionPlan;
use crate::error::{Error, Result};
use crate::platform::device::Machine;
use crate::runtime::artifacts::Manifest;
use crate::runtime::client::RtClient;
use crate::runtime::exec::{ChunkRunner, RequestArgs};
use crate::runtime::native::NativeEngine;
use crate::runtime::residency::{self, ArgKey, ResidencyKey, ResidencyPool, TransferStats};
use crate::scheduler::launcher::{
    launch_graph, launch_with, GraphRunner, LaunchOpts, SlotClock, StealPolicy, SyncOutcome,
    SyncVerdict, TaskOutput, TaskRunner,
};
use crate::scheduler::queues::{Task, WorkQueues};
use crate::scheduler::reservation::SlotMask;
use crate::scheduler::{plan, DrainMode, ExecEnv, ExecOutcome, RunOutcome};
use crate::sct::{Reduction, Sct};
use crate::tuner::profile::FrameworkConfig;

/// Real (PJRT) scheduler over one machine description.
pub struct RealScheduler<'a> {
    pub machine: Machine,
    pub client: &'a RtClient,
    pub manifest: &'a Manifest,
    /// Chunk launches performed (perf-pass counter).
    pub launches: u64,
    /// Adaptive chunk-selection knowledge, shared across requests.
    pub timings: crate::runtime::exec::TimingCache,
    /// Arguments used by timing-only [`ExecEnv::execute`] probes (the tuner
    /// drives real kernels, so it needs real buffers to feed them).
    pub tuning_args: RequestArgs,
    /// Stealable tasks generated per slot (finer tasks give idle slots
    /// something to steal when another slot falls behind). Configurable
    /// via [`ExecEnv::set_tasks_per_slot`] / `--tasks-per-slot`.
    pub tasks_per_slot: u32,
    /// Buffer residency: staged input ranges per slot, persisted across
    /// requests so repeated requests over the same workload skip the
    /// upload (DESIGN.md §2.6). Shared with every [`ChunkRunner`] this
    /// scheduler spawns and consulted by the steal policy.
    pub residency: Arc<ResidencyPool>,
    /// Drain mode (DESIGN.md §2.7): `Dataflow` (default) drains the
    /// request's dependency-driven task graph with cross-stage overlap;
    /// `Barrier` keeps the per-stage chunked-queue drain for A/B runs.
    pub drain_mode: DrainMode,
    /// Co-scheduling reservation (DESIGN.md §2.8): when set, requests are
    /// projected onto this device subset before planning, and the launcher
    /// spawns workers only for granted slots — stealing can never cross
    /// the reservation boundary.
    pub slot_mask: Option<SlotMask>,
    /// Native CPU kernel backend (DESIGN.md §2.11): when set, every
    /// [`ChunkRunner`] this scheduler spawns dispatches chunk launches to
    /// specialized compiled-in kernels under the request's tuned
    /// work-group size, and CPU workers pin to their slot's core.
    pub native: Option<Arc<NativeEngine>>,
    /// Graph-drain prefetch lookahead (DESIGN.md §2.12): parked workers
    /// stage inputs for up to this many upcoming nodes homed on their
    /// slot. 0 (default) disables prefetch; barrier drains ignore it.
    pub prefetch_depth: u32,
}

/// Backwards-compatible name for the outputs+timing of one request.
pub type RealOutcome = RunOutcome;

/// Default per-slot residency budget (bytes). Bounds the pool's staged
/// host copies under long request streams over varying datasets; LRU
/// eviction reclaims the coldest ranges (DESIGN.md §2.6).
pub const DEFAULT_RESIDENCY_CAPACITY: u64 = 256 << 20;

/// Per-slot engine handed to the launcher: one [`ChunkRunner`] shared by
/// every worker, serialized behind the client's gate in `pjrt` builds.
struct SlotTaskRunner<'r, 'a> {
    runner: &'r ChunkRunner<'a>,
    sct: &'r Sct,
    args: &'r RequestArgs,
}

impl<'r, 'a> TaskRunner for SlotTaskRunner<'r, 'a> {
    fn run_task(
        &self,
        slot: crate::decompose::ExecSlot,
        task: &Task,
    ) -> Result<TaskOutput> {
        let _exclusive = if cfg!(feature = "pjrt") {
            Some(self.runner.client.exclusive())
        } else {
            None
        };
        // Time inside the gate: the busy clock must hold pure execution
        // time — gate waits would make every slot look equally slow.
        // Residency is attributed to the slot *executing* the task: a
        // stolen task re-stages on the thief (its home ranges were
        // forfeited when the migration was booked).
        let start = Instant::now();
        let outputs = self.runner.run_tree_on(
            slot,
            self.sct,
            self.args,
            task.partition.start_unit,
            task.partition.units,
        )?;
        Ok(TaskOutput {
            outputs,
            busy: Some(start.elapsed().as_secs_f64()),
        })
    }
}

impl<'a> RealScheduler<'a> {
    pub fn new(
        machine: Machine,
        client: &'a RtClient,
        manifest: &'a Manifest,
    ) -> RealScheduler<'a> {
        RealScheduler {
            machine,
            client,
            manifest,
            launches: 0,
            timings: Default::default(),
            tuning_args: RequestArgs::default(),
            tasks_per_slot: 4,
            residency: Arc::new(
                ResidencyPool::new().with_capacity(DEFAULT_RESIDENCY_CAPACITY),
            ),
            drain_mode: DrainMode::default(),
            slot_mask: None,
            native: None,
            prefetch_depth: 0,
        }
    }

    /// Execute through the native CPU backend instead of PJRT/stub. The
    /// engine is shared (`Arc`) so sessions, pools and benches can reuse
    /// one specialization registry across schedulers.
    pub fn with_native(mut self, engine: Arc<NativeEngine>) -> Self {
        self.native = Some(engine);
        self
    }

    /// The native engine, when this scheduler runs the native backend.
    pub fn native_engine(&self) -> Option<&Arc<NativeEngine>> {
        self.native.as_ref()
    }

    /// The configuration a request actually runs under: the caller's,
    /// projected onto the installed reservation mask when one is set.
    fn masked_cfg(&self, cfg: &FrameworkConfig) -> FrameworkConfig {
        match &self.slot_mask {
            Some(m) => m.project(cfg),
            None => cfg.clone(),
        }
    }

    fn sct_chunk_quantum(&self, sct: &Sct) -> u64 {
        sct.kernels()
            .iter()
            .filter_map(|k| self.manifest.chunk_quantum(&k.family).ok())
            .max()
            .unwrap_or(1)
    }

    /// Fingerprint scoping this request's residency keys: two requests
    /// with different SCTs, domain sizes or argument data never alias in
    /// the pool; repeated requests over the same workload do — which is
    /// exactly what lets the second request skip the upload.
    fn request_id(&self, sct: &Sct, args: &RequestArgs, total_units: u64) -> u64 {
        let probes: Vec<u64> = args.vectors.iter().map(|v| v.value.probe()).collect();
        residency::request_fingerprint(&sct.id(), total_units, &probes)
    }

    /// The migration price per byte used by the locality-aware steal
    /// policy: the slowest host<->device link of the machine (PCIe of the
    /// weakest GPU; effectively free on CPU-only machines, where every
    /// slot shares host memory anyway).
    fn steal_secs_per_byte(&self) -> f64 {
        let gbps = self
            .machine
            .gpus
            .iter()
            .map(|g| g.pcie_gbps)
            .fold(f64::INFINITY, f64::min);
        if gbps.is_finite() && gbps > 0.0 {
            residency::migration_secs(1, gbps)
        } else {
            0.0
        }
    }

    /// Execute a request: returns merged outputs, per-slot wall times and
    /// the request's transfer accounting.
    pub fn run_request(
        &mut self,
        sct: &Sct,
        args: &RequestArgs,
        total_units: u64,
        cfg: &FrameworkConfig,
    ) -> Result<RunOutcome> {
        let quantum = self.sct_chunk_quantum(sct);
        let cfg = &self.masked_cfg(cfg);
        // The tuned work-group size rides to every ChunkRunner: it is the
        // native backend's specialization key (lane width, cache block).
        let wgs = cfg.wgs;
        let p = plan(&self.machine, sct, total_units, cfg, quantum)?;
        let request = self.request_id(sct, args, total_units);
        let before = self.residency.stats();
        let mut skipped = 0u64;
        if self.drain_mode == DrainMode::Dataflow {
            let (outputs, clock, skips) = self.run_graph(sct, args, &p, request, wgs)?;
            let mut out = self.outcome(outputs, clock);
            let mut transfers = self.residency.stats().minus(&before);
            transfers.steals_skipped = skips;
            out.exec.transfers = transfers;
            return Ok(out);
        }
        let out = match sct {
            Sct::Loop { body, state } if state.global_sync => {
                // Stage 1-3 per iteration (Section 3.1): body on devices,
                // state update on the host with a global sync point.
                let mut local = args.clone();
                let mut outputs = Vec::new();
                let mut clock = SlotClock::default();
                for it in 0..state.max_iters {
                    let (outs, it_clock, it_skips) =
                        self.run_plan(body, &local, &p, request, wgs)?;
                    clock.accumulate(&it_clock);
                    skipped += it_skips;
                    outputs = outs;
                    if let Some(update) = &state.update {
                        let mut vecs: Vec<ArgValue> =
                            local.vectors.iter().map(|v| v.value.clone()).collect();
                        let go = update(it, &mut vecs, &outputs);
                        for (i, (v, nv)) in local.vectors.iter_mut().zip(vecs).enumerate() {
                            // Only args the update actually rewrote lose
                            // their residency; untouched args keep it
                            // across iterations (the NBody reuse).
                            let changed = !v.value.same_contents(&nv);
                            v.value = nv;
                            if changed {
                                v.bump_version();
                                self.residency.invalidate_arg(ArgKey::Input {
                                    request,
                                    idx: i as u32,
                                });
                            }
                        }
                        if !go {
                            break;
                        }
                    }
                }
                self.outcome(outputs, clock)
            }
            Sct::MapReduce { map, reduce } => {
                // Reductions fold per-partition partials, so tasks stay at
                // partition granularity (no chunk splitting): splitting
                // would change the fold arity for order-sensitive merges.
                let queues = WorkQueues::from_plan(&p);
                let (partials, clock, skips) = self.drain(map, args, queues, request, wgs)?;
                skipped += skips;
                let merged = reduce_partials(reduce, &partials)?;
                self.outcome(merged, clock)
            }
            _ => {
                let (outs, clock, skips) = self.run_plan(sct, args, &p, request, wgs)?;
                skipped += skips;
                self.outcome(outs, clock)
            }
        };
        let mut out = out;
        let mut transfers = self.residency.stats().minus(&before);
        transfers.steals_skipped = skipped;
        out.exec.transfers = transfers;
        Ok(out)
    }

    /// Dataflow drain (DESIGN.md §2.7): flatten the request into its stage
    /// program, build the (stage × chunk) task graph, and drain it with
    /// dependency-driven scheduling — consumer chunks start the moment
    /// their producer chunk retires, and only sync nodes barrier. Returns
    /// (merged outputs, per-slot clocks, skipped steals).
    fn run_graph(
        &mut self,
        sct: &Sct,
        args: &RequestArgs,
        p: &PartitionPlan,
        request: u64,
        wgs: u32,
    ) -> Result<(Vec<ArgValue>, SlotClock, u64)> {
        let stages = flatten_stages(sct)?;
        let graph = build_graph(&stages, p, self.tasks_per_slot)?;
        let mut chunk_runner = ChunkRunner::new(self.client, self.manifest)
            .with_timings(self.timings.clone())
            .with_residency(self.residency.clone(), request);
        if let Some(engine) = &self.native {
            chunk_runner = chunk_runner.with_native(engine.clone(), wgs);
        }
        let runner = GraphTaskRunner {
            runner: &chunk_runner,
            stages: &stages,
            graph: &graph,
            args: RwLock::new(args.clone()),
            request,
            residency: self.residency.clone(),
            fold: Mutex::new(IncrementalFold::default()),
        };
        let out = launch_graph(
            &graph,
            &runner,
            LaunchOpts {
                policy: Some(StealPolicy {
                    residency: self.residency.as_ref(),
                    secs_per_byte: self.steal_secs_per_byte(),
                    default_task_secs: 1e-3,
                }),
                mask: self.slot_mask.clone(),
                pin_cores: self.native.is_some(),
                prefetch_depth: self.prefetch_depth,
            },
        );
        // Speculative uploads no task consumed (a Loop broke early, a
        // steal moved the consumer, the drain errored) must not leak into
        // the next request — drop them before propagating any failure.
        self.residency.clear_pending();
        let out = out?;
        self.launches += chunk_runner.launch_count();
        let outputs = match out.outputs {
            Some(o) => o,
            None => {
                // partials come back seq-sorted (unit order).
                let parts: Vec<Vec<ArgValue>> =
                    out.partials.into_iter().map(|(_, o)| o).collect();
                assemble_partials(&parts)?
            }
        };
        Ok((outputs, out.clock, out.steals_skipped))
    }

    /// Run a (loop-free) tree over every partition; concat outputs in unit
    /// order. Returns (outputs, per-slot clocks, skipped steals).
    fn run_plan(
        &mut self,
        sct: &Sct,
        args: &RequestArgs,
        p: &PartitionPlan,
        request: u64,
        wgs: u32,
    ) -> Result<(Vec<ArgValue>, SlotClock, u64)> {
        let queues = WorkQueues::from_plan_chunked(p, self.tasks_per_slot);
        let (partials, clock, skipped) = self.drain(sct, args, queues, request, wgs)?;
        Ok((assemble_partials(&partials)?, clock, skipped))
    }

    /// Drain prepared queues concurrently; partials come back seq-sorted
    /// (unit order), with per-slot busy clocks measured on the workers.
    /// Steals are priced against the scheduler's residency pool.
    fn drain(
        &mut self,
        sct: &Sct,
        args: &RequestArgs,
        queues: WorkQueues,
        request: u64,
        wgs: u32,
    ) -> Result<(Vec<Vec<ArgValue>>, SlotClock, u64)> {
        let mut runner = ChunkRunner::new(self.client, self.manifest)
            .with_timings(self.timings.clone())
            .with_residency(self.residency.clone(), request);
        if let Some(engine) = &self.native {
            runner = runner.with_native(engine.clone(), wgs);
        }
        let task_runner = SlotTaskRunner {
            runner: &runner,
            sct,
            args,
        };
        let out = launch_with(
            queues,
            &task_runner,
            LaunchOpts {
                policy: Some(StealPolicy {
                    residency: self.residency.as_ref(),
                    secs_per_byte: self.steal_secs_per_byte(),
                    // Before any completion, assume a task is worth a
                    // typical launch overhead — conservative enough that
                    // cold steals of resident data stay rare.
                    default_task_secs: 1e-3,
                }),
                mask: self.slot_mask.clone(),
                pin_cores: self.native.is_some(),
                // Barrier drains never park on dependencies, so there is
                // no compute window to hide an upload under.
                prefetch_depth: 0,
            },
        )?;
        self.launches += runner.launch_count();
        let clock = out.clock.clone();
        let skipped = out.steals_skipped;
        Ok((out.into_outputs(), clock, skipped))
    }

    fn outcome(&self, outputs: Vec<ArgValue>, clock: SlotClock) -> RunOutcome {
        let cpu_t = clock.cpu_time();
        let gpu_t = clock.gpu_time();
        RunOutcome {
            outputs,
            exec: ExecOutcome {
                // Wall time of the concurrent drain: the max over
                // overlapping slots (plus scheduling overhead), never the
                // serial sum the old single-thread launcher reported.
                total: clock.elapsed.max(cpu_t.max(gpu_t)),
                cpu_time: cpu_t,
                gpu_time: gpu_t,
                slot_times: clock.active_times(),
                transfers: TransferStats::default(),
            },
        }
    }
}

impl<'a> ExecEnv for RealScheduler<'a> {
    fn machine(&self) -> &Machine {
        &self.machine
    }

    /// Real measurements additionally depend on the compiled kernel set:
    /// fold the artifact manifest into the digest so profiles from
    /// different kernel builds (or from the analytic backend) never
    /// exchange as exact warm-start hits (DESIGN.md §2.9). Native-backend
    /// schedulers fold the engine fingerprint under a distinct label, so
    /// hardware-measured profiles never collide with stub/sim/pjrt ones
    /// — and scalar-reference timings never warm-start a vectorized
    /// fleet (DESIGN.md §2.11).
    fn manifest_digest(&self) -> String {
        let digest = match &self.native {
            Some(engine) => format!(
                "native\0{}\0{}\0{}",
                self.machine.manifest_json(),
                self.manifest.fingerprint_json(),
                engine.fingerprint()
            ),
            None => format!(
                "real\0{}\0{}",
                self.machine.manifest_json(),
                self.manifest.fingerprint_json()
            ),
        };
        crate::util::hash::sha256_hex(digest.as_bytes())
    }

    fn chunk_quantum(&self, sct: &Sct) -> u64 {
        self.sct_chunk_quantum(sct)
    }

    fn execute(
        &mut self,
        sct: &Sct,
        total_units: u64,
        cfg: &FrameworkConfig,
    ) -> Result<ExecOutcome> {
        let args = self.tuning_args.clone();
        Ok(RealScheduler::run_request(self, sct, &args, total_units, cfg)?.exec)
    }

    fn run_request(
        &mut self,
        sct: &Sct,
        args: &RequestArgs,
        total_units: u64,
        cfg: &FrameworkConfig,
    ) -> Result<RunOutcome> {
        RealScheduler::run_request(self, sct, args, total_units, cfg)
    }

    fn bind_tuning_args(&mut self, args: &RequestArgs) {
        self.tuning_args = args.clone();
    }

    fn launch_count(&self) -> u64 {
        self.launches
    }

    fn set_tasks_per_slot(&mut self, n: u32) {
        self.tasks_per_slot = n.max(1);
    }

    fn set_residency_enabled(&mut self, on: bool) {
        self.residency.set_enabled(on);
    }

    fn set_drain_mode(&mut self, mode: DrainMode) {
        self.drain_mode = mode;
    }

    fn set_prefetch_depth(&mut self, depth: u32) {
        self.prefetch_depth = depth;
    }

    fn set_slot_mask(&mut self, mask: Option<SlotMask>) {
        self.slot_mask = mask;
    }

    fn mask_migration_secs(&self, mask: &SlotMask) -> f64 {
        let secs_per_byte = self.steal_secs_per_byte();
        if secs_per_byte <= 0.0 {
            return 0.0;
        }
        // Data resident on a GPU the mask excludes must re-cross PCIe
        // before a masked request can use it elsewhere; host-side staging
        // (CPU sub-devices) moves for free.
        let bytes = self.residency.resident_bytes_where(|s| match s {
            crate::decompose::ExecSlot::GpuSlot { gpu, .. } => {
                !mask.allows_gpu(gpu as usize)
            }
            crate::decompose::ExecSlot::CpuSub { .. } => false,
        });
        bytes as f64 * secs_per_byte
    }
}

/// Concatenate unit-ordered chunk partials into whole-request outputs —
/// the single assembly both drains use, preallocated from the partials'
/// total size so appends never reallocate mid-copy, and bit-identical
/// across modes by construction.
fn assemble_partials(partials: &[Vec<ArgValue>]) -> Result<Vec<ArgValue>> {
    let n_out = partials.first().map(|o| o.len()).unwrap_or(0);
    let mut outputs: Vec<Vec<f32>> = (0..n_out)
        .map(|j| Vec::with_capacity(partials.iter().map(|part| part[j].len()).sum()))
        .collect();
    for part in partials {
        for (o, val) in outputs.iter_mut().zip(part) {
            o.extend_from_slice(val.as_f32()?);
        }
    }
    Ok(outputs.into_iter().map(ArgValue::F32).collect())
}

/// Fold one same-shaped partial into the accumulator — shared by the
/// barrier drain's end-of-stage fold and the dataflow drain's incremental
/// fold, so the two paths can never drift apart.
fn fold_into(acc: &mut [Vec<f32>], part: &[Vec<f32>], m: Merge, label: usize) -> Result<()> {
    if part.len() != acc.len() {
        return Err(Error::Spec(format!(
            "partial #{label} has {} outputs, expected {} — reduction \
             partials must be same-shaped",
            part.len(),
            acc.len()
        )));
    }
    for (oi, (a, v)) in acc.iter_mut().zip(part).enumerate() {
        if v.len() != a.len() {
            return Err(Error::Spec(format!(
                "partial #{label} output #{oi} has {} elements, expected {} \
                 — refusing to fold shape-mismatched partials",
                v.len(),
                a.len()
            )));
        }
        for i in 0..a.len() {
            a[i] = m.fold(a[i], v[i]);
        }
    }
    Ok(())
}

/// Order-preserving incremental reduction fold: partials fold the moment
/// they arrive, but strictly in seq order (out-of-order arrivals are
/// stashed), so the result is bit-identical to the barrier drain's
/// end-of-stage [`fold_partials`] — float folds are rounding-order
/// sensitive, and the two modes must agree to the bit.
#[derive(Default)]
struct IncrementalFold {
    next_seq: usize,
    acc: Option<Vec<Vec<f32>>>,
    stash: HashMap<usize, Vec<Vec<f32>>>,
}

impl IncrementalFold {
    fn absorb(&mut self, seq: usize, outputs: &[ArgValue], m: Merge) -> Result<()> {
        let conv: Vec<Vec<f32>> = outputs
            .iter()
            .map(|v| v.as_f32().map(|s| s.to_vec()))
            .collect::<Result<_>>()?;
        self.stash.insert(seq, conv);
        while let Some(part) = self.stash.remove(&self.next_seq) {
            match &mut self.acc {
                None => self.acc = Some(part),
                Some(acc) => fold_into(acc, &part, m, self.next_seq)?,
            }
            self.next_seq += 1;
        }
        Ok(())
    }

    fn take_result(&mut self) -> Result<Vec<ArgValue>> {
        if !self.stash.is_empty() {
            return Err(Error::Spec(
                "reduction fold is missing a partial (seq gap)".into(),
            ));
        }
        let acc = self
            .acc
            .take()
            .ok_or_else(|| Error::Spec("no partials to reduce".into()))?;
        self.next_seq = 0;
        Ok(acc.into_iter().map(ArgValue::F32).collect())
    }
}

/// The dataflow drain's engine: executes one stage subtree per node
/// through the shared [`ChunkRunner`], runs host sync points (Loop state
/// updates, reductions), pins produced intermediates in the residency pool
/// until their last consumer retires, and folds reduction partials
/// incrementally as sibling chunks complete.
struct GraphTaskRunner<'r, 'a, 's> {
    runner: &'r ChunkRunner<'a>,
    stages: &'r [StageOp<'s>],
    graph: &'r TaskGraph,
    /// Request arguments, host-updated by global-sync Loop nodes. Compute
    /// nodes hold the read lock while executing; a sync node's write can
    /// never deadlock because every reader is (transitively) one of its
    /// dependencies and has retired by the time the sync runs.
    args: RwLock<RequestArgs>,
    request: u64,
    residency: Arc<ResidencyPool>,
    fold: Mutex<IncrementalFold>,
}

impl GraphTaskRunner<'_, '_, '_> {
    fn stage_key(&self, node: &TaskNode) -> ResidencyKey {
        ResidencyKey {
            arg: ArgKey::Stage {
                request: self.request,
                stage: node.stage,
                out: 0,
            },
            start_unit: node.partition.start_unit,
            units: node.partition.units,
            version: 0,
        }
    }
}

impl GraphRunner for GraphTaskRunner<'_, '_, '_> {
    fn run_node(
        &self,
        slot: crate::decompose::ExecSlot,
        node: &TaskNode,
        carried: Option<&[ArgValue]>,
    ) -> Result<TaskOutput> {
        let (stage_sct, vec_off, scalar_off) = match &self.stages[node.stage as usize] {
            StageOp::Compute {
                sct,
                vec_off,
                scalar_off,
                ..
            } => (*sct, *vec_off, *scalar_off),
            _ => {
                return Err(Error::Spec(
                    "sync node dispatched to a compute worker".into(),
                ))
            }
        };
        let carried_val = carried.map(|c| c[0].clone());
        let _exclusive = if cfg!(feature = "pjrt") {
            Some(self.runner.client.exclusive())
        } else {
            None
        };
        // Busy time measured inside the gate (pure execution, no lock
        // waits); residency attributed to the slot *executing* the node.
        let start = Instant::now();
        let outputs = {
            let args = self.args.read().unwrap();
            self.runner.run_stage_on(
                slot,
                stage_sct,
                &args,
                carried_val,
                vec_off,
                scalar_off,
                node.partition.start_unit,
                node.partition.units,
            )?
        };
        let busy = start.elapsed().as_secs_f64();
        // Pin the produced intermediate for each consumer that will carry
        // it: the range stays device-resident (and visible to the steal
        // pricing) until the last consumer retires.
        let carried_consumers = self.graph.consumers[node.id]
            .iter()
            .filter(|&&c| self.graph.nodes[c].carried_from == Some(node.id))
            .count() as u32;
        if carried_consumers > 0 {
            let bytes = outputs.first().map(|o| o.len() as u64 * 4).unwrap_or(0);
            self.residency
                .pin_range(slot, self.stage_key(node), bytes, carried_consumers);
        }
        Ok(TaskOutput {
            outputs,
            busy: Some(busy),
        })
    }

    fn prefetch_node(&self, slot: crate::decompose::ExecSlot, node: &TaskNode) {
        // Stage request-vector inputs for an upcoming node homed on this
        // (parked) worker's slot — the upload runs under other slots'
        // compute (DESIGN.md §2.12). Best effort by contract: a failed
        // prefetch is swallowed, the node stages synchronously when it
        // runs. Carried-from bindings shift the cursor, so the flag must
        // match run_node's binding walk exactly.
        let (stage_sct, vec_off, scalar_off, carried) =
            match &self.stages[node.stage as usize] {
                StageOp::Compute {
                    sct,
                    vec_off,
                    scalar_off,
                    carried,
                } => (*sct, *vec_off, *scalar_off, *carried),
                _ => return,
            };
        let args = self.args.read().unwrap();
        let _ = self.runner.prefetch_stage_on(
            slot,
            stage_sct,
            &args,
            carried && node.carried_from.is_some(),
            vec_off,
            scalar_off,
            node.partition.start_unit,
            node.partition.units,
        );
    }

    fn absorb(&self, node: &TaskNode, outputs: &[ArgValue]) -> Result<bool> {
        // Only the direct producers of a foldable reduction absorb: their
        // partials fold as they complete instead of once at the fan-in.
        let reduce = match self.stages.get(node.stage as usize + 1) {
            Some(StageOp::Reduce { reduce }) => reduce,
            _ => return Ok(false),
        };
        let m = match reduce {
            Reduction::Host(m) => *m,
            Reduction::Device { combine, .. } => *combine,
            // Host functions need every partial at once, in order.
            Reduction::HostFn(_) => return Ok(false),
        };
        self.fold.lock().unwrap().absorb(node.seq, outputs, m)?;
        Ok(true)
    }

    fn run_sync(
        &self,
        node: &TaskNode,
        gathered: &[(usize, Arc<Vec<ArgValue>>)],
        is_sink: bool,
    ) -> Result<SyncOutcome> {
        match &self.stages[node.stage as usize] {
            StageOp::LoopSync { state, iter } => {
                // Stage 3 of the Loop (Section 3.1): concatenate the
                // iteration's body outputs, run the host update, bump the
                // versions of rewritten args (their residency is stale).
                let parts: Vec<Vec<ArgValue>> =
                    gathered.iter().map(|(_, o)| o.as_ref().clone()).collect();
                let outs = assemble_partials(&parts)?;
                let mut go = true;
                if let Some(update) = &state.update {
                    let mut local = self.args.write().unwrap();
                    let mut vecs: Vec<ArgValue> =
                        local.vectors.iter().map(|v| v.value.clone()).collect();
                    go = update(*iter, &mut vecs, &outs);
                    for (i, (v, nv)) in local.vectors.iter_mut().zip(vecs).enumerate() {
                        let changed = !v.value.same_contents(&nv);
                        v.value = nv;
                        if changed {
                            v.bump_version();
                            self.residency.invalidate_arg(ArgKey::Input {
                                request: self.request,
                                idx: i as u32,
                            });
                        }
                    }
                }
                let brk = !go;
                Ok(SyncOutcome {
                    verdict: if brk {
                        SyncVerdict::Break
                    } else {
                        SyncVerdict::Continue
                    },
                    // The request's outputs are this iteration's body
                    // outputs when the loop ends here (break or last
                    // iteration); otherwise they are transient.
                    outputs: if brk || is_sink { Some(outs) } else { None },
                })
            }
            StageOp::Reduce { reduce } => {
                let outs = match reduce {
                    Reduction::HostFn(f) => {
                        let firsts: Vec<ArgValue> =
                            gathered.iter().map(|(_, p)| p[0].clone()).collect();
                        vec![f(&firsts)]
                    }
                    Reduction::Host(_) | Reduction::Device { .. } => {
                        self.fold.lock().unwrap().take_result()?
                    }
                };
                Ok(SyncOutcome {
                    verdict: SyncVerdict::Continue,
                    outputs: Some(outs),
                })
            }
            StageOp::Compute { .. } => Err(Error::Spec(
                "compute node dispatched to the sync path".into(),
            )),
        }
    }

    fn retire_output(&self, node: &TaskNode) {
        if node.kind == NodeKind::Compute {
            self.residency.unpin(&self.stage_key(node));
        }
    }
}

/// Merge per-partition partials under the request's reduction.
fn reduce_partials(reduce: &Reduction, partials: &[Vec<ArgValue>]) -> Result<Vec<ArgValue>> {
    match reduce {
        Reduction::Host(m) => fold_partials(partials, *m),
        Reduction::HostFn(f) => {
            let firsts: Vec<ArgValue> = partials.iter().map(|p| p[0].clone()).collect();
            Ok(vec![f(&firsts)])
        }
        // Device reduction: each partition's partial is already folded
        // on-device by the map tree; partials combine across partitions
        // with the reduction's own merge operator.
        Reduction::Device { combine, .. } => fold_partials(partials, *combine),
    }
}

fn fold_partials(partials: &[Vec<ArgValue>], m: Merge) -> Result<Vec<ArgValue>> {
    let first = partials
        .first()
        .ok_or_else(|| Error::Spec("no partials to reduce".into()))?;
    let mut out: Vec<Vec<f32>> = first
        .iter()
        .map(|v| v.as_f32().map(|s| s.to_vec()))
        .collect::<Result<_>>()?;
    for (pi, part) in partials.iter().enumerate().skip(1) {
        let conv: Vec<Vec<f32>> = part
            .iter()
            .map(|v| v.as_f32().map(|s| s.to_vec()))
            .collect::<Result<_>>()?;
        fold_into(&mut out, &conv, m, pi)?;
    }
    Ok(out.into_iter().map(ArgValue::F32).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sct::{KernelSpec, ParamSpec};

    #[test]
    fn fold_partials_adds_elementwise() {
        let a = vec![ArgValue::F32(vec![1.0, 2.0])];
        let b = vec![ArgValue::F32(vec![10.0, 20.0])];
        let out = fold_partials(&[a, b], Merge::Add).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[11.0, 22.0]);
    }

    #[test]
    fn fold_partials_rejects_shape_mismatch() {
        // Historically the fold silently truncated to the shorter length,
        // producing a wrong (partially-merged) reduction.
        let a = vec![ArgValue::F32(vec![1.0, 2.0, 3.0])];
        let b = vec![ArgValue::F32(vec![10.0])];
        let err = fold_partials(&[a, b], Merge::Add).unwrap_err();
        assert!(format!("{err}").contains("shape-mismatched"));
        // Output-arity mismatch is rejected too.
        let a = vec![ArgValue::F32(vec![1.0]), ArgValue::F32(vec![2.0])];
        let b = vec![ArgValue::F32(vec![1.0])];
        assert!(fold_partials(&[a, b], Merge::Add).is_err());
    }

    #[test]
    fn device_reduction_folds_with_its_own_merge_op() {
        // A product-reduction kernel must combine partition partials with
        // Mul — the old code hard-coded Add for every Device reduction.
        let reduce = Reduction::device(
            KernelSpec::new("prod", vec![ParamSpec::VecIn], 1),
            Merge::Mul,
        );
        let partials = vec![
            vec![ArgValue::F32(vec![2.0, 3.0])],
            vec![ArgValue::F32(vec![4.0, 5.0])],
        ];
        let out = reduce_partials(&reduce, &partials).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[8.0, 15.0]);
    }

    #[test]
    fn incremental_fold_matches_barrier_fold_bitwise() {
        // Partials arrive out of order (the dataflow drain's completion
        // order), but the stash folds them strictly in seq order — the
        // result must equal the barrier drain's fold_partials to the bit
        // (float folds are rounding-order sensitive).
        let parts: Vec<Vec<ArgValue>> = (0..5)
            .map(|i| {
                vec![ArgValue::F32(vec![
                    0.1 * i as f32 + 0.333,
                    1.0 / (i as f32 + 1.0),
                ])]
            })
            .collect();
        let want = fold_partials(&parts, Merge::Add).unwrap();
        let mut f = IncrementalFold::default();
        for seq in [3usize, 0, 4, 1, 2] {
            f.absorb(seq, &parts[seq], Merge::Add).unwrap();
        }
        let got = f.take_result().unwrap();
        let bits = |v: &ArgValue| -> Vec<u32> {
            v.as_f32().unwrap().iter().map(|x| x.to_bits()).collect()
        };
        assert_eq!(bits(&got[0]), bits(&want[0]));
    }

    #[test]
    fn incremental_fold_rejects_gaps_and_shape_mismatch() {
        let mut f = IncrementalFold::default();
        f.absorb(1, &[ArgValue::F32(vec![1.0])], Merge::Add).unwrap();
        assert!(f.take_result().is_err(), "seq 0 never arrived");
        let mut f = IncrementalFold::default();
        f.absorb(0, &[ArgValue::F32(vec![1.0, 2.0])], Merge::Add)
            .unwrap();
        let err = f
            .absorb(1, &[ArgValue::F32(vec![1.0])], Merge::Add)
            .unwrap_err();
        assert!(format!("{err}").contains("shape-mismatched"));
    }

    #[test]
    fn host_fn_reduction_receives_every_partial() {
        use std::sync::Arc;
        let reduce = Reduction::HostFn(Arc::new(|firsts: &[ArgValue]| {
            let sum: f32 = firsts
                .iter()
                .map(|v| v.as_f32().unwrap().iter().sum::<f32>())
                .sum();
            ArgValue::F32(vec![sum])
        }));
        let partials = vec![
            vec![ArgValue::F32(vec![1.0, 2.0])],
            vec![ArgValue::F32(vec![3.0])],
        ];
        let out = reduce_partials(&reduce, &partials).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[6.0]);
    }
}
