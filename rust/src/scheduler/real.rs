//! Real-mode scheduler: orchestrates an execution request end-to-end on the
//! PJRT runtime — decomposition, per-slot work queues drained concurrently
//! by the work-stealing launcher, partial-result merging, host-side Loop
//! state updates and MapReduce reductions (Sections 3.1 and 3.4).
//!
//! `RealScheduler` implements the widened [`ExecEnv`] trait, so the session
//! facade, the tuner and the load balancer drive it exactly like the
//! simulated backend — timing-only probes use [`ExecEnv::execute`] with the
//! bound tuning arguments, full requests go through
//! [`ExecEnv::run_request`].
//!
//! Concurrency contract: every queue drains on its own scoped worker thread
//! ([`crate::scheduler::launcher`]). Where the PJRT binding demands
//! single-threaded access (the `pjrt` build), tasks serialize behind the
//! *client's* gate ([`RtClient::exclusive`] — per client, so any number of
//! schedulers sharing one client contend on the same lock); per-task busy
//! time is measured inside the gate, so the balance monitor sees pure
//! execution time, never lock waits. Queue semantics, stealing and
//! per-slot accounting are identical in both builds; the stub build runs
//! fully parallel.

use std::sync::Arc;
use std::time::Instant;

use crate::data::vector::{ArgValue, Merge};
use crate::decompose::PartitionPlan;
use crate::error::{Error, Result};
use crate::platform::device::Machine;
use crate::runtime::artifacts::Manifest;
use crate::runtime::client::RtClient;
use crate::runtime::exec::{ChunkRunner, RequestArgs};
use crate::runtime::residency::{self, ArgKey, ResidencyPool, TransferStats};
use crate::scheduler::launcher::{
    launch_with, LaunchOpts, SlotClock, StealPolicy, TaskOutput, TaskRunner,
};
use crate::scheduler::queues::{Task, WorkQueues};
use crate::scheduler::{plan, ExecEnv, ExecOutcome, RunOutcome};
use crate::sct::{Reduction, Sct};
use crate::tuner::profile::FrameworkConfig;

/// Real (PJRT) scheduler over one machine description.
pub struct RealScheduler<'a> {
    pub machine: Machine,
    pub client: &'a RtClient,
    pub manifest: &'a Manifest,
    /// Chunk launches performed (perf-pass counter).
    pub launches: u64,
    /// Adaptive chunk-selection knowledge, shared across requests.
    pub timings: crate::runtime::exec::TimingCache,
    /// Arguments used by timing-only [`ExecEnv::execute`] probes (the tuner
    /// drives real kernels, so it needs real buffers to feed them).
    pub tuning_args: RequestArgs,
    /// Stealable tasks generated per slot (finer tasks give idle slots
    /// something to steal when another slot falls behind). Configurable
    /// via [`ExecEnv::set_tasks_per_slot`] / `--tasks-per-slot`.
    pub tasks_per_slot: u32,
    /// Buffer residency: staged input ranges per slot, persisted across
    /// requests so repeated requests over the same workload skip the
    /// upload (DESIGN.md §2.6). Shared with every [`ChunkRunner`] this
    /// scheduler spawns and consulted by the steal policy.
    pub residency: Arc<ResidencyPool>,
}

/// Backwards-compatible name for the outputs+timing of one request.
pub type RealOutcome = RunOutcome;

/// Default per-slot residency budget (bytes). Bounds the pool's staged
/// host copies under long request streams over varying datasets; LRU
/// eviction reclaims the coldest ranges (DESIGN.md §2.6).
pub const DEFAULT_RESIDENCY_CAPACITY: u64 = 256 << 20;

/// Per-slot engine handed to the launcher: one [`ChunkRunner`] shared by
/// every worker, serialized behind the client's gate in `pjrt` builds.
struct SlotTaskRunner<'r, 'a> {
    runner: &'r ChunkRunner<'a>,
    sct: &'r Sct,
    args: &'r RequestArgs,
}

impl<'r, 'a> TaskRunner for SlotTaskRunner<'r, 'a> {
    fn run_task(
        &self,
        slot: crate::decompose::ExecSlot,
        task: &Task,
    ) -> Result<TaskOutput> {
        let _exclusive = if cfg!(feature = "pjrt") {
            Some(self.runner.client.exclusive())
        } else {
            None
        };
        // Time inside the gate: the busy clock must hold pure execution
        // time — gate waits would make every slot look equally slow.
        // Residency is attributed to the slot *executing* the task: a
        // stolen task re-stages on the thief (its home ranges were
        // forfeited when the migration was booked).
        let start = Instant::now();
        let outputs = self.runner.run_tree_on(
            slot,
            self.sct,
            self.args,
            task.partition.start_unit,
            task.partition.units,
        )?;
        Ok(TaskOutput {
            outputs,
            busy: Some(start.elapsed().as_secs_f64()),
        })
    }
}

impl<'a> RealScheduler<'a> {
    pub fn new(
        machine: Machine,
        client: &'a RtClient,
        manifest: &'a Manifest,
    ) -> RealScheduler<'a> {
        RealScheduler {
            machine,
            client,
            manifest,
            launches: 0,
            timings: Default::default(),
            tuning_args: RequestArgs::default(),
            tasks_per_slot: 4,
            residency: Arc::new(
                ResidencyPool::new().with_capacity(DEFAULT_RESIDENCY_CAPACITY),
            ),
        }
    }

    fn sct_chunk_quantum(&self, sct: &Sct) -> u64 {
        sct.kernels()
            .iter()
            .filter_map(|k| self.manifest.chunk_quantum(&k.family).ok())
            .max()
            .unwrap_or(1)
    }

    /// Fingerprint scoping this request's residency keys: two requests
    /// with different SCTs, domain sizes or argument data never alias in
    /// the pool; repeated requests over the same workload do — which is
    /// exactly what lets the second request skip the upload.
    fn request_id(&self, sct: &Sct, args: &RequestArgs, total_units: u64) -> u64 {
        let probes: Vec<u64> = args.vectors.iter().map(|v| v.value.probe()).collect();
        residency::request_fingerprint(&sct.id(), total_units, &probes)
    }

    /// The migration price per byte used by the locality-aware steal
    /// policy: the slowest host<->device link of the machine (PCIe of the
    /// weakest GPU; effectively free on CPU-only machines, where every
    /// slot shares host memory anyway).
    fn steal_secs_per_byte(&self) -> f64 {
        let gbps = self
            .machine
            .gpus
            .iter()
            .map(|g| g.pcie_gbps)
            .fold(f64::INFINITY, f64::min);
        if gbps.is_finite() && gbps > 0.0 {
            residency::migration_secs(1, gbps)
        } else {
            0.0
        }
    }

    /// Execute a request: returns merged outputs, per-slot wall times and
    /// the request's transfer accounting.
    pub fn run_request(
        &mut self,
        sct: &Sct,
        args: &RequestArgs,
        total_units: u64,
        cfg: &FrameworkConfig,
    ) -> Result<RunOutcome> {
        let quantum = self.sct_chunk_quantum(sct);
        let p = plan(&self.machine, sct, total_units, cfg, quantum)?;
        let request = self.request_id(sct, args, total_units);
        let before = self.residency.stats();
        let mut skipped = 0u64;
        let out = match sct {
            Sct::Loop { body, state } if state.global_sync => {
                // Stage 1-3 per iteration (Section 3.1): body on devices,
                // state update on the host with a global sync point.
                let mut local = args.clone();
                let mut outputs = Vec::new();
                let mut clock = SlotClock::default();
                for it in 0..state.max_iters {
                    let (outs, it_clock, it_skips) =
                        self.run_plan(body, &local, &p, request)?;
                    clock.accumulate(&it_clock);
                    skipped += it_skips;
                    outputs = outs;
                    if let Some(update) = &state.update {
                        let mut vecs: Vec<ArgValue> =
                            local.vectors.iter().map(|v| v.value.clone()).collect();
                        let go = update(it, &mut vecs, &outputs);
                        for (i, (v, nv)) in local.vectors.iter_mut().zip(vecs).enumerate() {
                            // Only args the update actually rewrote lose
                            // their residency; untouched args keep it
                            // across iterations (the NBody reuse).
                            let changed = !v.value.same_contents(&nv);
                            v.value = nv;
                            if changed {
                                v.bump_version();
                                self.residency.invalidate_arg(ArgKey::Input {
                                    request,
                                    idx: i as u32,
                                });
                            }
                        }
                        if !go {
                            break;
                        }
                    }
                }
                self.outcome(outputs, clock)
            }
            Sct::MapReduce { map, reduce } => {
                // Reductions fold per-partition partials, so tasks stay at
                // partition granularity (no chunk splitting): splitting
                // would change the fold arity for order-sensitive merges.
                let queues = WorkQueues::from_plan(&p);
                let (partials, clock, skips) = self.drain(map, args, queues, request)?;
                skipped += skips;
                let merged = reduce_partials(reduce, &partials)?;
                self.outcome(merged, clock)
            }
            _ => {
                let (outs, clock, skips) = self.run_plan(sct, args, &p, request)?;
                skipped += skips;
                self.outcome(outs, clock)
            }
        };
        let mut out = out;
        let mut transfers = self.residency.stats().minus(&before);
        transfers.steals_skipped = skipped;
        out.exec.transfers = transfers;
        Ok(out)
    }

    /// Run a (loop-free) tree over every partition; concat outputs in unit
    /// order. Returns (outputs, per-slot clocks, skipped steals).
    fn run_plan(
        &mut self,
        sct: &Sct,
        args: &RequestArgs,
        p: &PartitionPlan,
        request: u64,
    ) -> Result<(Vec<ArgValue>, SlotClock, u64)> {
        let queues = WorkQueues::from_plan_chunked(p, self.tasks_per_slot);
        let (partials, clock, skipped) = self.drain(sct, args, queues, request)?;
        let n_out = partials.first().map(|o| o.len()).unwrap_or(0);
        // Preallocate each concatenated output from the partials' total
        // size — merging never reallocates mid-copy.
        let mut outputs: Vec<Vec<f32>> = (0..n_out)
            .map(|j| {
                Vec::with_capacity(partials.iter().map(|part| part[j].len()).sum())
            })
            .collect();
        for part in &partials {
            for (o, val) in outputs.iter_mut().zip(part) {
                o.extend_from_slice(val.as_f32()?);
            }
        }
        Ok((
            outputs.into_iter().map(ArgValue::F32).collect(),
            clock,
            skipped,
        ))
    }

    /// Drain prepared queues concurrently; partials come back seq-sorted
    /// (unit order), with per-slot busy clocks measured on the workers.
    /// Steals are priced against the scheduler's residency pool.
    fn drain(
        &mut self,
        sct: &Sct,
        args: &RequestArgs,
        queues: WorkQueues,
        request: u64,
    ) -> Result<(Vec<Vec<ArgValue>>, SlotClock, u64)> {
        let runner = ChunkRunner::new(self.client, self.manifest)
            .with_timings(self.timings.clone())
            .with_residency(self.residency.clone(), request);
        let task_runner = SlotTaskRunner {
            runner: &runner,
            sct,
            args,
        };
        let out = launch_with(
            queues,
            &task_runner,
            LaunchOpts {
                policy: Some(StealPolicy {
                    residency: self.residency.as_ref(),
                    secs_per_byte: self.steal_secs_per_byte(),
                    // Before any completion, assume a task is worth a
                    // typical launch overhead — conservative enough that
                    // cold steals of resident data stay rare.
                    default_task_secs: 1e-3,
                }),
            },
        )?;
        self.launches += runner.launch_count();
        let clock = out.clock.clone();
        let skipped = out.steals_skipped;
        Ok((out.into_outputs(), clock, skipped))
    }

    fn outcome(&self, outputs: Vec<ArgValue>, clock: SlotClock) -> RunOutcome {
        let cpu_t = clock.cpu_time();
        let gpu_t = clock.gpu_time();
        RunOutcome {
            outputs,
            exec: ExecOutcome {
                // Wall time of the concurrent drain: the max over
                // overlapping slots (plus scheduling overhead), never the
                // serial sum the old single-thread launcher reported.
                total: clock.elapsed.max(cpu_t.max(gpu_t)),
                cpu_time: cpu_t,
                gpu_time: gpu_t,
                slot_times: clock.active_times(),
                transfers: TransferStats::default(),
            },
        }
    }
}

impl<'a> ExecEnv for RealScheduler<'a> {
    fn machine(&self) -> &Machine {
        &self.machine
    }

    fn chunk_quantum(&self, sct: &Sct) -> u64 {
        self.sct_chunk_quantum(sct)
    }

    fn execute(
        &mut self,
        sct: &Sct,
        total_units: u64,
        cfg: &FrameworkConfig,
    ) -> Result<ExecOutcome> {
        let args = self.tuning_args.clone();
        Ok(RealScheduler::run_request(self, sct, &args, total_units, cfg)?.exec)
    }

    fn run_request(
        &mut self,
        sct: &Sct,
        args: &RequestArgs,
        total_units: u64,
        cfg: &FrameworkConfig,
    ) -> Result<RunOutcome> {
        RealScheduler::run_request(self, sct, args, total_units, cfg)
    }

    fn bind_tuning_args(&mut self, args: &RequestArgs) {
        self.tuning_args = args.clone();
    }

    fn launch_count(&self) -> u64 {
        self.launches
    }

    fn set_tasks_per_slot(&mut self, n: u32) {
        self.tasks_per_slot = n.max(1);
    }

    fn set_residency_enabled(&mut self, on: bool) {
        self.residency.set_enabled(on);
    }
}

/// Merge per-partition partials under the request's reduction.
fn reduce_partials(reduce: &Reduction, partials: &[Vec<ArgValue>]) -> Result<Vec<ArgValue>> {
    match reduce {
        Reduction::Host(m) => fold_partials(partials, *m),
        Reduction::HostFn(f) => {
            let firsts: Vec<ArgValue> = partials.iter().map(|p| p[0].clone()).collect();
            Ok(vec![f(&firsts)])
        }
        // Device reduction: each partition's partial is already folded
        // on-device by the map tree; partials combine across partitions
        // with the reduction's own merge operator.
        Reduction::Device { combine, .. } => fold_partials(partials, *combine),
    }
}

fn fold_partials(partials: &[Vec<ArgValue>], m: Merge) -> Result<Vec<ArgValue>> {
    let first = partials
        .first()
        .ok_or_else(|| Error::Spec("no partials to reduce".into()))?;
    let mut out: Vec<Vec<f32>> = first
        .iter()
        .map(|v| v.as_f32().map(|s| s.to_vec()))
        .collect::<Result<_>>()?;
    for (pi, part) in partials.iter().enumerate().skip(1) {
        if part.len() != out.len() {
            return Err(Error::Spec(format!(
                "partial #{pi} has {} outputs, expected {} — reduction \
                 partials must be same-shaped",
                part.len(),
                out.len()
            )));
        }
        for (oi, (acc, val)) in out.iter_mut().zip(part).enumerate() {
            let v = val.as_f32()?;
            if v.len() != acc.len() {
                return Err(Error::Spec(format!(
                    "partial #{pi} output #{oi} has {} elements, expected {} \
                     — refusing to fold shape-mismatched partials",
                    v.len(),
                    acc.len()
                )));
            }
            for i in 0..acc.len() {
                acc[i] = m.fold(acc[i], v[i]);
            }
        }
    }
    Ok(out.into_iter().map(ArgValue::F32).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sct::{KernelSpec, ParamSpec};

    #[test]
    fn fold_partials_adds_elementwise() {
        let a = vec![ArgValue::F32(vec![1.0, 2.0])];
        let b = vec![ArgValue::F32(vec![10.0, 20.0])];
        let out = fold_partials(&[a, b], Merge::Add).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[11.0, 22.0]);
    }

    #[test]
    fn fold_partials_rejects_shape_mismatch() {
        // Historically the fold silently truncated to the shorter length,
        // producing a wrong (partially-merged) reduction.
        let a = vec![ArgValue::F32(vec![1.0, 2.0, 3.0])];
        let b = vec![ArgValue::F32(vec![10.0])];
        let err = fold_partials(&[a, b], Merge::Add).unwrap_err();
        assert!(format!("{err}").contains("shape-mismatched"));
        // Output-arity mismatch is rejected too.
        let a = vec![ArgValue::F32(vec![1.0]), ArgValue::F32(vec![2.0])];
        let b = vec![ArgValue::F32(vec![1.0])];
        assert!(fold_partials(&[a, b], Merge::Add).is_err());
    }

    #[test]
    fn device_reduction_folds_with_its_own_merge_op() {
        // A product-reduction kernel must combine partition partials with
        // Mul — the old code hard-coded Add for every Device reduction.
        let reduce = Reduction::device(
            KernelSpec::new("prod", vec![ParamSpec::VecIn], 1),
            Merge::Mul,
        );
        let partials = vec![
            vec![ArgValue::F32(vec![2.0, 3.0])],
            vec![ArgValue::F32(vec![4.0, 5.0])],
        ];
        let out = reduce_partials(&reduce, &partials).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[8.0, 15.0]);
    }

    #[test]
    fn host_fn_reduction_receives_every_partial() {
        use std::sync::Arc;
        let reduce = Reduction::HostFn(Arc::new(|firsts: &[ArgValue]| {
            let sum: f32 = firsts
                .iter()
                .map(|v| v.as_f32().unwrap().iter().sum::<f32>())
                .sum();
            ArgValue::F32(vec![sum])
        }));
        let partials = vec![
            vec![ArgValue::F32(vec![1.0, 2.0])],
            vec![ArgValue::F32(vec![3.0])],
        ];
        let out = reduce_partials(&reduce, &partials).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[6.0]);
    }
}
