//! The Scheduler + Task Launcher (Section 2.2): distributes an SCT
//! execution among the selected hardware, generating stealable tasks per
//! parallel execution slot, placed in per-slot work queues ([`queues`])
//! drained concurrently by the work-stealing launcher ([`launcher`]) — one
//! worker thread per slot, idle slots stealing from the back of the
//! longest queue.
//!
//! Two execution environments implement [`ExecEnv`]:
//!  * [`SimEnv`] — prices executions with the analytic cost model
//!    ([`crate::sim`]); used by the paper-scale benches and by the tuner.
//!  * [`real::RealScheduler`] — executes partitions on the PJRT client with
//!    real numerics and wall-clock times.
//!
//! Both sit behind the same widened trait, so the [`crate::session`] facade,
//! the tuner and the load balancer drive either backend interchangeably.

pub mod launcher;
pub mod queues;
pub mod real;
pub mod reservation;

use crate::data::vector::ArgValue;
use crate::decompose::{decompose, DecomposeConfig, PartitionPlan};
use crate::error::Result;
use crate::platform::cpu::CpuPlatform;
use crate::platform::device::Machine;
use crate::platform::occupancy;
use crate::runtime::exec::RequestArgs;
use crate::runtime::residency::{self, ArgKey, ResidencyKey, ResidencyPool, TransferStats};
use crate::sct::Sct;
use crate::sim::cost::SctCost;
use crate::sim::machine::SimMachine;
use crate::tuner::profile::FrameworkConfig;

pub use launcher::{
    launch, launch_graph, launch_with, GraphOutput, GraphRunner, LaunchOpts, LaunchOutput,
    SlotClock, StealPolicy, SyncOutcome, SyncVerdict, TaskRunner,
};
pub use queues::{ReadyQueues, SharedQueues, Task, WorkQueues};
pub use reservation::{
    candidate_masks, ReservationGuard, SlotMask, SlotReservations, VirtualTimeline,
};

/// How an execution request drains its tasks (DESIGN.md §2.7).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DrainMode {
    /// Every stage of the request runs to a global barrier before the next
    /// stage starts — the pre-dataflow behavior, kept as the A/B baseline
    /// and for order-sensitive debugging.
    Barrier,
    /// Dependency-driven task graph: a consumer chunk starts as soon as the
    /// producer chunks covering its unit range retire; only global-sync
    /// points (Loop condition reductions, MapReduce fan-ins) barrier.
    #[default]
    Dataflow,
}

impl DrainMode {
    pub fn label(&self) -> &'static str {
        match self {
            DrainMode::Barrier => "barrier",
            DrainMode::Dataflow => "dataflow",
        }
    }

    /// Parse a CLI spelling.
    pub fn parse(s: &str) -> Option<DrainMode> {
        match s {
            "barrier" => Some(DrainMode::Barrier),
            "dataflow" => Some(DrainMode::Dataflow),
            _ => None,
        }
    }
}

/// Result of one SCT execution request, as seen by the adaptation layer.
#[derive(Clone, Debug)]
pub struct ExecOutcome {
    /// Completion time (seconds — virtual in Sim, wall in Real).
    pub total: f64,
    /// Per-device-type completion times.
    pub cpu_time: f64,
    pub gpu_time: f64,
    /// Per-slot *busy* times of every active parallel execution, summed
    /// over the whole request (never per-stage — the monitor must not
    /// mistake a short unbalanced stage for a load spike).
    pub slot_times: Vec<f64>,
    /// Transfer accounting of this request (uploads, reuses, migrations)
    /// from the buffer-residency layer (DESIGN.md §2.6). Both backends
    /// fill it: Real from the chunk runner's pool, Sim from the priced
    /// model, so the two agree in shape.
    pub transfers: TransferStats,
}

impl ExecOutcome {
    /// Idle seconds per active slot: wall clock minus the slot's busy time
    /// (the overlap win dataflow draining buys is visible exactly here).
    pub fn slot_idle(&self) -> Vec<f64> {
        self.slot_times
            .iter()
            .map(|&busy| (self.total - busy).max(0.0))
            .collect()
    }

    /// Mean idle fraction over the active slots (0 = perfectly packed,
    /// 1 = slots idled the whole request).
    pub fn mean_idle_frac(&self) -> f64 {
        if self.total <= 0.0 || self.slot_times.is_empty() {
            return 0.0;
        }
        let sum: f64 = self
            .slot_idle()
            .iter()
            .map(|&idle| idle / self.total)
            .sum();
        sum / self.slot_times.len() as f64
    }

    /// Completion time of several requests drained as one fused batch
    /// (DESIGN.md §2.10): each device type serves every member's work for
    /// that device back to back while the other type runs concurrently, so
    /// the fused makespan is the busiest device's summed load — the same
    /// aggregate rule the dataflow drain prices a single request by,
    /// applied across members. Never below the longest member (fusion
    /// cannot speed a request up in isolation), and never above the
    /// serialized sum of totals (each member's own makespan already covers
    /// both device types).
    pub fn fused_total(members: &[&ExecOutcome]) -> f64 {
        let cpu: f64 = members.iter().map(|m| m.cpu_time).sum();
        let gpu: f64 = members.iter().map(|m| m.gpu_time).sum();
        let longest = members.iter().map(|m| m.total).fold(0.0, f64::max);
        cpu.max(gpu).max(longest)
    }
}

/// Outputs + timing of one full execution request. Timing-only backends
/// (the simulator) return empty `outputs`.
#[derive(Clone, Debug)]
pub struct RunOutcome {
    pub outputs: Vec<ArgValue>,
    pub exec: ExecOutcome,
}

/// An execution environment the session facade, tuner and balancer drive.
///
/// The trait covers both halves of the paper's runtime: timing-only
/// executions ([`ExecEnv::execute`], what Algorithm 1 and the adaptive
/// binary search observe) and full data-carrying requests
/// ([`ExecEnv::run_request`], what user computations go through).
pub trait ExecEnv {
    fn machine(&self) -> &Machine;

    /// Content digest of the execution platform this backend's learned
    /// profiles describe (DESIGN.md §2.9): KB-store records carry it, and
    /// imported profiles are exact warm-start hits only when digests
    /// match. The default covers analytic backends — a hash of the
    /// machine manifest under the "analytic" kind tag; real backends
    /// override to fold in their kernel-artifact manifest, so simulated
    /// and measured profiles never mix.
    fn manifest_digest(&self) -> String {
        crate::kb::store::machine_digest("analytic", self.machine())
    }

    /// Decomposition quantum contributed by the AOT chunk menu for this SCT
    /// (1 when everything is simulated).
    fn chunk_quantum(&self, sct: &Sct) -> u64;

    /// Execute the SCT over `total_units` under `cfg`, returning timings.
    fn execute(
        &mut self,
        sct: &Sct,
        total_units: u64,
        cfg: &FrameworkConfig,
    ) -> Result<ExecOutcome>;

    /// Execute a full request: decomposition, per-slot queues, chunked
    /// execution and partial-result merging. The default covers analytic
    /// backends — timings from [`ExecEnv::execute`], no output buffers.
    fn run_request(
        &mut self,
        sct: &Sct,
        args: &RequestArgs,
        total_units: u64,
        cfg: &FrameworkConfig,
    ) -> Result<RunOutcome> {
        let _ = args;
        Ok(RunOutcome {
            outputs: Vec::new(),
            exec: self.execute(sct, total_units, cfg)?,
        })
    }

    /// Bind the arguments timing-only executions should use (real backends
    /// need data to run the tuner's probes; analytic backends ignore it).
    fn bind_tuning_args(&mut self, args: &RequestArgs) {
        let _ = args;
    }

    /// Per-request cost hint: COPY-mode bytes replicated to every device
    /// (consumed by analytic backends; a no-op on real hardware).
    fn set_copy_bytes(&mut self, bytes: f64) {
        let _ = bytes;
    }

    /// Cumulative kernel-launch count (0 for backends that don't launch).
    fn launch_count(&self) -> u64 {
        0
    }

    /// Stealable tasks generated per execution slot (the steal-slack knob;
    /// backends without work queues ignore it).
    fn set_tasks_per_slot(&mut self, n: u32) {
        let _ = n;
    }

    /// Toggle the buffer-residency layer (on by default; the off state is
    /// the A/B baseline for the locality benches).
    fn set_residency_enabled(&mut self, on: bool) {
        let _ = on;
    }

    /// Select the drain mode (default [`DrainMode::Dataflow`]; backends
    /// without a stage structure ignore it).
    fn set_drain_mode(&mut self, mode: DrainMode) {
        let _ = mode;
    }

    /// Graph-drain prefetch lookahead (DESIGN.md §2.12): parked workers
    /// stage inputs for up to `depth` upcoming nodes homed on their slot,
    /// hiding uploads under other slots' compute. 0 (the default)
    /// disables prefetch; barrier drains and backends without a graph
    /// structure ignore it.
    fn set_prefetch_depth(&mut self, depth: u32) {
        let _ = depth;
    }

    /// Restrict every subsequent request to a device-space subset of the
    /// machine (DESIGN.md §2.8): configurations are projected onto the
    /// mask, excluded devices receive no work, and stealing never crosses
    /// the boundary. `None` restores the whole machine. Backends without a
    /// slot structure ignore it.
    fn set_slot_mask(&mut self, mask: Option<SlotMask>) {
        let _ = mask;
    }

    /// Estimated seconds to migrate this backend's device-resident data
    /// off the devices `mask` excludes (the residency term of the
    /// admission price — data parked on an excluded GPU must re-cross
    /// PCIe before a masked request can use it elsewhere). 0 for backends
    /// without a residency pool.
    fn mask_migration_secs(&self, mask: &SlotMask) -> f64 {
        let _ = mask;
        0.0
    }
}

/// Build the decomposition config for a framework configuration.
pub fn decompose_config(
    machine: &Machine,
    cfg: &FrameworkConfig,
    chunk_quantum: u64,
) -> DecomposeConfig {
    let cpu = CpuPlatform::new(machine.cpu.clone());
    // A GPU with no overlap slots (masked out by a reservation projection,
    // DESIGN.md §2.8) can hold no units: zero its weight and renormalize
    // the rest, or the decomposer would route units to a slotless device.
    let mut gpu_weights = machine.gpu_weights();
    for (g, w) in gpu_weights.iter_mut().enumerate() {
        if cfg.overlap.get(g).copied().unwrap_or(0) == 0 {
            *w = 0.0;
        }
    }
    let total: f64 = gpu_weights.iter().sum();
    if total > 0.0 {
        for w in &mut gpu_weights {
            *w /= total;
        }
    }
    DecomposeConfig {
        cpu_subdevices: cpu.subdevice_count(cfg.fission),
        gpu_overlap: cfg.overlap.clone(),
        gpu_weights,
        cpu_share: cfg.cpu_share,
        wgs: cfg.wgs,
        chunk_quantum,
    }
}

/// Plan an execution request (shared by both environments).
pub fn plan(
    machine: &Machine,
    sct: &Sct,
    total_units: u64,
    cfg: &FrameworkConfig,
    chunk_quantum: u64,
) -> Result<PartitionPlan> {
    decompose(
        sct,
        total_units,
        &decompose_config(machine, cfg, chunk_quantum),
    )
}

/// The simulated environment: cost model + virtual clock.
pub struct SimEnv {
    pub sim: SimMachine,
    /// COPY-mode bytes of the current request (replicated per device).
    pub copy_bytes: f64,
    /// Chunk granularity for launch-overhead accounting.
    pub chunk_units: u64,
    /// The buffer-residency model: persists across requests, so repeated
    /// requests over the same workload skip the partition upload exactly
    /// like the real runner's pool does. Timing-only [`ExecEnv::execute`]
    /// probes (the tuner's hypotheticals) never touch it — only full
    /// [`ExecEnv::run_request`]s move data.
    pub residency: ResidencyPool,
    /// Drain model (DESIGN.md §2.7): `Dataflow` prices the aggregate cost
    /// once — stages overlap, the makespan is the slowest slot's total
    /// work. `Barrier` prices stage by stage, sums the per-stage maxima
    /// and charges a sync-priced gate per stage boundary — the makespan a
    /// per-stage drain actually exhibits. Both report whole-request
    /// per-slot busy times, so tuner/KB entries stay comparable.
    pub drain_mode: DrainMode,
    /// Co-scheduling reservation (DESIGN.md §2.8): when set, every request
    /// is projected onto this device subset before planning and pricing,
    /// so the simulator prices exactly the hardware the reservation
    /// granted — the analytic twin of the real scheduler's masked drain.
    pub slot_mask: Option<SlotMask>,
    /// Prefetch lookahead (DESIGN.md §2.12): with a dataflow drain, uploads
    /// for up to this many not-yet-ready chunks ride under earlier chunks'
    /// compute. 0 disables overlap modeling (today's exposed-upload cost).
    pub prefetch_depth: u32,
}

impl SimEnv {
    pub fn new(sim: SimMachine) -> SimEnv {
        SimEnv {
            sim,
            copy_bytes: 0.0,
            chunk_units: 4096,
            // Accounting-only entries, but still bounded: long serve runs
            // over varying workloads must not grow the key set forever.
            residency: ResidencyPool::new()
                .with_capacity(crate::scheduler::real::DEFAULT_RESIDENCY_CAPACITY),
            drain_mode: DrainMode::default(),
            slot_mask: None,
            prefetch_depth: 0,
        }
    }

    /// The configuration a request actually runs under: the caller's,
    /// projected onto the installed reservation mask when one is set.
    fn masked_cfg(&self, cfg: &FrameworkConfig) -> FrameworkConfig {
        match &self.slot_mask {
            Some(m) => m.project(cfg),
            None => cfg.clone(),
        }
    }

    /// Price one request under the drain mode. `cost` is the aggregate
    /// cost profile (possibly transfer-discounted by the residency model);
    /// barrier mode re-derives the per-stage split and carries the same
    /// discount into each stage's transfer term.
    fn price(
        &mut self,
        p: &PartitionPlan,
        cost: &SctCost,
        sct: &Sct,
        cfg: &FrameworkConfig,
        occ: f64,
    ) -> crate::sim::machine::SimOutcome {
        if self.drain_mode == DrainMode::Dataflow {
            return self
                .sim
                .execute(p, cost, cfg.fission, occ, &cfg.overlap, self.chunk_units);
        }
        let mut stages = SctCost::stage_costs(sct, cost.copy_bytes);
        let base = SctCost::from_sct(sct, cost.copy_bytes);
        if base.transfer_bytes_per_unit > 0.0 {
            let scale = cost.transfer_bytes_per_unit / base.transfer_bytes_per_unit;
            for s in &mut stages {
                s.transfer_bytes_per_unit *= scale;
            }
        }
        let n_active = p.active().count();
        let mut busy: Vec<f64> = vec![0.0; p.partitions.len()];
        let (mut total, mut cpu_t, mut gpu_t) = (0.0f64, 0.0f64, 0.0f64);
        for sc in &stages {
            let out = self
                .sim
                .execute(p, sc, cfg.fission, occ, &cfg.overlap, self.chunk_units);
            for (b, t) in busy.iter_mut().zip(&out.slot_times) {
                *b += t;
            }
            // A barrier drain idles every slot until the stage's slowest
            // finishes: the makespan is the *sum of per-stage maxima*,
            // while each slot's busy clock only accumulates its own work.
            total += out.total;
            cpu_t += out.cpu_time;
            gpu_t += out.gpu_time;
        }
        // Each stage boundary is a global sync point of the barrier drain
        // (join every worker, re-dispatch the next stage's queues), priced
        // like the other sync points; loops barrier once per iteration.
        let boundaries = stages.len().saturating_sub(1) as f64 * cost.iter_factor.max(1.0);
        total += self.sim.params.sync_us_per_slot * 1e-6 * n_active as f64 * boundaries;
        crate::sim::machine::SimOutcome {
            slot_times: busy,
            total,
            cpu_time: cpu_t,
            gpu_time: gpu_t,
        }
    }

    /// SCT occupancy at the configured work-group size: the minimum over
    /// the kernels' occupancies, i.e. the max-footprint kernel constrains
    /// the whole tree (the paper configures a single wgs dimension per SCT
    /// in Algorithm 1, so the tightest kernel bounds residency).
    fn occupancy(&self, sct: &Sct, cfg: &FrameworkConfig) -> f64 {
        if self.sim.machine.gpus.is_empty() {
            return 1.0;
        }
        let fps: Vec<_> = sct.kernels().iter().map(|k| k.footprint).collect();
        occupancy::sct_occupancy(&self.sim.machine.gpus[0], &fps, cfg.wgs)
    }
}

impl ExecEnv for SimEnv {
    fn machine(&self) -> &Machine {
        &self.sim.machine
    }

    fn chunk_quantum(&self, _sct: &Sct) -> u64 {
        1
    }

    fn execute(
        &mut self,
        sct: &Sct,
        total_units: u64,
        cfg: &FrameworkConfig,
    ) -> Result<ExecOutcome> {
        let cfg = &self.masked_cfg(cfg);
        let p = plan(&self.sim.machine, sct, total_units, cfg, 1)?;
        let cost = SctCost::from_sct(sct, self.copy_bytes);
        let occ = self.occupancy(sct, cfg);
        let out = self.price(&p, &cost, sct, cfg, occ);
        Ok(ExecOutcome {
            total: out.total,
            cpu_time: out.cpu_time,
            gpu_time: out.gpu_time,
            slot_times: out
                .slot_times
                .iter()
                .copied()
                .filter(|&t| t > 0.0)
                .collect(),
            transfers: TransferStats::default(),
        })
    }

    /// The residency-aware request path: books uploads / reuses against
    /// the pool (partition inputs keyed per slot and unit range, pipeline
    /// intermediates and Loop iterations counted as reuse, COPY state
    /// re-broadcast at every global sync), then prices the execution with
    /// the resident fraction of the GPU upload discounted — the same cost
    /// shape the real runner's pool produces.
    fn run_request(
        &mut self,
        sct: &Sct,
        args: &RequestArgs,
        total_units: u64,
        cfg: &FrameworkConfig,
    ) -> Result<RunOutcome> {
        let _ = args;
        let cfg = &self.masked_cfg(cfg);
        let p = plan(&self.sim.machine, sct, total_units, cfg, 1)?;
        let cost = SctCost::from_sct(sct, self.copy_bytes);
        let occ = self.occupancy(sct, cfg);
        let request = residency::request_fingerprint(&sct.id(), total_units, &[]);
        let stages = sct.kernels().len().max(1) as u64;
        let iters = (cost.iter_factor.round() as u64).max(1);
        let before = self.residency.stats();

        let mut gpu_in_bytes = 0u64;
        let mut gpu_resident_bytes = 0u64;
        // Fresh (non-resident) GPU uploads: (gpu index, units, bytes) —
        // the only traffic a prefetch lookahead can hide (§2.12).
        let mut fresh_gpu: Vec<(usize, u64, u64)> = Vec::new();
        for part in p.active() {
            let in_bytes = (part.units as f64 * cost.transfer_bytes_per_unit).ceil() as u64;
            let key = ResidencyKey {
                arg: ArgKey::Input { request, idx: 0 },
                start_unit: part.start_unit,
                units: part.units,
                version: 0,
            };
            let was_resident = self.residency.ensure_resident(part.slot, key, in_bytes);
            if !part.slot.is_cpu() {
                gpu_in_bytes += in_bytes;
                if was_resident {
                    gpu_resident_bytes += in_bytes;
                } else if let crate::decompose::ExecSlot::GpuSlot { gpu, .. } = part.slot {
                    fresh_gpu.push((gpu as usize, part.units, in_bytes));
                }
            }
            // Pipeline intermediates stay device-resident between stages;
            // Loop iterations re-read unchanged inputs in place.
            if stages > 1 {
                self.residency.note_reuse(stages - 1, in_bytes * (stages - 1));
            }
            if iters > 1 {
                self.residency.note_reuse(iters - 1, in_bytes * (iters - 1));
            }
            // Final outputs come back to the host once.
            self.residency.note_download(in_bytes);
        }
        // Global-sync loops re-broadcast the COPY-mode state every
        // iteration (it flows through the host update) — never resident.
        if cost.sync_points > 0 && self.copy_bytes > 0.0 {
            self.residency
                .note_upload((self.copy_bytes * cost.sync_points as f64) as u64);
        }

        // Residency discount: resident inputs kill the upload half of the
        // PCIe traffic (the download half always happens).
        let mut priced = cost.clone();
        if gpu_in_bytes > 0 {
            let frac = gpu_resident_bytes as f64 / gpu_in_bytes as f64;
            priced.transfer_bytes_per_unit *= 1.0 - 0.5 * frac;
        }
        // Transfer/compute overlap (DESIGN.md §2.12): with a dataflow drain
        // and a non-zero prefetch depth, uploads for chunks beyond the
        // first ride under earlier chunks' compute. The hidden share is
        // bounded by per-link occupancy: each lookahead chunk hides at
        // most one compute window's worth of upload-seconds, so a
        // transfer-bound link serializes and hides little, and the first
        // chunk's upload is always exposed. Hidden bytes move from the
        // `bytes_uploaded` bucket to `uploads_overlapped_bytes` — the
        // conservation sum (§2.12) is unchanged.
        if self.drain_mode == DrainMode::Dataflow
            && self.prefetch_depth > 0
            && gpu_in_bytes > 0
        {
            let mut hidden_bytes = 0u64;
            let mut hidden_events = 0u64;
            for &(gpu, units, in_bytes) in &fresh_gpu {
                let t = (units / self.chunk_units).max(1);
                let w = (self.prefetch_depth as u64).min(t - 1);
                if w == 0 {
                    continue;
                }
                let spec = &self.sim.machine.gpus[gpu];
                let chunk = units as f64 / t as f64;
                let up_secs = (in_bytes as f64 / t as f64) / (spec.pcie_gbps.max(1e-9) * 1e9);
                // Roofline compute window per chunk: the slower of the
                // flop-bound and memory-bound traversal times.
                let flop_secs = cost.flops_per_unit * cost.passes * chunk
                    / (spec.gflops.max(1e-9) * 1e9 * self.sim.params.gpu_eff * occ.max(1e-3));
                let mem_secs =
                    cost.bytes_per_unit * cost.passes * chunk / (spec.mem_bw_gbps.max(1e-9) * 1e9);
                let window = flop_secs.max(mem_secs);
                let hideable = if up_secs > 0.0 {
                    (window / up_secs).min(1.0)
                } else {
                    1.0
                };
                hidden_bytes += ((in_bytes as f64 / t as f64) * w as f64 * hideable) as u64;
                hidden_events += 1;
            }
            if hidden_bytes > 0 {
                self.residency.reclassify_overlapped(hidden_events, hidden_bytes);
                // Applied on top of the residency discount: resident and
                // hidden byte sets are disjoint, and the multiplicative
                // compose undercounts their union — conservative, and the
                // download half of the traffic is never discounted.
                priced.transfer_bytes_per_unit *=
                    1.0 - 0.5 * (hidden_bytes as f64 / gpu_in_bytes as f64);
            }
        }
        let out = self.price(&p, &priced, sct, cfg, occ);
        Ok(RunOutcome {
            outputs: Vec::new(),
            exec: ExecOutcome {
                total: out.total,
                cpu_time: out.cpu_time,
                gpu_time: out.gpu_time,
                slot_times: out
                    .slot_times
                    .iter()
                    .copied()
                    .filter(|&t| t > 0.0)
                    .collect(),
                transfers: self.residency.stats().minus(&before),
            },
        })
    }

    fn set_copy_bytes(&mut self, bytes: f64) {
        self.copy_bytes = bytes;
    }

    fn set_residency_enabled(&mut self, on: bool) {
        self.residency.set_enabled(on);
    }

    fn set_drain_mode(&mut self, mode: DrainMode) {
        self.drain_mode = mode;
    }

    fn set_prefetch_depth(&mut self, depth: u32) {
        self.prefetch_depth = depth;
    }

    fn set_slot_mask(&mut self, mask: Option<SlotMask>) {
        self.slot_mask = mask;
    }

    fn mask_migration_secs(&self, mask: &SlotMask) -> f64 {
        let gbps = self
            .sim
            .machine
            .gpus
            .iter()
            .map(|g| g.pcie_gbps)
            .fold(f64::INFINITY, f64::min);
        if !gbps.is_finite() || gbps <= 0.0 {
            return 0.0;
        }
        // Data modeled as resident on a GPU the mask excludes must re-cross
        // PCIe before the masked request can use it elsewhere; host-side
        // (CPU) residency moves for free.
        let bytes = self.residency.resident_bytes_where(|s| match s {
            crate::decompose::ExecSlot::GpuSlot { gpu, .. } => {
                !mask.allows_gpu(gpu as usize)
            }
            crate::decompose::ExecSlot::CpuSub { .. } => false,
        });
        residency::migration_secs(bytes, gbps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::cpu::FissionLevel;
    use crate::platform::device::i7_hd7950;
    use crate::platform::occupancy::KernelFootprint;
    use crate::sct::{KernelSpec, ParamSpec};

    fn saxpy() -> Sct {
        let mut k = KernelSpec::new("saxpy", vec![ParamSpec::VecIn], 1);
        k.flops_per_unit = 2.0;
        k.bytes_per_unit = 12.0;
        Sct::kernel(k)
    }

    fn cfg(share: f64) -> FrameworkConfig {
        FrameworkConfig {
            fission: FissionLevel::L2,
            overlap: vec![4],
            wgs: 256,
            cpu_share: share,
        }
    }

    #[test]
    fn sim_env_executes_and_times() {
        let mut env = SimEnv::new(SimMachine::new(i7_hd7950(1), 42));
        let out = env.execute(&saxpy(), 1 << 22, &cfg(0.25)).unwrap();
        assert!(out.total > 0.0);
        assert!(out.cpu_time > 0.0 && out.gpu_time > 0.0);
        assert!(!out.slot_times.is_empty());
        assert!((out.total - out.cpu_time.max(out.gpu_time)).abs() < 1e-15);
    }

    #[test]
    fn more_gpu_share_speeds_up_gpu_favored_workload() {
        let mut env = SimEnv::new(SimMachine::new(i7_hd7950(1), 1));
        let t_hi_cpu = env.execute(&saxpy(), 1 << 24, &cfg(0.8)).unwrap().total;
        let t_lo_cpu = env.execute(&saxpy(), 1 << 24, &cfg(0.25)).unwrap().total;
        assert!(t_lo_cpu < t_hi_cpu);
    }

    #[test]
    fn plan_respects_machine_topology() {
        let m = i7_hd7950(2);
        let c = FrameworkConfig {
            fission: FissionLevel::L1,
            overlap: vec![4, 4],
            wgs: 256,
            cpu_share: 0.2,
        };
        let p = plan(&m, &saxpy(), 1 << 20, &c, 1).unwrap();
        // 6 cpu subdevices + 8 gpu slots.
        assert_eq!(p.partitions.len(), 14);
    }

    #[test]
    fn occupancy_uses_max_footprint_kernel() {
        // A light kernel piped with a local-memory hog: the SCT's occupancy
        // must be the hog's, not the first (light) kernel's.
        let light = KernelSpec::new("light", vec![ParamSpec::VecIn], 1);
        let mut heavy = KernelSpec::new("heavy", vec![ParamSpec::VecIn], 1);
        heavy.footprint = KernelFootprint {
            local_mem_base: 32 * 1024,
            local_mem_per_thread: 0,
            regs_per_thread: 16,
        };
        let env = SimEnv::new(SimMachine::new(i7_hd7950(1), 7));
        let light_first =
            Sct::pipeline(vec![Sct::kernel(light.clone()), Sct::kernel(heavy.clone())]);
        let heavy_first = Sct::pipeline(vec![Sct::kernel(heavy), Sct::kernel(light)]);
        let c = cfg(0.25);
        let a = env.occupancy(&light_first, &c);
        let b = env.occupancy(&heavy_first, &c);
        assert!((a - b).abs() < 1e-12, "order must not matter: {a} vs {b}");
        let gpu = &env.sim.machine.gpus[0];
        let hog_fp = KernelFootprint {
            local_mem_base: 32 * 1024,
            local_mem_per_thread: 0,
            regs_per_thread: 16,
        };
        let want = occupancy::occupancy(gpu, &hog_fp, c.wgs);
        assert!((a - want).abs() < 1e-12, "hog constrains: {a} vs {want}");
    }

    #[test]
    fn barrier_drain_prices_above_dataflow_on_pipelines() {
        // Noise-free machines so the comparison is structural: the barrier
        // drain's makespan is the sum of per-stage maxima plus a gate per
        // stage boundary, which strictly exceeds the dataflow drain's
        // max-over-slots — and its slots idle strictly more.
        let b = crate::bench::workloads::filter_pipeline(2048, 2048, false);
        let mut df = SimEnv::new(SimMachine::quiet(i7_hd7950(1), 17));
        let mut bar = SimEnv::new(SimMachine::quiet(i7_hd7950(1), 17));
        bar.set_drain_mode(DrainMode::Barrier);
        let c = cfg(0.25);
        let d = df.execute(&b.sct, b.total_units, &c).unwrap();
        let r = bar.execute(&b.sct, b.total_units, &c).unwrap();
        assert!(
            r.total > d.total,
            "barrier {} must exceed dataflow {}",
            r.total,
            d.total
        );
        assert!(
            r.mean_idle_frac() > d.mean_idle_frac(),
            "barrier idle {} must exceed dataflow idle {}",
            r.mean_idle_frac(),
            d.mean_idle_frac()
        );
        // Both report whole-request busy clocks over the same active slots.
        assert_eq!(r.slot_times.len(), d.slot_times.len());
        assert!(r.slot_times.iter().all(|&t| t > 0.0));
    }

    #[test]
    fn slot_mask_projects_sim_pricing_onto_the_subset() {
        // A CPU-only reservation must price exactly like an explicit
        // cpu_share=1 config with no GPU slots — bit-identically, since
        // quiet cost params make the pricing a pure function.
        let mk = || SimEnv::new(SimMachine::quiet(i7_hd7950(1), 5));
        let c = cfg(0.25);
        let mut full = mk();
        let f = full.execute(&saxpy(), 1 << 22, &c).unwrap();
        assert!(f.gpu_time > 0.0);
        let mut masked = mk();
        masked.set_slot_mask(Some(SlotMask::cpu_only(&i7_hd7950(1))));
        let m = masked.execute(&saxpy(), 1 << 22, &c).unwrap();
        assert_eq!(m.gpu_time, 0.0, "masked request must not touch the GPU");
        assert!(m.cpu_time > 0.0);
        let mut pinned = mk();
        let mut c1 = c.clone();
        c1.cpu_share = 1.0;
        c1.overlap = vec![0];
        let want = pinned.execute(&saxpy(), 1 << 22, &c1).unwrap();
        assert_eq!(m.total.to_bits(), want.total.to_bits());
        // Clearing the mask restores whole-machine pricing.
        masked.set_slot_mask(None);
        let back = masked.execute(&saxpy(), 1 << 22, &c).unwrap();
        assert!(back.gpu_time > 0.0);
    }

    #[test]
    fn idle_fractions_derive_from_slot_times() {
        let out = ExecOutcome {
            total: 2.0,
            cpu_time: 2.0,
            gpu_time: 1.0,
            slot_times: vec![2.0, 1.0],
            transfers: TransferStats::default(),
        };
        assert_eq!(out.slot_idle(), vec![0.0, 1.0]);
        assert!((out.mean_idle_frac() - 0.25).abs() < 1e-12);
        let empty = ExecOutcome {
            total: 0.0,
            cpu_time: 0.0,
            gpu_time: 0.0,
            slot_times: Vec::new(),
            transfers: TransferStats::default(),
        };
        assert_eq!(empty.mean_idle_frac(), 0.0);
    }

    #[test]
    fn fused_total_packs_opposite_leanings() {
        let lean = |cpu: f64, gpu: f64| ExecOutcome {
            total: cpu.max(gpu),
            cpu_time: cpu,
            gpu_time: gpu,
            slot_times: vec![cpu, gpu],
            transfers: Default::default(),
        };
        // Opposite leanings pack: each member's idle device absorbs the
        // other's work, so the fused makespan is far below the sum.
        let (a, b) = (lean(0.9, 0.1), lean(0.1, 0.9));
        let fused = ExecOutcome::fused_total(&[&a, &b]);
        assert!((fused - 1.0).abs() < 1e-12, "fused {fused}");
        assert!(fused < a.total + b.total);
        // Same leanings cannot pack: the fused time is the serialized sum
        // on the contended device — never better than honest.
        let (c, d) = (lean(0.9, 0.1), lean(0.8, 0.2));
        let fused = ExecOutcome::fused_total(&[&c, &d]);
        assert!((fused - 1.7).abs() < 1e-12, "fused {fused}");
        // A singleton batch is exactly the member's own makespan.
        assert_eq!(ExecOutcome::fused_total(&[&a]), a.total);
    }

    #[test]
    fn drain_mode_parses_and_labels() {
        assert_eq!(DrainMode::parse("barrier"), Some(DrainMode::Barrier));
        assert_eq!(DrainMode::parse("dataflow"), Some(DrainMode::Dataflow));
        assert_eq!(DrainMode::parse("nope"), None);
        assert_eq!(DrainMode::default().label(), "dataflow");
    }

    #[test]
    fn default_run_request_returns_timings_without_outputs() {
        let mut env = SimEnv::new(SimMachine::new(i7_hd7950(1), 3));
        let out = env
            .run_request(&saxpy(), &RequestArgs::default(), 1 << 20, &cfg(0.25))
            .unwrap();
        assert!(out.outputs.is_empty());
        assert!(out.exec.total > 0.0);
    }
}
