//! Work queues and tasks (Section 2.2: "a group of tasks placed in a set of
//! work queues — one per parallel execution").
//!
//! Each parallel execution slot owns a deque of tasks. The concurrent
//! launcher ([`crate::scheduler::launcher`]) drains every queue on its own
//! worker thread: a worker pops from the *front* of its own queue and, once
//! empty, steals from the *back* of the longest remaining queue, so slots
//! idled by load fluctuations pick up work from overloaded ones. Task `seq`
//! numbers are globally ordered by unit range, so partial results merge in
//! unit order no matter which slot ultimately ran a task.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

use crate::decompose::{chunk_partition, ExecSlot, Partition, PartitionPlan};
use crate::scheduler::reservation::SlotMask;

/// One task: execute the SCT over a partition on a slot.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Task {
    pub partition: Partition,
    /// Sequence number within the request: tasks are numbered in unit
    /// order, so sorting partials by `seq` reconstructs the domain.
    pub seq: usize,
}

/// Per-slot FIFO work queues.
#[derive(Clone, Debug, Default)]
pub struct WorkQueues {
    queues: Vec<(ExecSlot, VecDeque<Task>)>,
}

impl WorkQueues {
    /// Build the queues for a partition plan: one queue per parallel
    /// execution slot, holding that slot's (single) task. Empty partitions
    /// produce no task.
    pub fn from_plan(plan: &PartitionPlan) -> WorkQueues {
        Self::build(plan, |part| vec![*part])
    }

    /// Build the queues with each partition split into roughly
    /// `tasks_per_slot` stealable tasks, every piece aligned to the plan's
    /// quantum (the last piece absorbs the remainder, preserving whatever
    /// residue the partition carried). Finer tasks give idle slots
    /// something to steal when another slot falls behind.
    pub fn from_plan_chunked(plan: &PartitionPlan, tasks_per_slot: u32) -> WorkQueues {
        let q = plan.quantum.max(1);
        Self::build(plan, |part| chunk_partition(part, q, tasks_per_slot))
    }

    fn build<F: Fn(&Partition) -> Vec<Partition>>(plan: &PartitionPlan, split: F) -> WorkQueues {
        let mut queues: Vec<(ExecSlot, VecDeque<Task>)> = Vec::new();
        let mut seq = 0usize;
        for part in &plan.partitions {
            let q = match queues.iter_mut().find(|(s, _)| *s == part.slot) {
                Some((_, q)) => q,
                None => {
                    queues.push((part.slot, VecDeque::new()));
                    &mut queues.last_mut().unwrap().1
                }
            };
            if part.units > 0 {
                for piece in split(part) {
                    q.push_back(Task {
                        partition: piece,
                        seq,
                    });
                    seq += 1;
                }
            }
        }
        WorkQueues { queues }
    }

    pub fn n_queues(&self) -> usize {
        self.queues.len()
    }

    pub fn n_tasks(&self) -> usize {
        self.queues.iter().map(|(_, q)| q.len()).sum()
    }

    /// Restrict the queues to a reservation mask (DESIGN.md §2.8): queues
    /// owned by excluded slots are removed — no worker thread is spawned
    /// for them and no thief can reach across the boundary. Any tasks such
    /// a queue still held (a plan that routed units outside the mask)
    /// migrate to the first allowed queue rather than silently dropping
    /// work. A mask excluding every queue leaves the queues untouched —
    /// an empty reservation cannot execute anything.
    pub fn restrict(&mut self, mask: &SlotMask) {
        if !self.queues.iter().any(|(s, _)| mask.allows(s)) {
            return;
        }
        let mut displaced: VecDeque<Task> = VecDeque::new();
        self.queues.retain_mut(|(slot, q)| {
            if mask.allows(slot) {
                true
            } else {
                displaced.append(q);
                false
            }
        });
        if !displaced.is_empty() {
            self.queues[0].1.append(&mut displaced);
        }
    }

    /// The slot owning queue `i`.
    pub fn slot(&self, i: usize) -> ExecSlot {
        self.queues[i].0
    }

    /// Hand the queues to the concurrent launcher: per-queue locks so every
    /// worker thread pops (and steals) independently.
    pub fn into_shared(self) -> SharedQueues {
        SharedQueues {
            queues: self
                .queues
                .into_iter()
                .map(|(s, q)| (s, Mutex::new(q)))
                .collect(),
        }
    }
}

/// The thread-shared form of [`WorkQueues`]: one lock per queue.
pub struct SharedQueues {
    queues: Vec<(ExecSlot, Mutex<VecDeque<Task>>)>,
}

/// What one steal attempt produced: a task (when some victim's candidate
/// was admitted) and how many candidates were rejected on migration cost.
#[derive(Debug, Default)]
pub struct StealOutcome {
    pub task: Option<Task>,
    pub skipped: u64,
}

impl SharedQueues {
    pub fn n_queues(&self) -> usize {
        self.queues.len()
    }

    pub fn slot(&self, i: usize) -> ExecSlot {
        self.queues[i].0
    }

    /// Pop the next task of worker `i`'s own queue (front: unit order).
    pub fn pop_local(&self, i: usize) -> Option<Task> {
        self.queues[i].1.lock().unwrap().pop_front()
    }

    /// Steal a task for idle worker `thief`: take from the *back* of the
    /// longest other queue (the victim keeps draining its front, the thief
    /// peels units off the far end — the classic deque-stealing rule).
    pub fn steal(&self, thief: usize) -> Option<Task> {
        self.steal_where(thief, |_, _| true).task
    }

    /// Locality-aware steal: victims are visited longest-queue-first; the
    /// candidate task (the victim's back) is offered to `admit(task,
    /// victim_len)` and only popped when admitted. A rejection counts as a
    /// skipped steal and the next victim is tried — so a thief refuses
    /// work whose migration would cost more than waiting it out, without
    /// giving up on cheaper work elsewhere.
    pub fn steal_where<F>(&self, thief: usize, admit: F) -> StealOutcome
    where
        F: Fn(&Task, usize) -> bool,
    {
        let mut victims: Vec<(usize, usize)> = self
            .queues
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != thief)
            .map(|(i, (_, q))| (i, q.lock().unwrap().len()))
            .filter(|(_, len)| *len > 0)
            .collect();
        victims.sort_by_key(|(_, len)| std::cmp::Reverse(*len));
        let mut skipped = 0u64;
        for (v, _) in victims {
            // Snapshot the candidate, then price it with the victim's
            // lock released — `admit` may consult the residency pool,
            // and the victim must keep draining its front meanwhile.
            let (cand, len) = {
                let q = self.queues[v].1.lock().unwrap();
                match q.back() {
                    Some(t) => (*t, q.len()),
                    None => continue,
                }
            };
            if admit(&cand, len) {
                let mut q = self.queues[v].1.lock().unwrap();
                // Pop only if the back is still the priced candidate; a
                // raced-away task is neither stolen nor skipped.
                if q.back().map(|t| t.seq) == Some(cand.seq) {
                    return StealOutcome {
                        task: q.pop_back(),
                        skipped,
                    };
                }
            } else {
                skipped += 1;
            }
        }
        StealOutcome {
            task: None,
            skipped,
        }
    }

    pub fn remaining(&self) -> usize {
        self.queues.iter().map(|(_, q)| q.lock().unwrap().len()).sum()
    }
}

/// Ready-set scheduler for the dataflow drain (DESIGN.md §2.7): per-slot
/// deques of *node ids* that are admitted only when their dependency count
/// hits zero. Completions on the launcher's workers push newly-released
/// consumers here and bump an epoch counter, waking any parked worker —
/// the dataflow replacement for the fixed per-stage queues above.
pub struct ReadyQueues {
    queues: Vec<(ExecSlot, Mutex<VecDeque<usize>>)>,
    /// Epoch counter: bumped on every push / wake so a worker that saw
    /// empty queues at epoch `e` can sleep without missing a wake-up
    /// (recheck-then-wait on the same epoch).
    epoch: Mutex<u64>,
    cv: Condvar,
}

impl ReadyQueues {
    /// One deque per distinct execution slot, in first-seen (unit) order.
    pub fn new(slots: &[ExecSlot]) -> ReadyQueues {
        let mut queues: Vec<(ExecSlot, Mutex<VecDeque<usize>>)> = Vec::new();
        for s in slots {
            if !queues.iter().any(|(q, _)| q == s) {
                queues.push((*s, Mutex::new(VecDeque::new())));
            }
        }
        ReadyQueues {
            queues,
            epoch: Mutex::new(0),
            cv: Condvar::new(),
        }
    }

    pub fn n_queues(&self) -> usize {
        self.queues.len()
    }

    pub fn slot(&self, i: usize) -> ExecSlot {
        self.queues[i].0
    }

    /// Queue index owning `slot` (queue 0 when the slot is unknown — sync
    /// nodes are homed there and freely stealable).
    pub fn queue_of(&self, slot: ExecSlot) -> usize {
        self.queues
            .iter()
            .position(|(s, _)| *s == slot)
            .unwrap_or(0)
    }

    /// Admit a node whose dependency count hit zero, then wake sleepers.
    pub fn push(&self, queue: usize, node: usize) {
        self.queues[queue].1.lock().unwrap().push_back(node);
        self.bump();
    }

    pub fn pop_local(&self, i: usize) -> Option<usize> {
        self.queues[i].1.lock().unwrap().pop_front()
    }

    /// Steal from the back of the longest other queue; `admit(node,
    /// victim_len)` prices the candidate (same contract as
    /// [`SharedQueues::steal_where`]). Returns the stolen node and how many
    /// candidates were rejected on price.
    pub fn steal_where<F>(&self, thief: usize, admit: F) -> (Option<usize>, u64)
    where
        F: Fn(usize, usize) -> bool,
    {
        let mut victims: Vec<(usize, usize)> = self
            .queues
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != thief)
            .map(|(i, (_, q))| (i, q.lock().unwrap().len()))
            .filter(|(_, len)| *len > 0)
            .collect();
        victims.sort_by_key(|(_, len)| std::cmp::Reverse(*len));
        let mut skipped = 0u64;
        for (v, _) in victims {
            let (cand, len) = {
                let q = self.queues[v].1.lock().unwrap();
                match q.back() {
                    Some(&n) => (n, q.len()),
                    None => continue,
                }
            };
            if admit(cand, len) {
                let mut q = self.queues[v].1.lock().unwrap();
                if q.back() == Some(&cand) {
                    q.pop_back();
                    return (Some(cand), skipped);
                }
            } else {
                skipped += 1;
            }
        }
        (None, skipped)
    }

    /// Current epoch; pass it to [`ReadyQueues::wait_change`] after a
    /// fruitless scan so an interleaved push can never be missed.
    pub fn epoch(&self) -> u64 {
        *self.epoch.lock().unwrap()
    }

    /// Park until the epoch moves past `seen` (returns immediately when it
    /// already has).
    pub fn wait_change(&self, seen: u64) {
        let mut e = self.epoch.lock().unwrap();
        while *e == seen {
            e = self.cv.wait(e).unwrap();
        }
    }

    /// Wake every parked worker (drain finished, error, or cancellation).
    pub fn wake_all(&self) {
        self.bump();
    }

    fn bump(&self) {
        *self.epoch.lock().unwrap() += 1;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decompose::{decompose, DecomposeConfig};
    use crate::sct::{KernelSpec, ParamSpec, Sct};

    fn plan() -> PartitionPlan {
        let sct = Sct::kernel(KernelSpec::new("k", vec![ParamSpec::VecIn], 1));
        decompose(
            &sct,
            4096,
            &DecomposeConfig {
                cpu_subdevices: 4,
                gpu_overlap: vec![2],
                gpu_weights: vec![1.0],
                cpu_share: 0.5,
                wgs: 1,
                chunk_quantum: 1,
            },
        )
        .unwrap()
    }

    #[test]
    fn one_queue_per_slot() {
        let q = WorkQueues::from_plan(&plan());
        assert_eq!(q.n_queues(), 6); // 4 cpu + 2 gpu slots
        assert_eq!(q.n_tasks(), 6);
    }

    #[test]
    fn chunked_tasks_tile_the_domain_in_seq_order() {
        let p = plan();
        let q = WorkQueues::from_plan_chunked(&p, 4);
        assert!(q.n_tasks() > q.n_queues(), "chunking must add steal slack");
        // Collect every task, sort by seq: ranges must tile [0, 4096).
        let shared = q.into_shared();
        let mut tasks = Vec::new();
        for i in 0..shared.n_queues() {
            while let Some(t) = shared.pop_local(i) {
                tasks.push(t);
            }
        }
        tasks.sort_by_key(|t| t.seq);
        let mut cursor = 0u64;
        for t in &tasks {
            assert_eq!(t.partition.start_unit, cursor, "gap at seq {}", t.seq);
            assert!(t.partition.units > 0);
            cursor += t.partition.units;
        }
        assert_eq!(cursor, 4096);
    }

    #[test]
    fn chunked_pieces_respect_the_quantum() {
        let sct = Sct::kernel(KernelSpec::new("k", vec![ParamSpec::VecIn], 1));
        let p = decompose(
            &sct,
            8192,
            &DecomposeConfig {
                cpu_subdevices: 2,
                gpu_overlap: vec![1],
                gpu_weights: vec![1.0],
                cpu_share: 0.5,
                wgs: 1,
                chunk_quantum: 256,
            },
        )
        .unwrap();
        let shared = WorkQueues::from_plan_chunked(&p, 4).into_shared();
        for i in 0..shared.n_queues() {
            let mut last: Option<Task> = None;
            while let Some(t) = shared.pop_local(i) {
                if let Some(prev) = last {
                    assert_eq!(prev.partition.units % 256, 0, "non-tail piece off-quantum");
                }
                last = Some(t);
            }
        }
    }

    #[test]
    fn steal_takes_back_of_longest_queue() {
        let p = plan();
        let shared = WorkQueues::from_plan_chunked(&p, 4).into_shared();
        // Drain queue 0 fully, then steal for it: the task must come from
        // another queue's back (highest start_unit of that queue).
        while shared.pop_local(0).is_some() {}
        let before = shared.remaining();
        let stolen = shared.steal(0).expect("other queues still hold work");
        assert_eq!(shared.remaining(), before - 1);
        assert_ne!(stolen.partition.slot, shared.slot(0));
    }

    #[test]
    fn prop_chunked_queues_cover_partitions_aligned_and_ordered() {
        use crate::util::propcheck::forall;
        // For random (domain size, tasks_per_slot, cpu share, quantum):
        //  * the pieces of each partition tile it exactly;
        //  * every non-tail piece of a partition is quantum-aligned;
        //  * seq numbers are globally ordered by start unit.
        forall(
            0x5EA1,
            250,
            |r| {
                (
                    r.below(1 << 13) + 1, // total units
                    r.below(8) + 1,       // tasks per slot
                    r.below(101),         // cpu share %
                )
            },
            |&(total, tps, share)| {
                let sct = Sct::kernel(KernelSpec::new("k", vec![ParamSpec::VecIn], 1));
                let plan = decompose(
                    &sct,
                    total,
                    &DecomposeConfig {
                        cpu_subdevices: 3,
                        gpu_overlap: vec![2],
                        gpu_weights: vec![1.0],
                        cpu_share: share as f64 / 100.0,
                        wgs: 1,
                        chunk_quantum: 16,
                    },
                )
                .map_err(|e| format!("{e}"))?;
                let q = WorkQueues::from_plan_chunked(&plan, tps as u32);
                let shared = q.into_shared();
                let mut tasks = Vec::new();
                for i in 0..shared.n_queues() {
                    while let Some(t) = shared.pop_local(i) {
                        tasks.push(t);
                    }
                }
                tasks.sort_by_key(|t| t.seq);
                // seq order == unit order, gap-free tiling of the domain.
                let mut cursor = 0u64;
                for t in &tasks {
                    if t.partition.start_unit != cursor {
                        return Err(format!(
                            "seq {} starts at {} expected {cursor}",
                            t.seq, t.partition.start_unit
                        ));
                    }
                    if t.partition.units == 0 {
                        return Err(format!("seq {} is empty", t.seq));
                    }
                    cursor += t.partition.units;
                }
                if cursor != total {
                    return Err(format!("tiled {cursor} of {total}"));
                }
                // Every piece that is not the tail of its partition must
                // be quantum-aligned (the tail absorbs the residue).
                for pair in tasks.windows(2) {
                    let (a, b) = (&pair[0], &pair[1]);
                    if a.partition.slot == b.partition.slot
                        && a.partition.units % plan.quantum != 0
                    {
                        return Err(format!(
                            "non-tail piece at seq {} ({} units) off the \
                             quantum {}",
                            a.seq, a.partition.units, plan.quantum
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn steal_where_rejections_count_and_fall_through() {
        let p = plan();
        let shared = WorkQueues::from_plan_chunked(&p, 4).into_shared();
        while shared.pop_local(0).is_some() {}
        // Reject everything: no task moves, every victim counted.
        let out = shared.steal_where(0, |_, _| false);
        assert!(out.task.is_none());
        assert!(out.skipped > 0);
        // Admit only tasks owned by CPU slots: the steal falls through
        // rejected victims to an admissible one.
        let out = shared.steal_where(0, |t, _| t.partition.slot.is_cpu());
        let stolen = out.task.expect("cpu-owned task must be admitted");
        assert!(stolen.partition.slot.is_cpu());
    }

    #[test]
    fn ready_queues_release_steal_and_wake() {
        let slots = [
            ExecSlot::CpuSub { idx: 0 },
            ExecSlot::GpuSlot { gpu: 0, slot: 0 },
            ExecSlot::CpuSub { idx: 0 }, // duplicate collapses
        ];
        let rq = ReadyQueues::new(&slots);
        assert_eq!(rq.n_queues(), 2);
        assert_eq!(rq.queue_of(ExecSlot::GpuSlot { gpu: 0, slot: 0 }), 1);
        assert_eq!(rq.queue_of(ExecSlot::GpuSlot { gpu: 9, slot: 9 }), 0);
        rq.push(1, 7);
        rq.push(1, 8);
        // A thief takes the back of the longest other queue.
        let (n, skipped) = rq.steal_where(0, |_, _| true);
        assert_eq!(n, Some(8));
        assert_eq!(skipped, 0);
        // Rejections are counted, nothing moves.
        let (n, skipped) = rq.steal_where(0, |_, _| false);
        assert_eq!(n, None);
        assert_eq!(skipped, 1);
        assert_eq!(rq.pop_local(1), Some(7));
        assert_eq!(rq.pop_local(1), None);
        // wait_change on a stale epoch returns immediately.
        let e = rq.epoch();
        rq.wake_all();
        rq.wait_change(e);
    }

    #[test]
    fn restrict_drops_excluded_queues_without_losing_work() {
        let p = plan();
        let mut q = WorkQueues::from_plan_chunked(&p, 2);
        let total = q.n_tasks();
        // CPU-only reservation: GPU queues disappear, their tasks migrate.
        q.restrict(&SlotMask {
            cpu: true,
            gpus: vec![false],
        });
        assert!(q.n_queues() > 0);
        for i in 0..q.n_queues() {
            assert!(q.slot(i).is_cpu(), "excluded slot survived the mask");
        }
        assert_eq!(q.n_tasks(), total, "displaced tasks must be reassigned");
        // An all-excluding mask is ignored — something must drain the work.
        let mut q2 = WorkQueues::from_plan_chunked(&p, 2);
        let nq = q2.n_queues();
        q2.restrict(&SlotMask {
            cpu: false,
            gpus: vec![false],
        });
        assert_eq!(q2.n_queues(), nq);
    }

    #[test]
    fn empty_partitions_create_no_tasks() {
        let sct = Sct::kernel(KernelSpec::new("k", vec![ParamSpec::VecIn], 1));
        let p = decompose(
            &sct,
            2,
            &DecomposeConfig {
                cpu_subdevices: 8,
                gpu_overlap: vec![],
                gpu_weights: vec![],
                cpu_share: 1.0,
                wgs: 1,
                chunk_quantum: 1,
            },
        )
        .unwrap();
        let q = WorkQueues::from_plan(&p);
        assert!(q.n_tasks() <= 2);
    }
}
