//! Work queues and tasks (Section 2.2: "a group of tasks placed in a set of
//! work queues — one per parallel execution").
//!
//! The launcher consumes queues in round-robin order. On the paper's
//! hardware each queue drains on its own device concurrently; the PJRT CPU
//! client binding is single-threaded, so the Real scheduler preserves queue
//! *semantics* (ordering, per-slot accounting) with deterministic
//! round-robin draining, and per-slot times come from per-task wall clocks.

use std::collections::VecDeque;

use crate::decompose::{ExecSlot, Partition, PartitionPlan};

/// One task: execute the SCT over a partition on a slot.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Task {
    pub partition: Partition,
    /// Sequence number within the request (stable ordering for merges).
    pub seq: usize,
}

/// Per-slot FIFO work queues.
#[derive(Clone, Debug, Default)]
pub struct WorkQueues {
    queues: Vec<(ExecSlot, VecDeque<Task>)>,
}

impl WorkQueues {
    /// Build the queues for a partition plan: one queue per parallel
    /// execution slot, holding that slot's (single) task. Empty partitions
    /// produce no task.
    pub fn from_plan(plan: &PartitionPlan) -> WorkQueues {
        let mut queues: Vec<(ExecSlot, VecDeque<Task>)> = Vec::new();
        for (seq, part) in plan.partitions.iter().enumerate() {
            let q = match queues.iter_mut().find(|(s, _)| *s == part.slot) {
                Some((_, q)) => q,
                None => {
                    queues.push((part.slot, VecDeque::new()));
                    &mut queues.last_mut().unwrap().1
                }
            };
            if part.units > 0 {
                q.push_back(Task {
                    partition: *part,
                    seq,
                });
            }
        }
        WorkQueues { queues }
    }

    pub fn n_queues(&self) -> usize {
        self.queues.len()
    }

    pub fn n_tasks(&self) -> usize {
        self.queues.iter().map(|(_, q)| q.len()).sum()
    }

    /// Round-robin drain: repeatedly take the front task of each non-empty
    /// queue. Returns tasks in a deterministic interleaving.
    pub fn drain_round_robin(&mut self) -> Vec<Task> {
        let mut out = Vec::with_capacity(self.n_tasks());
        loop {
            let mut any = false;
            for (_, q) in self.queues.iter_mut() {
                if let Some(t) = q.pop_front() {
                    out.push(t);
                    any = true;
                }
            }
            if !any {
                break;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decompose::{decompose, DecomposeConfig};
    use crate::sct::{KernelSpec, ParamSpec, Sct};

    fn plan() -> PartitionPlan {
        let sct = Sct::kernel(KernelSpec::new("k", vec![ParamSpec::VecIn], 1));
        decompose(
            &sct,
            4096,
            &DecomposeConfig {
                cpu_subdevices: 4,
                gpu_overlap: vec![2],
                gpu_weights: vec![1.0],
                cpu_share: 0.5,
                wgs: 1,
                chunk_quantum: 1,
            },
        )
        .unwrap()
    }

    #[test]
    fn one_queue_per_slot() {
        let q = WorkQueues::from_plan(&plan());
        assert_eq!(q.n_queues(), 6); // 4 cpu + 2 gpu slots
        assert_eq!(q.n_tasks(), 6);
    }

    #[test]
    fn drain_is_deterministic_and_complete() {
        let mut a = WorkQueues::from_plan(&plan());
        let mut b = WorkQueues::from_plan(&plan());
        let ta = a.drain_round_robin();
        let tb = b.drain_round_robin();
        assert_eq!(ta, tb);
        assert_eq!(ta.len(), 6);
        assert_eq!(a.n_tasks(), 0);
    }

    #[test]
    fn empty_partitions_create_no_tasks() {
        let sct = Sct::kernel(KernelSpec::new("k", vec![ParamSpec::VecIn], 1));
        let p = decompose(
            &sct,
            2,
            &DecomposeConfig {
                cpu_subdevices: 8,
                gpu_overlap: vec![],
                gpu_weights: vec![],
                cpu_share: 1.0,
                wgs: 1,
                chunk_quantum: 1,
            },
        )
        .unwrap();
        let q = WorkQueues::from_plan(&p);
        assert!(q.n_tasks() <= 2);
    }
}
