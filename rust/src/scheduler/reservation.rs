//! Device-space co-scheduling (DESIGN.md §2.8): slot reservations that let
//! the serve path admit each request onto a *subset* of the machine's
//! execution slots — request A on the GPU slots while request B runs on the
//! CPU sub-devices — instead of time-sharing the whole pool.
//!
//! The paper's central claim is that compound computations should run on
//! the best workload-dependent subset of the hardware; PR 2's serve path
//! honoured that *within* a request but still serialized *across* requests.
//! This module provides the three pieces the co-scheduler needs:
//!
//!  * [`SlotMask`] — a device-space subset (the CPU device plus any
//!    combination of GPUs), with the projection that restricts a
//!    [`FrameworkConfig`] to the masked hardware and the capacity fraction
//!    used to derate a KB cost estimate onto the subset;
//!  * [`SlotReservations`] — the admission registry: blocking, RAII-guarded
//!    reservations where conflicting masks serialize and disjoint masks
//!    overlap. Guards release on drop, so a panicking or failing request
//!    can never leak its slots;
//!  * [`VirtualTimeline`] — the analytic model of overlapping reservations:
//!    requests booked on conflicting masks stack up, disjoint ones overlap,
//!    so the whole feature is testable (and benchable) in [`SimEnv`]
//!    without a GPU.
//!
//! [`SimEnv`]: crate::scheduler::SimEnv

use std::sync::{Condvar, Mutex};

use crate::decompose::ExecSlot;
use crate::platform::device::Machine;
use crate::tuner::profile::FrameworkConfig;

/// A device-space subset of the machine's execution slots. Granularity is
/// the *device* (the paper's unit of data residency): the CPU device with
/// all its fission sub-devices, and each GPU with all its overlap slots —
/// a reservation boundary between two slots of one device would split one
/// memory, which the residency layer (§2.6) deliberately never does.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SlotMask {
    /// Whether the CPU device (every fission sub-device) is included.
    pub cpu: bool,
    /// Per-GPU inclusion, indexed like `machine.gpus`.
    pub gpus: Vec<bool>,
}

impl SlotMask {
    /// The whole machine (PR 2's implicit reservation).
    pub fn full(machine: &Machine) -> SlotMask {
        SlotMask {
            cpu: true,
            gpus: vec![true; machine.gpus.len()],
        }
    }

    /// CPU device only.
    pub fn cpu_only(machine: &Machine) -> SlotMask {
        SlotMask {
            cpu: true,
            gpus: vec![false; machine.gpus.len()],
        }
    }

    /// One GPU only.
    pub fn single_gpu(machine: &Machine, gpu: usize) -> SlotMask {
        let mut gpus = vec![false; machine.gpus.len()];
        if gpu < gpus.len() {
            gpus[gpu] = true;
        }
        SlotMask { cpu: false, gpus }
    }

    /// Every GPU, no CPU.
    pub fn all_gpus(machine: &Machine) -> SlotMask {
        SlotMask {
            cpu: false,
            gpus: vec![true; machine.gpus.len()],
        }
    }

    pub fn allows_gpu(&self, gpu: usize) -> bool {
        self.gpus.get(gpu).copied().unwrap_or(false)
    }

    pub fn has_gpu(&self) -> bool {
        self.gpus.iter().any(|&g| g)
    }

    pub fn is_empty(&self) -> bool {
        !self.cpu && !self.has_gpu()
    }

    /// Whether `slot` belongs to this subset.
    pub fn allows(&self, slot: &ExecSlot) -> bool {
        match slot {
            ExecSlot::CpuSub { .. } => self.cpu,
            ExecSlot::GpuSlot { gpu, .. } => self.allows_gpu(*gpu as usize),
        }
    }

    /// Whether two masks share any device (conflicting reservations must
    /// serialize; disjoint ones co-schedule).
    pub fn conflicts(&self, other: &SlotMask) -> bool {
        if self.cpu && other.cpu {
            return true;
        }
        self.gpus
            .iter()
            .zip(&other.gpus)
            .any(|(&a, &b)| a && b)
    }

    /// Human label, e.g. `cpu`, `gpu0`, `cpu+gpu0+gpu1`.
    pub fn label(&self) -> String {
        let mut parts = Vec::new();
        if self.cpu {
            parts.push("cpu".to_string());
        }
        for (g, &on) in self.gpus.iter().enumerate() {
            if on {
                parts.push(format!("gpu{g}"));
            }
        }
        if parts.is_empty() {
            "none".to_string()
        } else {
            parts.join("+")
        }
    }

    /// Restrict a framework configuration to the masked hardware: excluded
    /// GPUs lose their overlap slots (zero entries — the decomposer
    /// renormalizes the remaining device weights), a GPU-less mask pushes
    /// the whole domain onto the CPU, a CPU-less mask pushes it onto the
    /// granted GPUs. The projection never invents slots: with an empty
    /// mask the config comes back unchanged (callers reject empty masks at
    /// admission).
    pub fn project(&self, cfg: &FrameworkConfig) -> FrameworkConfig {
        if self.is_empty() {
            return cfg.clone();
        }
        let mut out = cfg.clone();
        for (g, o) in out.overlap.iter_mut().enumerate() {
            if !self.allows_gpu(g) {
                *o = 0;
            }
        }
        let any_gpu_slots = out.overlap.iter().any(|&o| o > 0);
        if !any_gpu_slots {
            out.cpu_share = 1.0;
        } else if !self.cpu {
            out.cpu_share = 0.0;
        }
        out
    }

    /// Fraction of the request's tuned throughput this subset retains —
    /// the per-device cost model of the admission control ("CPU and/or
    /// GPU", Kothapalli et al.): the KB's tuned `cpu_share` is the
    /// fraction of the workload the CPU handles at the balanced optimum,
    /// so it doubles as the CPU's relative capacity for *this* workload;
    /// the GPU remainder splits by the machine's static SHOC weights.
    /// 1.0 for the full mask, 0.0 for a subset that can't run the request.
    pub fn capacity_frac(&self, cfg: &FrameworkConfig, machine: &Machine) -> f64 {
        if machine.gpus.is_empty() {
            return if self.cpu { 1.0 } else { 0.0 };
        }
        let weights = machine.gpu_weights();
        let gpu_part: f64 = weights
            .iter()
            .enumerate()
            .filter(|(g, _)| self.allows_gpu(*g))
            .map(|(_, w)| w)
            .sum();
        let cpu_cap = if self.cpu { cfg.cpu_share } else { 0.0 };
        (cpu_cap + cfg.gpu_share() * gpu_part).clamp(0.0, 1.0)
    }
}

impl std::fmt::Display for SlotMask {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.label())
    }
}

/// The candidate subsets admission prices for a machine: the full pool,
/// the CPU device alone, each GPU alone, and (on multi-GPU machines) all
/// GPUs together. Device-granular by construction, never empty.
pub fn candidate_masks(machine: &Machine) -> Vec<SlotMask> {
    let mut out = vec![SlotMask::full(machine)];
    if !machine.gpus.is_empty() {
        out.push(SlotMask::cpu_only(machine));
        for g in 0..machine.gpus.len() {
            out.push(SlotMask::single_gpu(machine, g));
        }
        if machine.gpus.len() > 1 {
            out.push(SlotMask::all_gpus(machine));
        }
    }
    out
}

/// One active reservation.
struct Active {
    id: u64,
    mask: SlotMask,
    /// The admission-time completion estimate (seconds) — the wait price a
    /// later conflicting request pays for queuing behind this one.
    est_secs: f64,
}

#[derive(Default)]
struct ReservationState {
    active: Vec<Active>,
    /// FIFO admission queue: blocked acquirers park here in ticket order,
    /// and a later acquirer may not overtake an earlier one it conflicts
    /// with — without this, a wide (full-pool) reservation could be
    /// starved forever by a sustained stream of narrow disjoint ones.
    waiting: Vec<(u64, SlotMask, f64)>,
    next_id: u64,
}

/// The admission registry: requests reserve a [`SlotMask`] before
/// executing; conflicting masks block until the holder releases, disjoint
/// masks proceed concurrently. Each request holds at most one reservation
/// (acquired atomically), so the registry is deadlock-free, and blocked
/// acquirers are served in FIFO ticket order among conflicting masks, so
/// a request wider than any free subset queues — and *progresses* — even
/// under a sustained stream of narrow reservations.
#[derive(Default)]
pub struct SlotReservations {
    state: Mutex<ReservationState>,
    cv: Condvar,
}

impl SlotReservations {
    pub fn new() -> SlotReservations {
        SlotReservations::default()
    }

    /// Estimated seconds of already-admitted work conflicting with `mask`
    /// (the wait term of the admission price): conflicting reservations —
    /// held *or* queued ahead — serialize, so their estimates sum.
    pub fn pending_secs(&self, mask: &SlotMask) -> f64 {
        let st = self.state.lock().unwrap();
        let held: f64 = st
            .active
            .iter()
            .filter(|a| a.mask.conflicts(mask))
            .map(|a| a.est_secs)
            .sum();
        let queued: f64 = st
            .waiting
            .iter()
            .filter(|(_, m, _)| m.conflicts(mask))
            .map(|(_, _, est)| est)
            .sum();
        held + queued
    }

    /// Number of reservations currently held.
    pub fn active_len(&self) -> usize {
        self.state.lock().unwrap().active.len()
    }

    /// Reserve `mask` if no held reservation — and no FIFO-queued earlier
    /// acquirer — conflicts; `None` otherwise (barging past parked wide
    /// requests would reintroduce the starvation `acquire` prevents).
    pub fn try_acquire(&self, mask: SlotMask, est_secs: f64) -> Option<ReservationGuard<'_>> {
        let mut st = self.state.lock().unwrap();
        if st.active.iter().any(|a| a.mask.conflicts(&mask))
            || st.waiting.iter().any(|(_, m, _)| m.conflicts(&mask))
        {
            return None;
        }
        Some(self.grant(&mut st, mask, est_secs))
    }

    /// Reserve `mask`, blocking until every conflicting reservation has
    /// been released — FIFO among conflicting acquirers, so a wide mask
    /// cannot be starved by later narrow ones. The returned guard releases
    /// on drop — including unwinds, so a panicking request frees its
    /// slots.
    pub fn acquire(&self, mask: SlotMask, est_secs: f64) -> ReservationGuard<'_> {
        let mut st = self.state.lock().unwrap();
        let ticket = st.next_id;
        st.next_id += 1;
        st.waiting.push((ticket, mask.clone(), est_secs));
        loop {
            let blocked = st.active.iter().any(|a| a.mask.conflicts(&mask))
                || st
                    .waiting
                    .iter()
                    .any(|(t, m, _)| *t < ticket && m.conflicts(&mask));
            if !blocked {
                break;
            }
            st = self.cv.wait(st).unwrap();
        }
        st.waiting.retain(|(t, _, _)| *t != ticket);
        self.grant_with_id(&mut st, ticket, mask, est_secs)
    }

    fn grant(
        &self,
        st: &mut ReservationState,
        mask: SlotMask,
        est_secs: f64,
    ) -> ReservationGuard<'_> {
        let id = st.next_id;
        st.next_id += 1;
        self.grant_with_id(st, id, mask, est_secs)
    }

    fn grant_with_id(
        &self,
        st: &mut ReservationState,
        id: u64,
        mask: SlotMask,
        est_secs: f64,
    ) -> ReservationGuard<'_> {
        st.active.push(Active {
            id,
            mask: mask.clone(),
            est_secs,
        });
        ReservationGuard {
            registry: self,
            id,
            mask,
        }
    }

    fn release(&self, id: u64) {
        let mut st = self.state.lock().unwrap();
        st.active.retain(|a| a.id != id);
        drop(st);
        self.cv.notify_all();
    }
}

/// RAII handle to one granted reservation; releasing (drop) wakes every
/// queued acquirer.
pub struct ReservationGuard<'r> {
    registry: &'r SlotReservations,
    id: u64,
    mask: SlotMask,
}

impl ReservationGuard<'_> {
    pub fn mask(&self) -> &SlotMask {
        &self.mask
    }
}

impl Drop for ReservationGuard<'_> {
    fn drop(&mut self) {
        self.registry.release(self.id);
    }
}

/// Analytic model of overlapping reservations: each completed request books
/// `(mask, duration)`; a booking starts at the latest end among earlier
/// bookings it conflicts with, so requests on one device stack up while
/// requests on disjoint devices overlap. Booking every request with the
/// full mask reproduces PR 2's whole-pool serialization — the A/B baseline
/// the co-scheduling bench and tests compare against, all in virtual time
/// (no GPU, no wall-clock noise).
#[derive(Default)]
pub struct VirtualTimeline {
    bookings: Mutex<Vec<(SlotMask, f64)>>,
}

impl VirtualTimeline {
    pub fn new() -> VirtualTimeline {
        VirtualTimeline::default()
    }

    /// Book `secs` of work on `mask`; returns the booking's (start, end)
    /// in virtual seconds.
    pub fn book(&self, mask: &SlotMask, secs: f64) -> (f64, f64) {
        let mut b = self.bookings.lock().unwrap();
        let start = b
            .iter()
            .filter(|(m, _)| m.conflicts(mask))
            .map(|&(_, end)| end)
            .fold(0.0f64, f64::max);
        let end = start + secs.max(0.0);
        b.push((mask.clone(), end));
        (start, end)
    }

    /// Completion time of everything booked so far (max end).
    pub fn makespan(&self) -> f64 {
        self.bookings
            .lock()
            .unwrap()
            .iter()
            .map(|&(_, end)| end)
            .fold(0.0, f64::max)
    }

    pub fn len(&self) -> usize {
        self.bookings.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.bookings.lock().unwrap().is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::cpu::FissionLevel;
    use crate::platform::device::{i7_hd7950, opteron_6272_quad};

    fn cfg(cpu_share: f64, overlap: Vec<u32>) -> FrameworkConfig {
        FrameworkConfig {
            fission: FissionLevel::L2,
            overlap,
            wgs: 256,
            cpu_share,
        }
    }

    #[test]
    fn masks_conflict_on_shared_devices_only() {
        let m = i7_hd7950(2);
        let cpu = SlotMask::cpu_only(&m);
        let g0 = SlotMask::single_gpu(&m, 0);
        let g1 = SlotMask::single_gpu(&m, 1);
        let full = SlotMask::full(&m);
        assert!(!cpu.conflicts(&g0));
        assert!(!g0.conflicts(&g1));
        assert!(full.conflicts(&cpu) && full.conflicts(&g0) && full.conflicts(&g1));
        assert!(g0.conflicts(&SlotMask::all_gpus(&m)));
        assert_eq!(cpu.label(), "cpu");
        assert_eq!(g1.label(), "gpu1");
        assert_eq!(full.label(), "cpu+gpu0+gpu1");
    }

    #[test]
    fn mask_allows_slots_of_its_devices() {
        let m = i7_hd7950(2);
        let g0 = SlotMask::single_gpu(&m, 0);
        assert!(g0.allows(&ExecSlot::GpuSlot { gpu: 0, slot: 3 }));
        assert!(!g0.allows(&ExecSlot::GpuSlot { gpu: 1, slot: 0 }));
        assert!(!g0.allows(&ExecSlot::CpuSub { idx: 0 }));
        assert!(SlotMask::cpu_only(&m).allows(&ExecSlot::CpuSub { idx: 5 }));
    }

    #[test]
    fn projection_restricts_config_to_the_mask() {
        let m = i7_hd7950(2);
        let base = cfg(0.25, vec![4, 4]);
        let cpu = SlotMask::cpu_only(&m).project(&base);
        assert_eq!(cpu.cpu_share, 1.0);
        assert_eq!(cpu.overlap, vec![0, 0]);
        let g1 = SlotMask::single_gpu(&m, 1).project(&base);
        assert_eq!(g1.cpu_share, 0.0);
        assert_eq!(g1.overlap, vec![0, 4]);
        let full = SlotMask::full(&m).project(&base);
        assert_eq!(full, base);
        // A mask whose GPUs have no overlap slots degrades to CPU-only.
        let no_slots = SlotMask::single_gpu(&m, 0).project(&cfg(0.25, vec![0, 4]));
        assert_eq!(no_slots.cpu_share, 1.0);
    }

    #[test]
    fn capacity_fraction_tracks_the_tuned_split() {
        let m = i7_hd7950(1);
        let c = cfg(0.9, vec![4]);
        let full = SlotMask::full(&m).capacity_frac(&c, &m);
        assert!((full - 1.0).abs() < 1e-12);
        let cpu = SlotMask::cpu_only(&m).capacity_frac(&c, &m);
        assert!((cpu - 0.9).abs() < 1e-12);
        let gpu = SlotMask::all_gpus(&m).capacity_frac(&c, &m);
        assert!((gpu - 0.1).abs() < 1e-12);
        // CPU-only machines: the CPU is all the capacity there is.
        let cm = opteron_6272_quad();
        assert_eq!(SlotMask::cpu_only(&cm).capacity_frac(&c, &cm), 1.0);
    }

    #[test]
    fn candidates_cover_the_device_subsets() {
        let two = candidate_masks(&i7_hd7950(2));
        // full, cpu, gpu0, gpu1, all-gpus.
        assert_eq!(two.len(), 5);
        assert!(two.iter().all(|m| !m.is_empty()));
        let cpu_only = candidate_masks(&opteron_6272_quad());
        assert_eq!(cpu_only.len(), 1);
        assert_eq!(cpu_only[0], SlotMask::full(&opteron_6272_quad()));
    }

    #[test]
    fn disjoint_reservations_coexist_conflicting_block() {
        let m = i7_hd7950(1);
        let reg = SlotReservations::new();
        let cpu = reg
            .try_acquire(SlotMask::cpu_only(&m), 1.0)
            .expect("empty registry grants");
        let gpu = reg
            .try_acquire(SlotMask::all_gpus(&m), 2.0)
            .expect("disjoint mask grants");
        assert_eq!(reg.active_len(), 2);
        assert!(reg.try_acquire(SlotMask::full(&m), 1.0).is_none());
        // Wait price sums the conflicting estimates.
        assert!((reg.pending_secs(&SlotMask::full(&m)) - 3.0).abs() < 1e-12);
        assert!((reg.pending_secs(&SlotMask::cpu_only(&m)) - 1.0).abs() < 1e-12);
        drop(cpu);
        drop(gpu);
        assert_eq!(reg.active_len(), 0);
        assert!(reg.try_acquire(SlotMask::full(&m), 1.0).is_some());
    }

    #[test]
    fn narrow_reservations_cannot_overtake_a_queued_wide_one() {
        // A full-pool acquirer parks behind a held cpu reservation; a
        // later narrow (gpu) acquirer — disjoint from everything *held* —
        // must still yield to the queued wide request, or sustained
        // narrow traffic would starve it forever.
        let m = i7_hd7950(1);
        let reg = SlotReservations::new();
        let cpu = reg.try_acquire(SlotMask::cpu_only(&m), 1.0).unwrap();
        std::thread::scope(|s| {
            let reg = &reg;
            let m = &m;
            s.spawn(move || {
                let _g = reg.acquire(SlotMask::full(m), 1.0);
            });
            // The waiter is parked once its estimate shows up in the
            // conflicting-pending sum (1.0 held + 1.0 queued).
            while reg.pending_secs(&SlotMask::full(m)) < 1.5 {
                std::thread::yield_now();
            }
            assert!(
                reg.try_acquire(SlotMask::all_gpus(m), 1.0).is_none(),
                "a narrow acquirer must not barge past the queued wide one"
            );
            drop(cpu);
        });
        // Queue drained in order; the pool is free again.
        assert_eq!(reg.active_len(), 0);
        assert!(reg.try_acquire(SlotMask::all_gpus(&m), 1.0).is_some());
    }

    #[test]
    fn timeline_overlaps_disjoint_and_stacks_conflicting() {
        let m = i7_hd7950(1);
        let tl = VirtualTimeline::new();
        let (s0, e0) = tl.book(&SlotMask::cpu_only(&m), 2.0);
        let (s1, e1) = tl.book(&SlotMask::all_gpus(&m), 3.0);
        assert_eq!((s0, e0), (0.0, 2.0));
        assert_eq!((s1, e1), (0.0, 3.0), "disjoint bookings overlap");
        assert_eq!(tl.makespan(), 3.0);
        // A full-mask booking waits for both.
        let (s2, e2) = tl.book(&SlotMask::full(&m), 1.0);
        assert_eq!((s2, e2), (3.0, 4.0));
        // Whole-pool bookings serialize: the PR 2 baseline.
        let tl2 = VirtualTimeline::new();
        tl2.book(&SlotMask::full(&m), 2.0);
        tl2.book(&SlotMask::full(&m), 3.0);
        assert_eq!(tl2.makespan(), 5.0);
    }
}
