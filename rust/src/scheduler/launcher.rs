//! The concurrent task launcher (Section 2.2): per-slot worker threads
//! drain the work queues simultaneously, so hybrid CPU/GPU executions
//! genuinely overlap — the request's completion time is the wall clock of
//! the slowest *concurrent* slot, not a serial sum of per-task slices.
//!
//! Each worker owns one queue (front pops preserve unit order) and steals
//! from the back of the longest other queue once its own runs dry. Per-task
//! wall times are measured on the worker that ran the task and stay paired
//! with the task's `seq`, so partial results and their timings can never
//! drift apart (the drain-order/plan-order mismatch the serial launcher
//! suffered from). Per-slot busy clocks feed the execution monitor.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::data::vector::ArgValue;
use crate::decompose::ExecSlot;
use crate::error::Result;
use crate::scheduler::queues::{SharedQueues, Task, WorkQueues};

/// One slot-execution engine the launcher drives: runs a single task and
/// returns its partial outputs. Implementations decide how much real
/// parallelism the backend tolerates (the PJRT binding serializes launches
/// behind the client's gate; the stub and the tests run fully parallel).
pub trait TaskRunner: Sync {
    fn run_task(&self, slot: ExecSlot, task: &Task) -> Result<TaskOutput>;
}

/// One task's outputs, plus an optional self-measured execution time.
pub struct TaskOutput {
    pub outputs: Vec<ArgValue>,
    /// Execution seconds as measured by the runner itself, *excluding* any
    /// serialization wait it imposed (e.g. the PJRT launch gate) — lock
    /// waits in a busy clock would make every slot look equally slow and
    /// blind the balance monitor. `None` lets the launcher's own wall
    /// measurement stand (right for runners with no internal locking).
    pub busy: Option<f64>,
}

impl From<Vec<ArgValue>> for TaskOutput {
    fn from(outputs: Vec<ArgValue>) -> TaskOutput {
        TaskOutput {
            outputs,
            busy: None,
        }
    }
}

/// Per-slot wall clocks of one concurrent drain.
#[derive(Clone, Debug, Default)]
pub struct SlotClock {
    /// The slot owning each queue (stable across iterations of a Loop).
    pub slots: Vec<ExecSlot>,
    /// Busy seconds accumulated by each slot's worker.
    pub busy: Vec<f64>,
    /// Wall-clock seconds of the whole concurrent drain — with real
    /// overlap this is (close to) the *max* over slots, not their sum.
    pub elapsed: f64,
}

impl SlotClock {
    fn max_busy<F: Fn(&ExecSlot) -> bool>(&self, pred: F) -> f64 {
        self.slots
            .iter()
            .zip(&self.busy)
            .filter(|(s, _)| pred(s))
            .map(|(_, &t)| t)
            .fold(0.0, f64::max)
    }

    /// Completion time of the CPU device type: max busy over CPU slots.
    pub fn cpu_time(&self) -> f64 {
        self.max_busy(|s| s.is_cpu())
    }

    /// Completion time of the GPU device type: max busy over GPU slots.
    pub fn gpu_time(&self) -> f64 {
        self.max_busy(|s| !s.is_cpu())
    }

    /// Per-slot times of the active slots (busy > 0), for the monitor.
    pub fn active_times(&self) -> Vec<f64> {
        self.busy.iter().copied().filter(|&t| t > 0.0).collect()
    }

    /// Fold another drain's clocks in (Loop iterations re-drain the same
    /// queues, so slots align by identity).
    pub fn accumulate(&mut self, other: &SlotClock) {
        if self.slots.is_empty() {
            self.slots = other.slots.clone();
            self.busy = vec![0.0; other.busy.len()];
        }
        for (slot, &t) in other.slots.iter().zip(&other.busy) {
            match self.slots.iter().position(|s| s == slot) {
                Some(i) => self.busy[i] += t,
                None => {
                    self.slots.push(*slot);
                    self.busy.push(t);
                }
            }
        }
        self.elapsed += other.elapsed;
    }
}

/// One completed task: (seq, partial outputs, wall seconds on its worker).
pub type TaskResult = (usize, Vec<ArgValue>, f64);

/// Everything one concurrent drain produced.
pub struct LaunchOutput {
    /// Partial outputs sorted by task `seq` (unit order), each paired with
    /// the wall time measured on the worker that ran it.
    pub partials: Vec<TaskResult>,
    pub clock: SlotClock,
    /// Tasks executed by a slot other than the one they were queued on.
    pub stolen: u64,
}

impl LaunchOutput {
    /// The seq-sorted partial outputs alone.
    pub fn into_outputs(self) -> Vec<Vec<ArgValue>> {
        self.partials.into_iter().map(|(_, o, _)| o).collect()
    }
}

/// Drain the queues concurrently: one scoped worker thread per queue, local
/// front pops then back-of-longest-queue steals. The first task error stops
/// every worker and is returned; partials are seq-sorted on return.
pub fn launch<R: TaskRunner>(queues: WorkQueues, runner: &R) -> Result<LaunchOutput> {
    let n = queues.n_queues();
    if n == 0 {
        return Ok(LaunchOutput {
            partials: Vec::new(),
            clock: SlotClock::default(),
            stolen: 0,
        });
    }
    let slots: Vec<ExecSlot> = (0..n).map(|i| queues.slot(i)).collect();
    let shared: SharedQueues = queues.into_shared();
    let results: Mutex<Vec<TaskResult>> = Mutex::new(Vec::new());
    let failure: Mutex<Option<crate::error::Error>> = Mutex::new(None);
    let stop = AtomicBool::new(false);
    let stolen = AtomicU64::new(0);

    let t0 = Instant::now();
    let busy: Vec<f64> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let shared = &shared;
                let results = &results;
                let failure = &failure;
                let stop = &stop;
                let stolen = &stolen;
                scope.spawn(move || {
                    let mut busy = 0.0f64;
                    loop {
                        if stop.load(Ordering::Relaxed) {
                            break;
                        }
                        let task = match shared.pop_local(i) {
                            Some(t) => t,
                            None => match shared.steal(i) {
                                Some(t) => {
                                    stolen.fetch_add(1, Ordering::Relaxed);
                                    t
                                }
                                None => break,
                            },
                        };
                        let start = Instant::now();
                        match runner.run_task(shared.slot(i), &task) {
                            Ok(out) => {
                                let dt = out
                                    .busy
                                    .unwrap_or_else(|| start.elapsed().as_secs_f64());
                                busy += dt;
                                results.lock().unwrap().push((task.seq, out.outputs, dt));
                            }
                            Err(e) => {
                                let mut f = failure.lock().unwrap();
                                if f.is_none() {
                                    *f = Some(e);
                                }
                                stop.store(true, Ordering::Relaxed);
                                break;
                            }
                        }
                    }
                    busy
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let elapsed = t0.elapsed().as_secs_f64();

    if let Some(e) = failure.into_inner().unwrap() {
        return Err(e);
    }
    let mut partials = results.into_inner().unwrap();
    partials.sort_by_key(|(seq, _, _)| *seq);
    Ok(LaunchOutput {
        partials,
        clock: SlotClock {
            slots,
            busy,
            elapsed,
        },
        stolen: stolen.into_inner(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decompose::{Partition, PartitionPlan};
    use crate::error::Error;
    use std::time::Duration;

    fn two_slot_plan(gpu_units: u64, cpu_units: u64) -> PartitionPlan {
        PartitionPlan {
            partitions: vec![
                Partition {
                    slot: ExecSlot::GpuSlot { gpu: 0, slot: 0 },
                    start_unit: 0,
                    units: gpu_units,
                },
                Partition {
                    slot: ExecSlot::CpuSub { idx: 0 },
                    start_unit: gpu_units,
                    units: cpu_units,
                },
            ],
            quantum: 1,
            gpu_share: gpu_units as f64 / (gpu_units + cpu_units) as f64,
        }
    }

    /// Runner that sleeps `per_unit_ms` per task unit and returns the
    /// task's start_unit as a marker output.
    struct Sleepy(u64);

    impl TaskRunner for Sleepy {
        fn run_task(&self, _slot: ExecSlot, task: &Task) -> Result<TaskOutput> {
            std::thread::sleep(Duration::from_millis(self.0 * task.partition.units));
            Ok(vec![ArgValue::F32(vec![task.partition.start_unit as f32])].into())
        }
    }

    fn sleepy(per_unit_ms: u64) -> Sleepy {
        Sleepy(per_unit_ms)
    }

    #[test]
    fn partials_come_back_in_seq_order() {
        // GPU task (seq 0) is 8x slower than the CPU task (seq 1): the CPU
        // partial lands first, but the output must still be seq-sorted.
        let p = two_slot_plan(8, 1);
        let out = launch(WorkQueues::from_plan(&p), &sleepy(5)).unwrap();
        let starts: Vec<f32> = out
            .partials
            .iter()
            .map(|(_, o, _)| o[0].as_f32().unwrap()[0])
            .collect();
        assert_eq!(starts, vec![0.0, 8.0]);
    }

    #[test]
    fn times_stay_paired_with_their_slot_under_out_of_order_completion() {
        // Regression for the serial launcher's attribution bug: partials
        // were seq-sorted while times stayed in drain order, so a fast CPU
        // slice completing before a slow GPU slice swapped their clocks.
        // Here the GPU slot does 40ms of work and the CPU slot 5ms; the
        // classification must reflect that no matter the completion order.
        let p = two_slot_plan(8, 1);
        let out = launch(WorkQueues::from_plan(&p), &sleepy(5)).unwrap();
        assert!(
            out.clock.gpu_time() > out.clock.cpu_time(),
            "gpu {} must exceed cpu {}",
            out.clock.gpu_time(),
            out.clock.cpu_time()
        );
        assert!(out.clock.gpu_time() >= 0.030);
        assert!(out.clock.cpu_time() < 0.030);
        // And the per-task times are paired with seq: seq 0 (gpu) is the
        // slow one even though it completed last.
        assert!(out.partials[0].2 > out.partials[1].2);
    }

    #[test]
    fn hybrid_drain_overlaps_slots() {
        // 4 slots x 20ms each: a serial launcher needs >= 80ms; concurrent
        // workers finish in roughly one task time.
        let p = PartitionPlan {
            partitions: (0..4)
                .map(|i| Partition {
                    slot: if i < 2 {
                        ExecSlot::CpuSub { idx: i as u32 }
                    } else {
                        ExecSlot::GpuSlot {
                            gpu: 0,
                            slot: i as u32 - 2,
                        }
                    },
                    start_unit: i * 4,
                    units: 4,
                })
                .collect(),
            quantum: 1,
            gpu_share: 0.5,
        };
        let out = launch(WorkQueues::from_plan(&p), &sleepy(5)).unwrap();
        let serial_sum: f64 = out.clock.busy.iter().sum();
        assert!(
            out.clock.elapsed < 0.75 * serial_sum,
            "no overlap: elapsed {} vs serial {}",
            out.clock.elapsed,
            serial_sum
        );
    }

    #[test]
    fn idle_slots_steal_from_the_longest_queue() {
        // One overloaded slot with 8 stealable tasks, one idle peer.
        let p = two_slot_plan(64, 8);
        let queues = WorkQueues::from_plan_chunked(&p, 8);
        assert!(queues.n_tasks() >= 9);
        let out = launch(queues, &sleepy(1)).unwrap();
        assert!(out.stolen > 0, "idle slot must have stolen work");
        // Every task completed exactly once, seq-sorted.
        let seqs: Vec<usize> = out.partials.iter().map(|(s, _, _)| *s).collect();
        assert_eq!(seqs, (0..seqs.len()).collect::<Vec<_>>());
    }

    struct FailPast(u64);

    impl TaskRunner for FailPast {
        fn run_task(&self, _slot: ExecSlot, task: &Task) -> Result<TaskOutput> {
            if task.partition.start_unit >= self.0 {
                Err(Error::Runtime("injected".into()))
            } else {
                std::thread::sleep(Duration::from_millis(1));
                Ok(vec![ArgValue::F32(vec![0.0])].into())
            }
        }
    }

    #[test]
    fn first_error_stops_the_drain() {
        let p = two_slot_plan(4, 4);
        let queues = WorkQueues::from_plan_chunked(&p, 4);
        let err = launch(queues, &FailPast(4)).unwrap_err();
        assert!(format!("{err}").contains("injected"));
    }

    #[test]
    fn clock_accumulates_across_iterations() {
        let mut acc = SlotClock::default();
        let a = SlotClock {
            slots: vec![ExecSlot::CpuSub { idx: 0 }, ExecSlot::GpuSlot { gpu: 0, slot: 0 }],
            busy: vec![1.0, 2.0],
            elapsed: 2.0,
        };
        acc.accumulate(&a);
        acc.accumulate(&a);
        assert_eq!(acc.busy, vec![2.0, 4.0]);
        assert_eq!(acc.elapsed, 4.0);
        assert_eq!(acc.cpu_time(), 2.0);
        assert_eq!(acc.gpu_time(), 4.0);
    }
}
