//! The concurrent task launcher (Section 2.2): per-slot worker threads
//! drain the work queues simultaneously, so hybrid CPU/GPU executions
//! genuinely overlap — the request's completion time is the wall clock of
//! the slowest *concurrent* slot, not a serial sum of per-task slices.
//!
//! Each worker owns one queue (front pops preserve unit order) and steals
//! from the back of the longest other queue once its own runs dry. Per-task
//! wall times are measured on the worker that ran the task and stay paired
//! with the task's `seq`, so partial results and their timings can never
//! drift apart (the drain-order/plan-order mismatch the serial launcher
//! suffered from). Per-slot busy clocks feed the execution monitor.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::data::vector::ArgValue;
use crate::decompose::graph::{NodeKind, TaskGraph, TaskNode};
use crate::decompose::ExecSlot;
use crate::error::Result;
use crate::runtime::residency::ResidencyView;
use crate::scheduler::queues::{ReadyQueues, SharedQueues, Task, WorkQueues};
use crate::scheduler::reservation::SlotMask;

/// One slot-execution engine the launcher drives: runs a single task and
/// returns its partial outputs. Implementations decide how much real
/// parallelism the backend tolerates (the PJRT binding serializes launches
/// behind the client's gate; the stub and the tests run fully parallel).
pub trait TaskRunner: Sync {
    fn run_task(&self, slot: ExecSlot, task: &Task) -> Result<TaskOutput>;
}

/// One task's outputs, plus an optional self-measured execution time.
pub struct TaskOutput {
    pub outputs: Vec<ArgValue>,
    /// Execution seconds as measured by the runner itself, *excluding* any
    /// serialization wait it imposed (e.g. the PJRT launch gate) — lock
    /// waits in a busy clock would make every slot look equally slow and
    /// blind the balance monitor. `None` lets the launcher's own wall
    /// measurement stand (right for runners with no internal locking).
    pub busy: Option<f64>,
}

impl From<Vec<ArgValue>> for TaskOutput {
    fn from(outputs: Vec<ArgValue>) -> TaskOutput {
        TaskOutput {
            outputs,
            busy: None,
        }
    }
}

/// Per-slot wall clocks of one concurrent drain.
#[derive(Clone, Debug, Default)]
pub struct SlotClock {
    /// The slot owning each queue (stable across iterations of a Loop).
    pub slots: Vec<ExecSlot>,
    /// Busy seconds accumulated by each slot's worker.
    pub busy: Vec<f64>,
    /// Wall-clock seconds of the whole concurrent drain — with real
    /// overlap this is (close to) the *max* over slots, not their sum.
    pub elapsed: f64,
}

impl SlotClock {
    fn max_busy<F: Fn(&ExecSlot) -> bool>(&self, pred: F) -> f64 {
        self.slots
            .iter()
            .zip(&self.busy)
            .filter(|(s, _)| pred(s))
            .map(|(_, &t)| t)
            .fold(0.0, f64::max)
    }

    /// Completion time of the CPU device type: max busy over CPU slots.
    pub fn cpu_time(&self) -> f64 {
        self.max_busy(|s| s.is_cpu())
    }

    /// Completion time of the GPU device type: max busy over GPU slots.
    pub fn gpu_time(&self) -> f64 {
        self.max_busy(|s| !s.is_cpu())
    }

    /// Per-slot times of the active slots (busy > 0), for the monitor.
    pub fn active_times(&self) -> Vec<f64> {
        self.busy.iter().copied().filter(|&t| t > 0.0).collect()
    }

    /// Fold another drain's clocks in (Loop iterations re-drain the same
    /// queues, so slots align by identity).
    pub fn accumulate(&mut self, other: &SlotClock) {
        if self.slots.is_empty() {
            self.slots = other.slots.clone();
            self.busy = vec![0.0; other.busy.len()];
        }
        for (slot, &t) in other.slots.iter().zip(&other.busy) {
            match self.slots.iter().position(|s| s == slot) {
                Some(i) => self.busy[i] += t,
                None => {
                    self.slots.push(*slot);
                    self.busy.push(t);
                }
            }
        }
        self.elapsed += other.elapsed;
    }
}

/// One completed task: (seq, partial outputs, wall seconds on its worker).
pub type TaskResult = (usize, Vec<ArgValue>, f64);

/// Everything one concurrent drain produced.
pub struct LaunchOutput {
    /// Partial outputs sorted by task `seq` (unit order), each paired with
    /// the wall time measured on the worker that ran it.
    pub partials: Vec<TaskResult>,
    pub clock: SlotClock,
    /// Tasks executed by a slot other than the one they were queued on.
    pub stolen: u64,
    /// Steal candidates rejected because the estimated migration cost
    /// exceeded the expected wait (locality-aware stealing only).
    pub steals_skipped: u64,
}

/// Locality-aware steal pricing (DESIGN.md §2.6): a thief only takes a
/// task when moving its resident data costs less than waiting for the
/// victim to drain it locally.
pub struct StealPolicy<'p> {
    /// Where the task's data lives (the scheduler's residency pool).
    pub residency: &'p dyn ResidencyView,
    /// Seconds to migrate one byte across devices (1 / link bytes-per-sec;
    /// see [`crate::runtime::residency::migration_secs`]).
    pub secs_per_byte: f64,
    /// Expected seconds per queued task before any task has completed
    /// (afterwards the drain's measured mean is used).
    pub default_task_secs: f64,
}

/// Knobs of one concurrent drain.
#[derive(Default)]
pub struct LaunchOpts<'p> {
    /// When set, steals are admitted by migration cost vs expected wait
    /// and booked against the residency pool; when `None`, stealing is
    /// unconditional (the PR-2 behavior).
    pub policy: Option<StealPolicy<'p>>,
    /// Reservation boundary (DESIGN.md §2.8): when set, workers exist only
    /// for slots inside the mask, so no steal can cross into (or execute
    /// on) a device another request has reserved. `None` drains on every
    /// slot the plan names.
    pub mask: Option<SlotMask>,
    /// Pin each CPU worker to the core matching its slot index before it
    /// drains (native backend, DESIGN.md §2.11): with the pin in place,
    /// per-slot residency and steal pricing describe physical caches.
    /// Best-effort — unsupported platforms drain unpinned.
    pub pin_cores: bool,
    /// Graph-drain lookahead (DESIGN.md §2.12): when a worker would
    /// otherwise park, it stages inputs for up to this many upcoming nodes
    /// homed on its slot ([`crate::decompose::graph::TaskGraph::prefetch_horizon`])
    /// via [`GraphRunner::prefetch_node`], hiding uploads under other
    /// slots' compute. 0 disables prefetch (the pre-PR-9 behavior);
    /// barrier drains ignore it.
    pub prefetch_depth: u32,
}

impl LaunchOutput {
    /// The seq-sorted partial outputs alone.
    pub fn into_outputs(self) -> Vec<Vec<ArgValue>> {
        self.partials.into_iter().map(|(_, o, _)| o).collect()
    }
}

/// Drain the queues concurrently with unconditional stealing (see
/// [`launch_with`] for the locality-aware variant).
pub fn launch<R: TaskRunner>(queues: WorkQueues, runner: &R) -> Result<LaunchOutput> {
    launch_with(queues, runner, LaunchOpts::default())
}

/// Drain the queues concurrently: one scoped worker thread per queue, local
/// front pops then back-of-longest-queue steals. With a [`StealPolicy`], a
/// thief prices each steal candidate — estimated migration cost (the
/// task's bytes resident on its home slot, free between same-device slots)
/// against the expected wait for the victim to drain it (queue length x
/// the drain's measured mean task time) — books admitted migrations
/// against the residency pool, and skips candidates not worth moving. The
/// first task error stops every worker and is returned; partials are
/// seq-sorted on return.
pub fn launch_with<R: TaskRunner>(
    mut queues: WorkQueues,
    runner: &R,
    opts: LaunchOpts<'_>,
) -> Result<LaunchOutput> {
    if let Some(mask) = &opts.mask {
        queues.restrict(mask);
    }
    let n = queues.n_queues();
    if n == 0 {
        return Ok(LaunchOutput {
            partials: Vec::new(),
            clock: SlotClock::default(),
            stolen: 0,
            steals_skipped: 0,
        });
    }
    let slots: Vec<ExecSlot> = (0..n).map(|i| queues.slot(i)).collect();
    let shared: SharedQueues = queues.into_shared();
    let results: Mutex<Vec<TaskResult>> = Mutex::new(Vec::new());
    let failure: Mutex<Option<crate::error::Error>> = Mutex::new(None);
    let stop = AtomicBool::new(false);
    let stolen = AtomicU64::new(0);
    let steals_skipped = AtomicU64::new(0);
    // Mean task duration of this drain (nanoseconds / completions): the
    // expected-wait side of the steal pricing.
    let task_nanos = AtomicU64::new(0);
    let task_count = AtomicU64::new(0);
    let opts = &opts;

    let t0 = Instant::now();
    let busy: Vec<f64> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let shared = &shared;
                let results = &results;
                let failure = &failure;
                let stop = &stop;
                let stolen = &stolen;
                let steals_skipped = &steals_skipped;
                let task_nanos = &task_nanos;
                let task_count = &task_count;
                scope.spawn(move || {
                    let my_slot = shared.slot(i);
                    if opts.pin_cores {
                        if let ExecSlot::CpuSub { idx } = my_slot {
                            crate::runtime::native::affinity::pin_current_thread(idx as usize);
                        }
                    }
                    let mut busy = 0.0f64;
                    loop {
                        if stop.load(Ordering::Relaxed) {
                            break;
                        }
                        let task = match shared.pop_local(i) {
                            Some(t) => Some(t),
                            None => match &opts.policy {
                                None => shared.steal(i),
                                Some(pol) => {
                                    let done = task_count.load(Ordering::Relaxed);
                                    let avg_secs = if done > 0 {
                                        task_nanos.load(Ordering::Relaxed) as f64
                                            / done as f64
                                            * 1e-9
                                    } else {
                                        pol.default_task_secs
                                    };
                                    let out = shared.steal_where(i, |t, victim_len| {
                                        let p = &t.partition;
                                        let bytes = if p.slot.same_device(&my_slot) {
                                            0
                                        } else {
                                            pol.residency.resident_range_bytes(
                                                p.slot,
                                                p.start_unit,
                                                p.units,
                                            )
                                        };
                                        let migration = bytes as f64 * pol.secs_per_byte;
                                        migration <= victim_len as f64 * avg_secs
                                    });
                                    if out.skipped > 0 {
                                        steals_skipped.fetch_add(out.skipped, Ordering::Relaxed);
                                        for _ in 0..out.skipped {
                                            pol.residency.note_steal_skipped();
                                        }
                                    }
                                    if let Some(t) = &out.task {
                                        let p = &t.partition;
                                        if !p.slot.same_device(&my_slot) {
                                            pol.residency.note_migration(
                                                p.slot,
                                                my_slot,
                                                p.start_unit,
                                                p.units,
                                            );
                                        }
                                    }
                                    out.task
                                }
                            },
                        };
                        let task = match task {
                            Some(t) => {
                                if t.partition.slot != my_slot {
                                    stolen.fetch_add(1, Ordering::Relaxed);
                                }
                                t
                            }
                            None => break,
                        };
                        let start = Instant::now();
                        match runner.run_task(my_slot, &task) {
                            Ok(out) => {
                                let dt = out
                                    .busy
                                    .unwrap_or_else(|| start.elapsed().as_secs_f64());
                                busy += dt;
                                task_nanos.fetch_add((dt * 1e9) as u64, Ordering::Relaxed);
                                task_count.fetch_add(1, Ordering::Relaxed);
                                results.lock().unwrap().push((task.seq, out.outputs, dt));
                            }
                            Err(e) => {
                                let mut f = failure.lock().unwrap();
                                if f.is_none() {
                                    *f = Some(e);
                                }
                                stop.store(true, Ordering::Relaxed);
                                break;
                            }
                        }
                    }
                    busy
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let elapsed = t0.elapsed().as_secs_f64();

    if let Some(e) = failure.into_inner().unwrap() {
        return Err(e);
    }
    let mut partials = results.into_inner().unwrap();
    partials.sort_by_key(|(seq, _, _)| *seq);
    Ok(LaunchOutput {
        partials,
        clock: SlotClock {
            slots,
            busy,
            elapsed,
        },
        stolen: stolen.into_inner(),
        steals_skipped: steals_skipped.into_inner(),
    })
}

/// What a sync node decided about the rest of the request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SyncVerdict {
    /// Release the downstream stages.
    Continue,
    /// Stoppage condition hit: cancel every node past this sync.
    Break,
}

/// A sync node's result.
pub struct SyncOutcome {
    pub verdict: SyncVerdict,
    /// Whole-request outputs produced by the sync: a reduction's folded
    /// result, or a `Loop`'s concatenated body outputs when the sync ends
    /// the request (sink or `Break`). `None` lets the final compute
    /// stage's chunk partials stand.
    pub outputs: Option<Vec<ArgValue>>,
}

/// The engine the dataflow drain drives: per-node chunk execution, host
/// sync points, and optional incremental absorption of partials.
pub trait GraphRunner: Sync {
    /// Run one compute node on `slot`. `carried` is the producer chunk's
    /// outputs when the node's stage chains a pipeline intermediate.
    fn run_node(
        &self,
        slot: ExecSlot,
        node: &TaskNode,
        carried: Option<&[ArgValue]>,
    ) -> Result<TaskOutput>;

    /// Incrementally absorb a completed node's outputs (e.g. fold a
    /// reduction partial the moment the sibling chunk retires, instead of
    /// once per stage). Return `true` when absorbed — the launcher then
    /// drops the buffers instead of slabbing them for the downstream sync.
    fn absorb(&self, node: &TaskNode, outputs: &[ArgValue]) -> Result<bool> {
        let _ = (node, outputs);
        Ok(false)
    }

    /// Run a sync node host-side. `gathered` holds the non-absorbed
    /// dependency outputs in seq (unit) order; `is_sink` marks the
    /// request's final node.
    fn run_sync(
        &self,
        node: &TaskNode,
        gathered: &[(usize, Arc<Vec<ArgValue>>)],
        is_sink: bool,
    ) -> Result<SyncOutcome>;

    /// A produced intermediate's last consumer retired — release whatever
    /// the runner pinned for it (residency refcount hook).
    fn retire_output(&self, node: &TaskNode) {
        let _ = node;
    }

    /// Stage `node`'s inputs ahead of need on `slot` (the prefetch
    /// pipeline, DESIGN.md §2.12). Called by a worker that would otherwise
    /// park, never for a node that is already ready on a queue. Best
    /// effort: a runner that cannot prefetch simply ignores the token, and
    /// errors must be swallowed — a failed prefetch falls back to the
    /// synchronous stage when the node actually runs.
    fn prefetch_node(&self, slot: ExecSlot, node: &TaskNode) {
        let _ = (slot, node);
    }
}

/// Everything one dataflow drain produced.
pub struct GraphOutput {
    /// Final-frontier chunk partials in seq (unit) order — empty when a
    /// sync node produced `outputs` instead.
    pub partials: Vec<(usize, Vec<ArgValue>)>,
    /// Whole-request outputs a sync node produced (reductions, loop ends).
    pub outputs: Option<Vec<ArgValue>>,
    pub clock: SlotClock,
    pub stolen: u64,
    pub steals_skipped: u64,
    /// Nodes actually executed (cancelled nodes past a `Break` excluded).
    pub executed: u64,
}

/// Drain a task graph with dependency-driven scheduling: per-slot ready
/// deques admit a node when its dependency count hits zero; completions
/// decrement consumers and wake parked workers; idle workers steal from
/// the back of the longest ready deque. With a [`StealPolicy`], a steal
/// candidate is priced against the *graph critical path*: its resident
/// bytes on the home device are charged once for the node itself plus once
/// per consumer chunk homed on the same device (their carried input now
/// lands on the thief and must migrate too). Only sync nodes barrier; the
/// first error stops every worker.
pub fn launch_graph<R: GraphRunner>(
    graph: &TaskGraph,
    runner: &R,
    opts: LaunchOpts<'_>,
) -> Result<GraphOutput> {
    let n = graph.n_nodes();
    if n == 0 {
        return Ok(GraphOutput {
            partials: Vec::new(),
            outputs: None,
            clock: SlotClock::default(),
            stolen: 0,
            steals_skipped: 0,
            executed: 0,
        });
    }
    let mut node_slots: Vec<ExecSlot> =
        graph.nodes.iter().map(|nd| nd.partition.slot).collect();
    // Reservation boundary: only slots inside the mask get a ready deque
    // (and a worker). Nodes homed outside — a plan that routed units past
    // the mask — fall back to queue 0 via `queue_of`, so they still run,
    // on a granted slot. An all-excluding mask is ignored: an empty
    // reservation cannot drain a graph.
    if let Some(mask) = &opts.mask {
        if node_slots.iter().any(|s| mask.allows(s)) {
            node_slots.retain(|s| mask.allows(s));
        }
    }
    let ready = ReadyQueues::new(&node_slots);
    let nq = ready.n_queues();
    let home: Vec<usize> = graph
        .nodes
        .iter()
        .map(|nd| ready.queue_of(nd.partition.slot))
        .collect();
    let indeg: Vec<AtomicUsize> = graph
        .deps
        .iter()
        .map(|d| AtomicUsize::new(d.len()))
        .collect();
    // Per-node remaining-consumer refcounts: an intermediate is dropped
    // (and the runner's pin released) when its last consumer retires.
    let pending: Vec<AtomicUsize> = graph
        .consumers
        .iter()
        .map(|c| AtomicUsize::new(c.len()))
        .collect();
    let slab: Vec<Mutex<Option<Arc<Vec<ArgValue>>>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    for (i, d) in graph.deps.iter().enumerate() {
        if d.is_empty() {
            ready.push(home[i], i);
        }
    }
    let retired = AtomicUsize::new(0);
    let executed = AtomicU64::new(0);
    let stop = AtomicBool::new(false);
    let failure: Mutex<Option<crate::error::Error>> = Mutex::new(None);
    let final_outputs: Mutex<Option<Vec<ArgValue>>> = Mutex::new(None);
    let stolen = AtomicU64::new(0);
    let steals_skipped = AtomicU64::new(0);
    let task_nanos = AtomicU64::new(0);
    let task_count = AtomicU64::new(0);
    let opts = &opts;

    let t0 = Instant::now();
    let busy: Vec<f64> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..nq)
            .map(|i| {
                let ready = &ready;
                let home = &home;
                let indeg = &indeg;
                let pending = &pending;
                let slab = &slab;
                let retired = &retired;
                let executed = &executed;
                let stop = &stop;
                let failure = &failure;
                let final_outputs = &final_outputs;
                let stolen = &stolen;
                let steals_skipped = &steals_skipped;
                let task_nanos = &task_nanos;
                let task_count = &task_count;
                scope.spawn(move || {
                    let my_slot = ready.slot(i);
                    if opts.pin_cores {
                        if let ExecSlot::CpuSub { idx } = my_slot {
                            crate::runtime::native::affinity::pin_current_thread(idx as usize);
                        }
                    }
                    let mut busy = 0.0f64;
                    // Node ids this worker already issued prefetch tokens
                    // for (the pool is idempotent; this just skips the
                    // re-staging work on repeated parks).
                    let mut prefetched: std::collections::HashSet<usize> =
                        std::collections::HashSet::new();
                    loop {
                        if stop.load(Ordering::Relaxed)
                            || retired.load(Ordering::Relaxed) >= n
                        {
                            ready.wake_all();
                            break;
                        }
                        let epoch = ready.epoch();
                        let id = match ready.pop_local(i) {
                            Some(t) => Some(t),
                            None => {
                                let admit = |cand: usize, victim_len: usize| -> bool {
                                    let nd = &graph.nodes[cand];
                                    // Sync nodes are host work: free to move.
                                    if nd.kind == NodeKind::Sync {
                                        return true;
                                    }
                                    let pol = match &opts.policy {
                                        None => return true,
                                        Some(p) => p,
                                    };
                                    let p = &nd.partition;
                                    if p.slot.same_device(&my_slot) {
                                        return true;
                                    }
                                    let done = task_count.load(Ordering::Relaxed);
                                    let avg = if done > 0 {
                                        task_nanos.load(Ordering::Relaxed) as f64
                                            / done as f64
                                            * 1e-9
                                    } else {
                                        pol.default_task_secs
                                    };
                                    // Critical-path pricing: every consumer
                                    // chunk homed on the victim's device
                                    // will have to migrate its carried
                                    // input too once this node's output
                                    // lands on the thief.
                                    let downstream = graph.consumers[cand]
                                        .iter()
                                        .filter(|&&c| {
                                            let cn = &graph.nodes[c];
                                            cn.kind == NodeKind::Compute
                                                && cn.carried_from == Some(cand)
                                                && cn.partition.slot.same_device(&p.slot)
                                        })
                                        .count()
                                        as u64;
                                    let bytes = pol
                                        .residency
                                        .resident_range_bytes(p.slot, p.start_unit, p.units)
                                        .saturating_mul(1 + downstream);
                                    let migration = bytes as f64 * pol.secs_per_byte;
                                    migration <= victim_len as f64 * avg
                                };
                                let (t, skipped) = ready.steal_where(i, admit);
                                if skipped > 0 {
                                    steals_skipped.fetch_add(skipped, Ordering::Relaxed);
                                    if let Some(pol) = &opts.policy {
                                        for _ in 0..skipped {
                                            pol.residency.note_steal_skipped();
                                        }
                                    }
                                }
                                if let Some(id) = t {
                                    let nd = &graph.nodes[id];
                                    if nd.kind == NodeKind::Compute
                                        && !nd.partition.slot.same_device(&my_slot)
                                    {
                                        if let Some(pol) = &opts.policy {
                                            pol.residency.note_migration(
                                                nd.partition.slot,
                                                my_slot,
                                                nd.partition.start_unit,
                                                nd.partition.units,
                                            );
                                        }
                                    }
                                }
                                t
                            }
                        };
                        let id = match id {
                            Some(id) => id,
                            None => {
                                if stop.load(Ordering::Relaxed)
                                    || retired.load(Ordering::Relaxed) >= n
                                {
                                    ready.wake_all();
                                    break;
                                }
                                // About to park: spend the idle window
                                // staging inputs for upcoming nodes homed
                                // here (DESIGN.md §2.12), so their uploads
                                // run under other slots' compute instead of
                                // on the critical path.
                                if opts.prefetch_depth > 0 {
                                    let horizon = graph.prefetch_horizon_where(
                                        my_slot,
                                        opts.prefetch_depth,
                                        |nid| indeg[nid].load(Ordering::Relaxed) > 0,
                                    );
                                    for pid in horizon {
                                        if prefetched.insert(pid) {
                                            runner.prefetch_node(my_slot, &graph.nodes[pid]);
                                        }
                                    }
                                }
                                ready.wait_change(epoch);
                                continue;
                            }
                        };
                        let node = &graph.nodes[id];
                        if node.kind == NodeKind::Compute && node.partition.slot != my_slot {
                            stolen.fetch_add(1, Ordering::Relaxed);
                        }

                        // Run the node; any error stops the whole drain.
                        let mut broke = false;
                        let run_result: Result<()> = match node.kind {
                            NodeKind::Compute => (|| {
                                let carried: Option<Arc<Vec<ArgValue>>> =
                                    match node.carried_from {
                                        Some(p) => slab[p].lock().unwrap().clone(),
                                        None => None,
                                    };
                                let start = Instant::now();
                                let out = runner.run_node(
                                    my_slot,
                                    node,
                                    carried.as_ref().map(|c| c.as_slice()),
                                )?;
                                let dt = out
                                    .busy
                                    .unwrap_or_else(|| start.elapsed().as_secs_f64());
                                busy += dt;
                                task_nanos.fetch_add((dt * 1e9) as u64, Ordering::Relaxed);
                                task_count.fetch_add(1, Ordering::Relaxed);
                                if !runner.absorb(node, &out.outputs)? {
                                    *slab[id].lock().unwrap() =
                                        Some(Arc::new(out.outputs));
                                }
                                Ok(())
                            })(),
                            NodeKind::Sync => (|| {
                                let start = Instant::now();
                                let mut gathered: Vec<(usize, Arc<Vec<ArgValue>>)> =
                                    graph.deps[id]
                                        .iter()
                                        .filter_map(|&d| {
                                            slab[d]
                                                .lock()
                                                .unwrap()
                                                .clone()
                                                .map(|o| (graph.nodes[d].seq, o))
                                        })
                                        .collect();
                                gathered.sort_by_key(|(s, _)| *s);
                                let is_sink = graph.consumers[id].is_empty();
                                let out = runner.run_sync(node, &gathered, is_sink)?;
                                busy += start.elapsed().as_secs_f64();
                                if let Some(outs) = out.outputs {
                                    *final_outputs.lock().unwrap() = Some(outs);
                                }
                                broke = out.verdict == SyncVerdict::Break;
                                Ok(())
                            })(),
                        };
                        if let Err(e) = run_result {
                            let mut f = failure.lock().unwrap();
                            if f.is_none() {
                                *f = Some(e);
                            }
                            stop.store(true, Ordering::Relaxed);
                            ready.wake_all();
                            break;
                        }
                        executed.fetch_add(1, Ordering::Relaxed);

                        // Release the inputs this node consumed: when a
                        // producer's last consumer retires, its buffers
                        // drop and the runner unpins its residency.
                        for &d in &graph.deps[id] {
                            if pending[d].fetch_sub(1, Ordering::Relaxed) == 1 {
                                *slab[d].lock().unwrap() = None;
                                runner.retire_output(&graph.nodes[d]);
                            }
                        }
                        retired.fetch_add(1, Ordering::Relaxed);
                        if broke {
                            // Stoppage condition: every node past this sync
                            // is cancelled (none can have started — the
                            // sync gates them all transitively).
                            retired.store(n, Ordering::Relaxed);
                            ready.wake_all();
                            continue;
                        }
                        // Wake consumers whose dependency count hit zero.
                        for &c in &graph.consumers[id] {
                            if indeg[c].fetch_sub(1, Ordering::Relaxed) == 1 {
                                ready.push(home[c], c);
                            }
                        }
                        if retired.load(Ordering::Relaxed) >= n {
                            ready.wake_all();
                        }
                    }
                    busy
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let elapsed = t0.elapsed().as_secs_f64();

    if let Some(e) = failure.into_inner().unwrap() {
        return Err(e);
    }
    let outputs = final_outputs.into_inner().unwrap();
    let mut partials: Vec<(usize, Vec<ArgValue>)> = Vec::new();
    if outputs.is_none() {
        for id in graph.sinks() {
            if graph.nodes[id].kind != NodeKind::Compute {
                continue;
            }
            if let Some(o) = slab[id].lock().unwrap().take() {
                let o = Arc::try_unwrap(o).unwrap_or_else(|a| (*a).clone());
                partials.push((graph.nodes[id].seq, o));
            }
        }
        partials.sort_by_key(|(s, _)| *s);
    }
    let slots: Vec<ExecSlot> = (0..nq).map(|i| ready.slot(i)).collect();
    Ok(GraphOutput {
        partials,
        outputs,
        clock: SlotClock {
            slots,
            busy,
            elapsed,
        },
        stolen: stolen.into_inner(),
        steals_skipped: steals_skipped.into_inner(),
        executed: executed.into_inner(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decompose::{Partition, PartitionPlan};
    use crate::error::Error;
    use std::time::Duration;

    fn two_slot_plan(gpu_units: u64, cpu_units: u64) -> PartitionPlan {
        PartitionPlan {
            partitions: vec![
                Partition {
                    slot: ExecSlot::GpuSlot { gpu: 0, slot: 0 },
                    start_unit: 0,
                    units: gpu_units,
                },
                Partition {
                    slot: ExecSlot::CpuSub { idx: 0 },
                    start_unit: gpu_units,
                    units: cpu_units,
                },
            ],
            quantum: 1,
            gpu_share: gpu_units as f64 / (gpu_units + cpu_units) as f64,
        }
    }

    /// Runner that sleeps `per_unit_ms` per task unit and returns the
    /// task's start_unit as a marker output.
    struct Sleepy(u64);

    impl TaskRunner for Sleepy {
        fn run_task(&self, _slot: ExecSlot, task: &Task) -> Result<TaskOutput> {
            std::thread::sleep(Duration::from_millis(self.0 * task.partition.units));
            Ok(vec![ArgValue::F32(vec![task.partition.start_unit as f32])].into())
        }
    }

    fn sleepy(per_unit_ms: u64) -> Sleepy {
        Sleepy(per_unit_ms)
    }

    #[test]
    fn partials_come_back_in_seq_order() {
        // GPU task (seq 0) is 8x slower than the CPU task (seq 1): the CPU
        // partial lands first, but the output must still be seq-sorted.
        let p = two_slot_plan(8, 1);
        let out = launch(WorkQueues::from_plan(&p), &sleepy(5)).unwrap();
        let starts: Vec<f32> = out
            .partials
            .iter()
            .map(|(_, o, _)| o[0].as_f32().unwrap()[0])
            .collect();
        assert_eq!(starts, vec![0.0, 8.0]);
    }

    #[test]
    fn times_stay_paired_with_their_slot_under_out_of_order_completion() {
        // Regression for the serial launcher's attribution bug: partials
        // were seq-sorted while times stayed in drain order, so a fast CPU
        // slice completing before a slow GPU slice swapped their clocks.
        // Here the GPU slot does 40ms of work and the CPU slot 5ms; the
        // classification must reflect that no matter the completion order.
        let p = two_slot_plan(8, 1);
        let out = launch(WorkQueues::from_plan(&p), &sleepy(5)).unwrap();
        assert!(
            out.clock.gpu_time() > out.clock.cpu_time(),
            "gpu {} must exceed cpu {}",
            out.clock.gpu_time(),
            out.clock.cpu_time()
        );
        assert!(out.clock.gpu_time() >= 0.030);
        assert!(out.clock.cpu_time() < 0.030);
        // And the per-task times are paired with seq: seq 0 (gpu) is the
        // slow one even though it completed last.
        assert!(out.partials[0].2 > out.partials[1].2);
    }

    #[test]
    fn hybrid_drain_overlaps_slots() {
        // 4 slots x 20ms each: a serial launcher needs >= 80ms; concurrent
        // workers finish in roughly one task time.
        let p = PartitionPlan {
            partitions: (0..4)
                .map(|i| Partition {
                    slot: if i < 2 {
                        ExecSlot::CpuSub { idx: i as u32 }
                    } else {
                        ExecSlot::GpuSlot {
                            gpu: 0,
                            slot: i as u32 - 2,
                        }
                    },
                    start_unit: i * 4,
                    units: 4,
                })
                .collect(),
            quantum: 1,
            gpu_share: 0.5,
        };
        let out = launch(WorkQueues::from_plan(&p), &sleepy(5)).unwrap();
        let serial_sum: f64 = out.clock.busy.iter().sum();
        assert!(
            out.clock.elapsed < 0.75 * serial_sum,
            "no overlap: elapsed {} vs serial {}",
            out.clock.elapsed,
            serial_sum
        );
    }

    #[test]
    fn idle_slots_steal_from_the_longest_queue() {
        // One overloaded slot with 8 stealable tasks, one idle peer.
        let p = two_slot_plan(64, 8);
        let queues = WorkQueues::from_plan_chunked(&p, 8);
        assert!(queues.n_tasks() >= 9);
        let out = launch(queues, &sleepy(1)).unwrap();
        assert!(out.stolen > 0, "idle slot must have stolen work");
        // Every task completed exactly once, seq-sorted.
        let seqs: Vec<usize> = out.partials.iter().map(|(s, _, _)| *s).collect();
        assert_eq!(seqs, (0..seqs.len()).collect::<Vec<_>>());
    }

    /// A residency oracle with a fixed per-task resident byte count, and
    /// counters for the migrations/skips the launcher books against it.
    struct FakeResidency {
        bytes: u64,
        migrations: AtomicU64,
        skips: AtomicU64,
    }

    impl FakeResidency {
        fn with_bytes(bytes: u64) -> FakeResidency {
            FakeResidency {
                bytes,
                migrations: AtomicU64::new(0),
                skips: AtomicU64::new(0),
            }
        }
    }

    impl ResidencyView for FakeResidency {
        fn resident_range_bytes(&self, _slot: ExecSlot, _start: u64, _units: u64) -> u64 {
            self.bytes
        }

        fn note_migration(&self, _f: ExecSlot, _t: ExecSlot, _s: u64, _u: u64) -> u64 {
            self.migrations.fetch_add(1, Ordering::Relaxed);
            self.bytes
        }

        fn note_steal_skipped(&self) {
            self.skips.fetch_add(1, Ordering::Relaxed);
        }
    }

    #[test]
    fn steal_skipped_when_migration_cost_exceeds_expected_wait() {
        // The CPU slot idles while the GPU slot holds 8 stealable tasks
        // whose data is (per the oracle) fully resident on the GPU: with a
        // migration price far above the expected wait, the thief must
        // leave the work where its data lives.
        let p = two_slot_plan(64, 8);
        let queues = WorkQueues::from_plan_chunked(&p, 8);
        let residency = FakeResidency::with_bytes(1 << 30);
        let out = launch_with(
            queues,
            &sleepy(1),
            LaunchOpts {
                policy: Some(StealPolicy {
                    residency: &residency,
                    secs_per_byte: 1.0, // 1 GiB "costs" ~1e9 s to move
                    default_task_secs: 1e-6,
                }),
                mask: None,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(out.stolen, 0, "no task may migrate away from its data");
        assert!(out.steals_skipped > 0, "the rejected candidates must be counted");
        assert_eq!(residency.migrations.load(Ordering::Relaxed), 0);
        assert_eq!(
            residency.skips.load(Ordering::Relaxed),
            out.steals_skipped,
            "skips are booked against the pool"
        );
        // The drain still completes: every task ran on its home slot.
        assert_eq!(out.partials.len(), 16);
    }

    #[test]
    fn steal_booked_as_migration_when_cheaper_than_waiting() {
        // Same shape, but migration is free per the oracle's pricing: the
        // idle CPU slot must steal GPU-homed tasks and every cross-device
        // steal must be booked against the pool.
        let p = two_slot_plan(64, 8);
        let queues = WorkQueues::from_plan_chunked(&p, 8);
        let residency = FakeResidency::with_bytes(64);
        let out = launch_with(
            queues,
            &sleepy(1),
            LaunchOpts {
                policy: Some(StealPolicy {
                    residency: &residency,
                    secs_per_byte: 1e-12,
                    default_task_secs: 0.05,
                }),
                mask: None,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(out.stolen > 0, "cheap migrations must be admitted");
        assert!(
            residency.migrations.load(Ordering::Relaxed) >= out.stolen,
            "every cross-device steal books a migration"
        );
        // Every task still completes exactly once, seq-sorted.
        let seqs: Vec<usize> = out.partials.iter().map(|(s, _, _)| *s).collect();
        assert_eq!(seqs, (0..seqs.len()).collect::<Vec<_>>());
    }

    struct FailPast(u64);

    impl TaskRunner for FailPast {
        fn run_task(&self, _slot: ExecSlot, task: &Task) -> Result<TaskOutput> {
            if task.partition.start_unit >= self.0 {
                Err(Error::Runtime("injected".into()))
            } else {
                std::thread::sleep(Duration::from_millis(1));
                Ok(vec![ArgValue::F32(vec![0.0])].into())
            }
        }
    }

    #[test]
    fn first_error_stops_the_drain() {
        let p = two_slot_plan(4, 4);
        let queues = WorkQueues::from_plan_chunked(&p, 4);
        let err = launch(queues, &FailPast(4)).unwrap_err();
        assert!(format!("{err}").contains("injected"));
    }

    mod graph {
        use super::two_slot_plan;
        use crate::data::vector::ArgValue;
        use crate::decompose::graph::{build_graph, flatten_stages};
        use crate::decompose::ExecSlot;
        use crate::error::{Error, Result};
        use crate::scheduler::launcher::{
            launch_graph, GraphRunner, LaunchOpts, SyncOutcome, SyncVerdict, TaskOutput,
        };
        use crate::sct::{KernelSpec, ParamSpec, Sct};
        use std::sync::atomic::{AtomicU64, Ordering};
        use std::sync::Arc;

        fn kernel(name: &str) -> Sct {
            Sct::kernel(KernelSpec::new(name, vec![ParamSpec::VecIn], 1))
        }

        /// Stage s maps each element x -> x + 1; stage 0 seeds from the
        /// chunk's unit indices. The final frontier must therefore hold
        /// `unit + n_stages` — and only if every chunk chained through its
        /// own producers in order.
        struct StageAdder;

        impl GraphRunner for StageAdder {
            fn run_node(
                &self,
                _slot: ExecSlot,
                node: &crate::decompose::graph::TaskNode,
                carried: Option<&[ArgValue]>,
            ) -> Result<TaskOutput> {
                let base: Vec<f32> = match carried {
                    Some(c) => c[0].as_f32()?.to_vec(),
                    None => (node.partition.start_unit
                        ..node.partition.start_unit + node.partition.units)
                        .map(|u| u as f32)
                        .collect(),
                };
                Ok(vec![ArgValue::F32(base.iter().map(|x| x + 1.0).collect())].into())
            }

            fn run_sync(
                &self,
                _node: &crate::decompose::graph::TaskNode,
                _gathered: &[(usize, Arc<Vec<ArgValue>>)],
                _is_sink: bool,
            ) -> Result<SyncOutcome> {
                Ok(SyncOutcome {
                    verdict: SyncVerdict::Continue,
                    outputs: None,
                })
            }
        }

        #[test]
        fn pipeline_chunks_chain_through_their_own_producers() {
            let sct = Sct::pipeline(vec![kernel("a"), kernel("b"), kernel("c")]);
            let plan = two_slot_plan(8, 8);
            let stages = flatten_stages(&sct).unwrap();
            let graph = build_graph(&stages, &plan, 2).unwrap();
            assert!(graph.n_nodes() >= 3 * 2, "3 stages x >= 2 chunks");
            let out = launch_graph(&graph, &StageAdder, LaunchOpts::default()).unwrap();
            assert!(out.outputs.is_none());
            let mut vals = Vec::new();
            for (_, o) in &out.partials {
                vals.extend_from_slice(o[0].as_f32().unwrap());
            }
            let want: Vec<f32> = (0..16).map(|u| u as f32 + 3.0).collect();
            assert_eq!(vals, want);
            assert_eq!(out.executed as usize, graph.n_nodes());
        }

        /// Batch fusion end to end (DESIGN.md §2.10): two distinct stage
        /// programs fused into one graph drain through the same ready-set
        /// scheduler, and the per-member disassembly is bit-identical to
        /// each member's solo run — fusion changes scheduling, never
        /// results.
        #[test]
        fn fused_members_drain_together_and_disassemble_bit_identically() {
            use crate::decompose::graph::fuse_graphs;
            let a_sct = Sct::pipeline(vec![kernel("a"), kernel("b")]);
            let b_sct = kernel("c");
            let plan_a = two_slot_plan(8, 8);
            let plan_b = two_slot_plan(4, 4);
            let ga = build_graph(&flatten_stages(&a_sct).unwrap(), &plan_a, 2).unwrap();
            let gb = build_graph(&flatten_stages(&b_sct).unwrap(), &plan_b, 2).unwrap();
            let solo_a = launch_graph(&ga, &StageAdder, LaunchOpts::default()).unwrap();
            let solo_b = launch_graph(&gb, &StageAdder, LaunchOpts::default()).unwrap();
            let fused = fuse_graphs(vec![ga, gb]).unwrap();
            let out = launch_graph(&fused.graph, &StageAdder, LaunchOpts::default()).unwrap();
            assert!(out.outputs.is_none());
            assert_eq!(out.executed as usize, fused.graph.n_nodes());
            let members = fused.split_partials(&out.partials);
            assert_eq!(members.len(), 2);
            for (got, want) in members.iter().zip([&solo_a.partials, &solo_b.partials]) {
                assert_eq!(got.len(), want.len(), "per-member chunk count");
                for ((gs, gv), (ws, wv)) in got.iter().zip(want.iter()) {
                    assert_eq!(gs, ws, "member-local seq");
                    assert_eq!(gv.len(), wv.len());
                    for (x, y) in gv.iter().zip(wv.iter()) {
                        assert_eq!(x.as_f32().unwrap(), y.as_f32().unwrap());
                    }
                }
            }
        }

        /// Loop sync that breaks after a fixed iteration, returning the
        /// concatenated body outputs of the final executed iteration.
        struct LoopBreaker {
            break_after: u32,
            fan_ins: AtomicU64,
        }

        impl GraphRunner for LoopBreaker {
            fn run_node(
                &self,
                _slot: ExecSlot,
                node: &crate::decompose::graph::TaskNode,
                _carried: Option<&[ArgValue]>,
            ) -> Result<TaskOutput> {
                // Value encodes the iteration (stage pairs [C, S] per iter).
                let iter = node.stage / 2;
                Ok(vec![ArgValue::F32(vec![
                    iter as f32;
                    node.partition.units as usize
                ])]
                .into())
            }

            fn run_sync(
                &self,
                node: &crate::decompose::graph::TaskNode,
                gathered: &[(usize, Arc<Vec<ArgValue>>)],
                is_sink: bool,
            ) -> Result<SyncOutcome> {
                self.fan_ins.fetch_add(gathered.len() as u64, Ordering::Relaxed);
                let iter = node.stage / 2;
                let brk = iter >= self.break_after;
                let outputs = if brk || is_sink {
                    let mut whole = Vec::new();
                    for (_, o) in gathered {
                        whole.extend_from_slice(o[0].as_f32()?);
                    }
                    Some(vec![ArgValue::F32(whole)])
                } else {
                    None
                };
                Ok(SyncOutcome {
                    verdict: if brk {
                        SyncVerdict::Break
                    } else {
                        SyncVerdict::Continue
                    },
                    outputs,
                })
            }
        }

        #[test]
        fn loop_break_cancels_later_iterations() {
            let sct = Sct::for_loop(kernel("body"), 5, true);
            let plan = two_slot_plan(8, 8);
            let stages = flatten_stages(&sct).unwrap();
            let graph = build_graph(&stages, &plan, 2).unwrap();
            let runner = LoopBreaker {
                break_after: 1,
                fan_ins: AtomicU64::new(0),
            };
            let out = launch_graph(&graph, &runner, LaunchOpts::default()).unwrap();
            // The sync of iteration 1 broke: its gathered outputs are the
            // request's result, and iterations 2-4 never executed.
            let outs = out.outputs.expect("breaking sync must produce outputs");
            assert_eq!(outs[0].as_f32().unwrap(), &vec![1.0f32; 16][..]);
            assert!(
                (out.executed as usize) < graph.n_nodes(),
                "cancelled nodes must not run ({} of {})",
                out.executed,
                graph.n_nodes()
            );
            // Every executed sync gathered one partial per chunk.
            let chunks = graph.nodes.iter().filter(|n| n.stage == 0).count() as u64;
            assert_eq!(runner.fan_ins.load(Ordering::Relaxed), 2 * chunks);
        }

        #[test]
        fn graph_errors_stop_the_drain() {
            struct FailStage1;
            impl GraphRunner for FailStage1 {
                fn run_node(
                    &self,
                    _slot: ExecSlot,
                    node: &crate::decompose::graph::TaskNode,
                    _carried: Option<&[ArgValue]>,
                ) -> Result<TaskOutput> {
                    if node.stage == 1 {
                        Err(Error::Runtime("boom".into()))
                    } else {
                        Ok(vec![ArgValue::F32(vec![0.0])].into())
                    }
                }

                fn run_sync(
                    &self,
                    _node: &crate::decompose::graph::TaskNode,
                    _gathered: &[(usize, Arc<Vec<ArgValue>>)],
                    _is_sink: bool,
                ) -> Result<SyncOutcome> {
                    Ok(SyncOutcome {
                        verdict: SyncVerdict::Continue,
                        outputs: None,
                    })
                }
            }
            let sct = Sct::pipeline(vec![kernel("a"), kernel("b")]);
            let plan = two_slot_plan(4, 4);
            let stages = flatten_stages(&sct).unwrap();
            let graph = build_graph(&stages, &plan, 2).unwrap();
            let err = launch_graph(&graph, &FailStage1, LaunchOpts::default()).unwrap_err();
            assert!(format!("{err}").contains("boom"));
        }
    }

    #[test]
    fn clock_accumulates_across_iterations() {
        let mut acc = SlotClock::default();
        let a = SlotClock {
            slots: vec![ExecSlot::CpuSub { idx: 0 }, ExecSlot::GpuSlot { gpu: 0, slot: 0 }],
            busy: vec![1.0, 2.0],
            elapsed: 2.0,
        };
        acc.accumulate(&a);
        acc.accumulate(&a);
        assert_eq!(acc.busy, vec![2.0, 4.0]);
        assert_eq!(acc.elapsed, 4.0);
        assert_eq!(acc.cpu_time(), 2.0);
        assert_eq!(acc.gpu_time(), 4.0);
    }
}
