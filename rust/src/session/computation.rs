//! The fluent computation builder: the user-facing way to assemble a
//! skeleton computational tree plus the workload metadata the adaptation
//! layers need (workload characterization, domain size, COPY volume).
//!
//! A `Computation` is what [`crate::session::Session`] executes; it wraps
//! the existing [`crate::sct`] types without replacing them — `.sct()`
//! hands back the tree for anything lower-level.
//!
//! Typical construction, fluent from a kernel leaf:
//!
//! ```text
//! let comp = Computation::kernel(gaussian)
//!     .pipeline(solarize)
//!     .pipeline(mirror)
//!     .over(Workload::d2(h, w))
//!     .units(h);
//! ```
//!
//! or from one of the paper benchmarks: `Computation::from(workloads::fft(128))`.

use crate::bench::workloads::Benchmark;
use crate::data::workload::Workload;
use crate::error::{Error, Result};
use crate::sct::{KernelSpec, LoopState, Reduction, Sct};

/// A runnable computation: SCT + workload characterization + domain size.
#[derive(Clone, Debug)]
pub struct Computation {
    name: String,
    sct: Sct,
    workload: Option<Workload>,
    total_units: Option<u64>,
    copy_bytes: f64,
}

impl Computation {
    /// Start from a single kernel leaf.
    pub fn kernel(k: KernelSpec) -> Computation {
        let name = k.family.clone();
        Computation {
            name,
            sct: Sct::kernel(k),
            workload: None,
            total_units: None,
            copy_bytes: 0.0,
        }
    }

    /// Start from an already-built tree.
    pub fn from_sct(sct: Sct) -> Computation {
        Computation {
            name: sct.id(),
            sct,
            workload: None,
            total_units: None,
            copy_bytes: 0.0,
        }
    }

    /// Append a kernel as the next pipeline stage: extends an existing
    /// `Pipeline` root, or wraps the current tree and the new stage in one.
    pub fn pipeline(self, k: KernelSpec) -> Computation {
        self.then(Sct::kernel(k))
    }

    /// Chain an arbitrary sub-tree as the next pipeline stage.
    pub fn then(mut self, sct: Sct) -> Computation {
        self.sct = match self.sct {
            Sct::Pipeline(mut stages) => {
                stages.push(sct);
                Sct::Pipeline(stages)
            }
            root => Sct::pipeline(vec![root, sct]),
        };
        self
    }

    /// Wrap the current tree in a `Map` skeleton.
    pub fn map(mut self) -> Computation {
        self.sct = Sct::map(self.sct);
        self
    }

    /// Wrap the current tree in a `Loop` skeleton.
    pub fn for_loop(mut self, iters: u32, global_sync: bool) -> Computation {
        self.sct = Sct::for_loop(self.sct, iters, global_sync);
        self
    }

    /// Wrap the current tree in a `Loop` with a full loop state (stoppage
    /// condition + host update).
    pub fn loop_with(mut self, state: LoopState) -> Computation {
        self.sct = Sct::loop_with(self.sct, state);
        self
    }

    /// Wrap the current tree in a `MapReduce` skeleton.
    pub fn reduce(mut self, r: Reduction) -> Computation {
        self.sct = Sct::map_reduce(self.sct, r);
        self
    }

    /// Attach the workload characterization (profile field (b)). When no
    /// explicit domain size was set, the first dimension becomes the number
    /// of elementary partitioning units — the common case for 1-D and
    /// line-partitioned 2-D workloads; call [`Computation::units`] when the
    /// partitioned dimension is a different one.
    pub fn over(mut self, w: Workload) -> Computation {
        if self.total_units.is_none() {
            self.total_units = w.dims.first().copied();
        }
        self.workload = Some(w);
        self
    }

    /// Set the domain size in elementary partitioning units.
    pub fn units(mut self, n: u64) -> Computation {
        self.total_units = Some(n);
        self
    }

    /// COPY-mode bytes replicated to every device per request (cost hint
    /// for analytic backends).
    pub fn copy_bytes(mut self, bytes: f64) -> Computation {
        self.copy_bytes = bytes;
        self
    }

    /// Display name (defaults to the kernel family / SCT id).
    pub fn named(mut self, name: &str) -> Computation {
        self.name = name.to_string();
        self
    }

    // --- accessors --------------------------------------------------------

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn sct(&self) -> &Sct {
        &self.sct
    }

    /// Mutable access to the tree (e.g. to attach a Loop host update).
    pub fn sct_mut(&mut self) -> &mut Sct {
        &mut self.sct
    }

    /// The knowledge-base identifier of this computation's tree.
    pub fn sct_id(&self) -> String {
        self.sct.id()
    }

    pub fn get_copy_bytes(&self) -> f64 {
        self.copy_bytes
    }

    /// Validate and expose the fields an execution needs.
    pub fn spec(&self) -> Result<(&Sct, &Workload, u64)> {
        let w = self.workload.as_ref().ok_or_else(|| {
            Error::Spec(format!(
                "computation '{}' has no workload characterization; call .over(..)",
                self.name
            ))
        })?;
        let units = self.total_units.ok_or_else(|| {
            Error::Spec(format!(
                "computation '{}' has no domain size; call .units(..)",
                self.name
            ))
        })?;
        Ok((&self.sct, w, units))
    }
}

impl From<Benchmark> for Computation {
    fn from(b: Benchmark) -> Computation {
        Computation {
            name: b.name,
            sct: b.sct,
            workload: Some(b.workload),
            total_units: Some(b.total_units),
            copy_bytes: b.copy_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::workloads;
    use crate::data::vector::Merge;
    use crate::sct::ParamSpec;

    fn k(name: &str) -> KernelSpec {
        KernelSpec::new(name, vec![ParamSpec::VecIn], 1)
    }

    #[test]
    fn fluent_pipeline_builds_expected_tree() {
        let c = Computation::kernel(k("a"))
            .pipeline(k("b"))
            .pipeline(k("c"))
            .over(Workload::d1(100));
        assert_eq!(c.sct_id(), "pipeline(a,b,c)");
        let (_, w, units) = c.spec().unwrap();
        assert_eq!(units, 100);
        assert_eq!(w.dimensionality(), 1);
    }

    #[test]
    fn map_loop_reduce_wrap() {
        let c = Computation::kernel(k("m"))
            .map()
            .for_loop(3, true)
            .reduce(Reduction::Host(Merge::Add))
            .over(Workload::d1(10));
        assert_eq!(c.sct_id(), "map_reduce(loop(map(m),n=3),host:Add)");
    }

    #[test]
    fn units_override_beats_workload_default() {
        let c = Computation::kernel(k("seg"))
            .over(Workload::d3(256, 256, 64))
            .units(64);
        assert_eq!(c.spec().unwrap().2, 64);
    }

    #[test]
    fn missing_workload_is_an_error() {
        let c = Computation::kernel(k("a"));
        assert!(c.spec().is_err());
    }

    #[test]
    fn from_benchmark_carries_everything() {
        let b = workloads::nbody(1024, 5);
        let copy = b.copy_bytes;
        let c = Computation::from(b);
        assert!(c.get_copy_bytes() > 0.0);
        assert_eq!(c.get_copy_bytes(), copy);
        assert_eq!(c.spec().unwrap().2, 1024);
    }
}
