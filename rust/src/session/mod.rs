//! The unified `Session` facade — one entry point that wires SCTs, the
//! tuner / knowledge base, and adaptive load balancing across the simulated
//! and real backends (the "seamless execution" contract of Sections
//! 3.2-3.3).
//!
//! A [`Session`] owns an execution backend (any [`ExecEnv`]: [`SimEnv`] or
//! [`crate::scheduler::real::RealScheduler`]), a [`KnowledgeBase`] and the
//! per-computation balancing state. [`Session::run`] resolves the framework
//! configuration through the paper's fallback chain — exact KB lookup, then
//! RBF-interpolated derivation, then a from-scratch Algorithm 1 profile
//! build — executes the request, feeds the observed outcome back into the
//! KB, and applies adaptive-binary-search rebalancing across repeated
//! requests (Fig 4's workflow).
//!
//! ```text
//! let comp = Computation::from(workloads::saxpy(1 << 20));
//! let mut s = Session::simulated(i7_hd7950(1), 42);
//! let out = s.run(&comp, &RequestArgs::default())?;   // cold start: builds
//! let out = s.run(&comp, &RequestArgs::default())?;   // KB hit, monitored
//! ```
//!
//! The facade is the only place in the tree that wires
//! `SimEnv`/`RealScheduler`/`FrameworkConfig` together; examples, the CLI
//! and the benches all go through it.

pub mod computation;

use std::collections::HashMap;
use std::path::Path;

use crate::balance::{AdaptiveBinarySearch, Monitor};
use crate::data::vector::ArgValue;
use crate::error::Result;
use crate::kb::KnowledgeBase;
use crate::platform::cpu::FissionLevel;
use crate::platform::device::Machine;
use crate::runtime::artifacts::Manifest;
use crate::runtime::client::RtClient;
use crate::runtime::exec::RequestArgs;
use crate::scheduler::real::RealScheduler;
use crate::scheduler::{ExecEnv, ExecOutcome, SimEnv};
use crate::sim::machine::SimMachine;
use crate::tuner::builder::{build_profile, TunerOpts};
use crate::tuner::profile::{FrameworkConfig, Profile, ProfileOrigin};

pub use computation::Computation;

/// How [`Session::run`] obtained the configuration of one request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConfigOrigin {
    /// Exact (SCT, workload) hit in the knowledge base.
    KbHit,
    /// Interpolated from nearby profiles (box "Derive work distribution").
    Derived,
    /// Built from scratch by Algorithm 1 (box "Build SCT profile").
    Built,
    /// Explicitly pinned by [`Session::run_with`] — adaptation bypassed.
    Pinned,
}

impl ConfigOrigin {
    pub fn label(&self) -> &'static str {
        match self {
            ConfigOrigin::KbHit => "kb-hit",
            ConfigOrigin::Derived => "derived",
            ConfigOrigin::Built => "built",
            ConfigOrigin::Pinned => "pinned",
        }
    }
}

/// Everything one [`Session::run`] call produced.
#[derive(Clone, Debug)]
pub struct SessionOutcome {
    /// Merged output buffers (empty on timing-only backends).
    pub outputs: Vec<ArgValue>,
    /// Timing of the execution.
    pub exec: ExecOutcome,
    /// The configuration the request actually ran under.
    pub config: FrameworkConfig,
    /// Where that configuration came from.
    pub origin: ConfigOrigin,
    /// Whether the monitor observed this execution as unbalanced (the lbt
    /// threshold needs a few consecutive unbalanced runs before triggering).
    pub unbalanced: bool,
    /// Whether the balancer moved the CPU/GPU split for the *next* run.
    pub rebalanced: bool,
    /// Cumulative backend kernel launches (0 for analytic backends).
    pub launches: u64,
}

/// Aggregate session counters.
#[derive(Clone, Debug, Default)]
pub struct SessionStats {
    pub runs: u64,
    pub kb_hits: u64,
    pub derived: u64,
    pub built: u64,
    pub pinned: u64,
    pub balance_ops: u64,
    pub unbalanced_runs: u64,
}

/// Per-configuration tweaks for [`Session::run_with`]: applied on top of a
/// machine-derived baseline so callers never assemble a raw
/// [`FrameworkConfig`] by hand.
#[derive(Clone, Debug, Default)]
pub struct ConfigOverride {
    cpu_share: Option<f64>,
    fission: Option<FissionLevel>,
    overlap: Option<u32>,
    wgs: Option<u32>,
}

impl ConfigOverride {
    pub fn new() -> ConfigOverride {
        ConfigOverride::default()
    }

    /// Pin the CPU fraction of the workload.
    pub fn cpu_share(mut self, share: f64) -> ConfigOverride {
        self.cpu_share = Some(share.clamp(0.0, 1.0));
        self
    }

    /// Everything on the GPUs.
    pub fn gpu_only(self) -> ConfigOverride {
        self.cpu_share(0.0)
    }

    /// Everything on the CPUs.
    pub fn cpu_only(self) -> ConfigOverride {
        self.cpu_share(1.0)
    }

    pub fn fission(mut self, level: FissionLevel) -> ConfigOverride {
        self.fission = Some(level);
        self
    }

    /// Overlap factor applied to every GPU.
    pub fn overlap(mut self, o: u32) -> ConfigOverride {
        self.overlap = Some(o);
        self
    }

    pub fn wgs(mut self, wgs: u32) -> ConfigOverride {
        self.wgs = Some(wgs);
        self
    }

    fn apply(&self, mut base: FrameworkConfig) -> FrameworkConfig {
        if let Some(s) = self.cpu_share {
            base.cpu_share = s;
        }
        if let Some(f) = self.fission {
            base.fission = f;
        }
        if let Some(o) = self.overlap {
            base.overlap = vec![o; base.overlap.len()];
        }
        if let Some(w) = self.wgs {
            base.wgs = w;
        }
        base
    }
}

/// A sensible machine-derived default configuration (used as the base for
/// pinned runs; the adaptive path never sees it).
fn baseline_config(machine: &Machine) -> FrameworkConfig {
    let hybrid = !machine.gpus.is_empty();
    FrameworkConfig {
        fission: FissionLevel::L2,
        overlap: if hybrid {
            vec![2; machine.gpus.len()]
        } else {
            Vec::new()
        },
        wgs: 256,
        cpu_share: if hybrid { 0.25 } else { 1.0 },
    }
}

/// Per-(SCT, workload) adaptation state: the execution monitor and the
/// adaptive binary search, persisted across requests.
struct BalanceState {
    monitor: Monitor,
    abs: AdaptiveBinarySearch,
}

/// The unified execution session.
pub struct Session<E: ExecEnv> {
    env: E,
    kb: KnowledgeBase,
    tuner: TunerOpts,
    /// Balance threshold `maxDev` handed to new monitors (Section 3.3).
    max_dev: f64,
    states: HashMap<String, BalanceState>,
    stats: SessionStats,
}

impl Session<SimEnv> {
    /// A session over the analytic simulator for `machine`.
    pub fn simulated(machine: Machine, seed: u64) -> Session<SimEnv> {
        Session::sim(SimMachine::new(machine, seed))
    }

    /// A session over a fully customized simulated machine (load profiles,
    /// cost parameters...).
    pub fn sim(sim: SimMachine) -> Session<SimEnv> {
        Session::new(SimEnv::new(sim))
    }
}

impl<'a> Session<RealScheduler<'a>> {
    /// A session over the real PJRT runtime.
    pub fn real(
        machine: Machine,
        client: &'a RtClient,
        manifest: &'a Manifest,
    ) -> Session<RealScheduler<'a>> {
        Session::new(RealScheduler::new(machine, client, manifest))
    }
}

impl<E: ExecEnv> Session<E> {
    /// A session over any execution environment.
    pub fn new(env: E) -> Session<E> {
        Session {
            env,
            kb: KnowledgeBase::in_memory(),
            tuner: TunerOpts::default(),
            max_dev: 0.85,
            states: HashMap::new(),
            stats: SessionStats::default(),
        }
    }

    /// Replace the knowledge base (e.g. one warmed by a simulated session).
    pub fn with_kb(mut self, kb: KnowledgeBase) -> Session<E> {
        self.kb = kb;
        self
    }

    /// Use a JSON-backed knowledge base at `path` (created when missing).
    pub fn with_kb_path(mut self, path: &Path) -> Result<Session<E>> {
        self.kb = KnowledgeBase::open(path)?;
        Ok(self)
    }

    /// Tuning options for cold-start profile builds.
    pub fn with_tuner(mut self, opts: TunerOpts) -> Session<E> {
        self.tuner = opts;
        self
    }

    /// Balance threshold for the execution monitor (paper default 0.85).
    pub fn with_max_dev(mut self, max_dev: f64) -> Session<E> {
        self.max_dev = max_dev;
        self
    }

    // --- the seamless path ------------------------------------------------

    /// Resolve the framework configuration for a computation through the
    /// Section 3.2.3 fallback chain: KB lookup, RBF derivation, profile
    /// build. The built profile (cold start) is stored into the KB; `args`
    /// feed the tuner's probe executions on backends that run real kernels
    /// (analytic backends ignore them).
    pub fn resolve_config(
        &mut self,
        comp: &Computation,
        args: &RequestArgs,
    ) -> Result<(FrameworkConfig, ConfigOrigin)> {
        let (sct, w, units) = comp.spec()?;
        let id = sct.id();
        if let Some(p) = self.kb.lookup(&id, w) {
            self.stats.kb_hits += 1;
            return Ok((p.config.clone(), ConfigOrigin::KbHit));
        }
        if let Some(cfg) = self.kb.derive(&id, w) {
            self.stats.derived += 1;
            return Ok((cfg, ConfigOrigin::Derived));
        }
        self.env.set_copy_bytes(comp.get_copy_bytes());
        self.env.bind_tuning_args(args);
        let p = build_profile(&mut self.env, sct, w, units, &self.tuner)?;
        let cfg = p.config.clone();
        self.kb.store(p);
        self.stats.built += 1;
        Ok((cfg, ConfigOrigin::Built))
    }

    /// Execute a computation under the KB-resolved configuration, monitor
    /// the execution, rebalance if the monitor triggers, and feed the
    /// outcome back into the knowledge base.
    pub fn run(&mut self, comp: &Computation, args: &RequestArgs) -> Result<SessionOutcome> {
        self.env.set_copy_bytes(comp.get_copy_bytes());
        self.env.bind_tuning_args(args);
        let (cfg, origin) = self.resolve_config(comp, args)?;
        let (sct, w, units) = comp.spec()?;
        let id = sct.id();
        let out = self.env.run_request(sct, args, units, &cfg)?;

        // Section 3.3: monitor every execution; adapt when lbt triggers.
        let key = format!("{id}|{}", w.id());
        let max_dev = self.max_dev;
        let st = self.states.entry(key).or_insert_with(|| BalanceState {
            monitor: Monitor::new(max_dev),
            abs: AdaptiveBinarySearch::new(cfg.cpu_share),
        });
        let status = st.monitor.observe(&out.exec.slot_times);
        if status.unbalanced {
            self.stats.unbalanced_runs += 1;
        }
        let mut stored_cfg = cfg.clone();
        let mut rebalanced = false;
        if status.trigger && !cfg.overlap.is_empty() {
            stored_cfg.cpu_share = st.abs.propose(out.exec.cpu_time, out.exec.gpu_time);
            st.monitor.reset_lbt();
            self.stats.balance_ops += 1;
            rebalanced = true;
        } else {
            st.abs.track(cfg.cpu_share);
        }

        // Feed the observed outcome back into the KB: refined profiles
        // replace the stored distribution; plain runs keep the best time of
        // the configuration they actually ran under (Refined entries bypass
        // the store's best-time guard, so the min is taken here).
        let existing = self.kb.lookup(&id, w);
        let store_origin = if rebalanced {
            ProfileOrigin::Refined
        } else {
            match origin {
                ConfigOrigin::Built => ProfileOrigin::Built,
                ConfigOrigin::Derived => ProfileOrigin::Derived,
                _ => existing.map(|p| p.origin).unwrap_or(ProfileOrigin::Built),
            }
        };
        let best_time = match existing {
            Some(p) if !rebalanced && p.config == stored_cfg => {
                out.exec.total.min(p.best_time)
            }
            _ => out.exec.total,
        };
        self.kb.store(Profile {
            sct_id: id,
            workload: w.clone(),
            config: stored_cfg,
            best_time,
            origin: store_origin,
        });

        self.stats.runs += 1;
        Ok(SessionOutcome {
            outputs: out.outputs,
            exec: out.exec,
            config: cfg,
            origin,
            unbalanced: status.unbalanced,
            rebalanced,
            launches: self.env.launch_count(),
        })
    }

    /// Execute under an explicitly pinned configuration (baseline + the
    /// override), bypassing the KB and the balancer — the escape hatch for
    /// reproducing fixed table rows and A/B comparisons.
    pub fn run_with(
        &mut self,
        comp: &Computation,
        args: &RequestArgs,
        ovr: ConfigOverride,
    ) -> Result<SessionOutcome> {
        let (sct, _, units) = comp.spec()?;
        self.env.set_copy_bytes(comp.get_copy_bytes());
        let cfg = ovr.apply(baseline_config(self.env.machine()));
        let out = self.env.run_request(sct, args, units, &cfg)?;
        self.stats.runs += 1;
        self.stats.pinned += 1;
        Ok(SessionOutcome {
            outputs: out.outputs,
            exec: out.exec,
            config: cfg,
            origin: ConfigOrigin::Pinned,
            unbalanced: false,
            rebalanced: false,
            launches: self.env.launch_count(),
        })
    }

    /// Run Algorithm 1 for a computation and persist the profile in the
    /// session's knowledge base.
    pub fn profile(&mut self, comp: &Computation) -> Result<Profile> {
        self.profile_with_args(comp, &RequestArgs::default())
    }

    /// Like [`Session::profile`], binding `args` for the tuner's probe
    /// executions (real backends need actual buffers).
    pub fn profile_with_args(
        &mut self,
        comp: &Computation,
        args: &RequestArgs,
    ) -> Result<Profile> {
        let (sct, w, units) = comp.spec()?;
        self.env.set_copy_bytes(comp.get_copy_bytes());
        self.env.bind_tuning_args(args);
        let p = build_profile(&mut self.env, sct, w, units, &self.tuner)?;
        self.kb.store(p.clone());
        self.stats.built += 1;
        Ok(p)
    }

    // --- accessors --------------------------------------------------------

    pub fn kb(&self) -> &KnowledgeBase {
        &self.kb
    }

    pub fn kb_mut(&mut self) -> &mut KnowledgeBase {
        &mut self.kb
    }

    /// Hand the knowledge base over (e.g. sim-warmed KB into a real session).
    pub fn into_kb(self) -> KnowledgeBase {
        self.kb
    }

    /// Persist the knowledge base (no-op for in-memory KBs).
    pub fn save_kb(&self) -> Result<()> {
        self.kb.save()
    }

    pub fn env(&self) -> &E {
        &self.env
    }

    pub fn env_mut(&mut self) -> &mut E {
        &mut self.env
    }

    pub fn machine(&self) -> &Machine {
        self.env.machine()
    }

    pub fn stats(&self) -> &SessionStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::workloads;
    use crate::platform::device::i7_hd7950;

    #[test]
    fn override_applies_on_machine_baseline() {
        let base = baseline_config(&i7_hd7950(2));
        assert_eq!(base.overlap.len(), 2);
        let cfg = ConfigOverride::new().gpu_only().overlap(4).apply(base);
        assert_eq!(cfg.cpu_share, 0.0);
        assert_eq!(cfg.overlap, vec![4, 4]);
    }

    #[test]
    fn pinned_run_reports_origin_and_skips_kb() {
        let comp = Computation::from(workloads::saxpy(1 << 20));
        let mut s = Session::simulated(i7_hd7950(1), 5);
        let out = s
            .run_with(&comp, &RequestArgs::default(), ConfigOverride::new().gpu_only())
            .unwrap();
        assert_eq!(out.origin, ConfigOrigin::Pinned);
        assert_eq!(out.config.cpu_share, 0.0);
        assert!(s.kb().is_empty());
        assert_eq!(s.stats().pinned, 1);
    }

    #[test]
    fn cpu_only_machine_never_rebalances() {
        use crate::platform::device::opteron_6272_quad;
        let comp = Computation::from(workloads::fft(16));
        let mut s = Session::simulated(opteron_6272_quad(), 9);
        for _ in 0..10 {
            let out = s.run(&comp, &RequestArgs::default()).unwrap();
            assert!(!out.rebalanced);
            assert_eq!(out.config.cpu_share, 1.0);
        }
        assert_eq!(s.stats().balance_ops, 0);
    }
}
