//! The unified `Session` facade — one entry point that wires SCTs, the
//! tuner / knowledge base, and adaptive load balancing across the simulated
//! and real backends (the "seamless execution" contract of Sections
//! 3.2-3.3).
//!
//! A [`Session`] owns an execution backend (any [`ExecEnv`]: [`SimEnv`] or
//! [`crate::scheduler::real::RealScheduler`]), a [`KnowledgeBase`] and the
//! per-computation balancing state. [`Session::run`] resolves the framework
//! configuration through the paper's fallback chain — exact KB lookup, then
//! RBF-interpolated derivation, then a from-scratch Algorithm 1 profile
//! build — executes the request, feeds the observed outcome back into the
//! KB, and applies adaptive-binary-search rebalancing across repeated
//! requests (Fig 4's workflow).
//!
//! ```text
//! let comp = Computation::from(workloads::saxpy(1 << 20));
//! let s = Session::simulated(i7_hd7950(1), 42);
//! let out = s.run(&comp, &RequestArgs::default())?;   // cold start: builds
//! let out = s.run(&comp, &RequestArgs::default())?;   // KB hit, monitored
//! ```
//!
//! **Concurrency model.** A `Session` is shareable: every public entry
//! point takes `&self`, so N client threads can drive one session (or N
//! pooled sessions can share one knowledge base — see [`serve`]). The
//! knowledge base sits behind an `Arc<RwLock<..>>` (concurrent lookups,
//! exclusive stores), the per-(SCT, workload) balancing state behind a
//! mutex (the lbt monitor observes interleaved slot-time streams in
//! arrival order), and the backend behind its own mutex — one in-flight
//! execution per backend, which is exactly the paper's one-machine
//! contract; cross-request parallelism comes from pooling sessions over a
//! shared KB.
//!
//! The facade is the only place in the tree that wires
//! `SimEnv`/`RealScheduler`/`FrameworkConfig` together; examples, the CLI
//! and the benches all go through it.

pub mod computation;
pub mod exec_profile;
pub mod serve;

use std::collections::HashMap;
use std::path::Path;
use std::sync::{
    Arc, Mutex, MutexGuard, OnceLock, RwLock, RwLockReadGuard, RwLockWriteGuard,
};
use std::time::Instant;

use crate::balance::{AdaptiveBinarySearch, Monitor};
use crate::data::vector::ArgValue;
use crate::error::{Error, Result};
use crate::kb::store::snapshot::KbSnapshot;
use crate::kb::KnowledgeBase;
use crate::platform::cpu::FissionLevel;
use crate::platform::device::Machine;
use crate::runtime::artifacts::Manifest;
use crate::runtime::client::RtClient;
use crate::runtime::native::NativeEngine;
use crate::runtime::exec::RequestArgs;
use crate::scheduler::real::RealScheduler;
use crate::scheduler::{DrainMode, ExecEnv, ExecOutcome, SimEnv, SlotMask};
use crate::sim::machine::SimMachine;
use crate::tuner::builder::{build_profile, TunerOpts};
use crate::tuner::profile::{FrameworkConfig, Profile, ProfileOrigin};

pub use computation::Computation;
pub use exec_profile::ExecProfile;
pub use serve::{ServeOpts, ServeReport, ServeRequest, SessionPool};

/// Which execution backend a session should be built over — the CLI's
/// `--backend sim|native|pjrt` flag parses into this (DESIGN.md §2.11).
/// Backends differ in type ([`SimEnv`] vs [`RealScheduler`]), so selection
/// happens at construction: [`Session::simulated`], [`Session::native`] or
/// [`Session::real`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Backend {
    /// The analytic simulator — deterministic, no hardware touched.
    #[default]
    Sim,
    /// Compiled in-process CPU kernels: real buffers, real wall-clock
    /// timing into Algorithm 1 and the knowledge base.
    Native,
    /// AOT-compiled PJRT artifacts (needs the `pjrt` feature and
    /// `make artifacts`; errors at run time in stub builds).
    Pjrt,
}

impl Backend {
    pub fn parse(s: &str) -> Result<Backend> {
        match s {
            "sim" => Ok(Backend::Sim),
            "native" => Ok(Backend::Native),
            "pjrt" => Ok(Backend::Pjrt),
            other => Err(Error::Usage(format!(
                "unknown backend '{other}' (expected sim|native|pjrt)"
            ))),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Backend::Sim => "sim",
            Backend::Native => "native",
            Backend::Pjrt => "pjrt",
        }
    }
}

/// How [`Session::run`] obtained the configuration of one request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConfigOrigin {
    /// Exact (SCT, workload) hit in the knowledge base.
    KbHit,
    /// Interpolated from nearby profiles (box "Derive work distribution").
    Derived,
    /// Built from scratch by Algorithm 1 (box "Build SCT profile").
    Built,
    /// Explicitly pinned by [`Session::run_with`] — adaptation bypassed.
    Pinned,
}

impl ConfigOrigin {
    pub fn label(&self) -> &'static str {
        match self {
            ConfigOrigin::KbHit => "kb-hit",
            ConfigOrigin::Derived => "derived",
            ConfigOrigin::Built => "built",
            ConfigOrigin::Pinned => "pinned",
        }
    }

    /// Inverse of [`ConfigOrigin::label`] (serialized request traces).
    pub fn parse(s: &str) -> Option<ConfigOrigin> {
        match s {
            "kb-hit" => Some(ConfigOrigin::KbHit),
            "derived" => Some(ConfigOrigin::Derived),
            "built" => Some(ConfigOrigin::Built),
            "pinned" => Some(ConfigOrigin::Pinned),
            _ => None,
        }
    }
}

/// Everything one [`Session::run`] call produced.
#[derive(Clone, Debug)]
pub struct SessionOutcome {
    /// Merged output buffers (empty on timing-only backends).
    pub outputs: Vec<ArgValue>,
    /// Timing of the execution.
    pub exec: ExecOutcome,
    /// The configuration the request actually ran under.
    pub config: FrameworkConfig,
    /// Where that configuration came from.
    pub origin: ConfigOrigin,
    /// Whether the monitor observed this execution as unbalanced (the lbt
    /// threshold needs a few consecutive unbalanced runs before triggering).
    pub unbalanced: bool,
    /// Whether the balancer moved the CPU/GPU split for the *next* run.
    pub rebalanced: bool,
    /// Cumulative backend kernel launches (0 for analytic backends).
    pub launches: u64,
}

/// Aggregate session counters.
#[derive(Clone, Debug, Default)]
pub struct SessionStats {
    pub runs: u64,
    pub kb_hits: u64,
    /// Subset of `kb_hits` whose entry came from the durable store / an
    /// imported snapshot rather than a local build — the warm-start
    /// provenance counter (DESIGN.md §2.9).
    pub warm_hits: u64,
    pub derived: u64,
    pub built: u64,
    /// Wall seconds spent inside Algorithm 1 cold builds (the cost
    /// warm-starting eliminates).
    pub build_secs: f64,
    pub pinned: u64,
    pub balance_ops: u64,
    pub unbalanced_runs: u64,
    /// Transfer accounting summed over every request this session ran
    /// (buffer-residency layer, DESIGN.md §2.6).
    pub bytes_uploaded: u64,
    pub bytes_downloaded: u64,
    pub uploads_avoided: u64,
    /// Bytes the avoided uploads would have moved (conservation term of
    /// the transfer-accounting invariant, DESIGN.md §2.12).
    pub uploads_avoided_bytes: u64,
    /// Uploads hidden under compute by the prefetch lookahead (§2.12).
    pub uploads_overlapped: u64,
    pub uploads_overlapped_bytes: u64,
    pub steal_migrations: u64,
    /// Sum over runs of the request's mean slot-idle fraction
    /// ([`ExecOutcome::mean_idle_frac`]) — divide by `runs` for the mean;
    /// the overlap win of the dataflow drain shows up here.
    pub idle_frac_sum: f64,
}

impl SessionStats {
    /// Mean slot idle percentage over every run (0 when nothing ran).
    pub fn mean_idle_pct(&self) -> f64 {
        if self.runs == 0 {
            0.0
        } else {
            100.0 * self.idle_frac_sum / self.runs as f64
        }
    }

    /// Share of link-crossing upload bytes hidden under compute by the
    /// prefetch lookahead (DESIGN.md §2.12): overlapped / (exposed +
    /// overlapped). 0 when nothing was uploaded.
    pub fn overlap_pct(&self) -> f64 {
        let crossed = self.bytes_uploaded + self.uploads_overlapped_bytes;
        if crossed == 0 {
            0.0
        } else {
            100.0 * self.uploads_overlapped_bytes as f64 / crossed as f64
        }
    }

    /// JSON form (serialized serve reports, DESIGN.md §2.13).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("runs", Json::num(self.runs as f64)),
            ("kb_hits", Json::num(self.kb_hits as f64)),
            ("warm_hits", Json::num(self.warm_hits as f64)),
            ("derived", Json::num(self.derived as f64)),
            ("built", Json::num(self.built as f64)),
            ("build_secs", Json::num(self.build_secs)),
            ("pinned", Json::num(self.pinned as f64)),
            ("balance_ops", Json::num(self.balance_ops as f64)),
            ("unbalanced_runs", Json::num(self.unbalanced_runs as f64)),
            ("bytes_uploaded", Json::num(self.bytes_uploaded as f64)),
            ("bytes_downloaded", Json::num(self.bytes_downloaded as f64)),
            ("uploads_avoided", Json::num(self.uploads_avoided as f64)),
            (
                "uploads_avoided_bytes",
                Json::num(self.uploads_avoided_bytes as f64),
            ),
            ("uploads_overlapped", Json::num(self.uploads_overlapped as f64)),
            (
                "uploads_overlapped_bytes",
                Json::num(self.uploads_overlapped_bytes as f64),
            ),
            ("steal_migrations", Json::num(self.steal_migrations as f64)),
            ("idle_frac_sum", Json::num(self.idle_frac_sum)),
        ])
    }

    /// Inverse of [`SessionStats::to_json`]; absent counters read as 0.
    pub fn from_json(v: &crate::util::json::Json) -> SessionStats {
        let u = |k: &str| v.get(k).ok().and_then(|x| x.as_u64()).unwrap_or(0);
        let f = |k: &str| v.get(k).ok().and_then(|x| x.as_f64()).unwrap_or(0.0);
        SessionStats {
            runs: u("runs"),
            kb_hits: u("kb_hits"),
            warm_hits: u("warm_hits"),
            derived: u("derived"),
            built: u("built"),
            build_secs: f("build_secs"),
            pinned: u("pinned"),
            balance_ops: u("balance_ops"),
            unbalanced_runs: u("unbalanced_runs"),
            bytes_uploaded: u("bytes_uploaded"),
            bytes_downloaded: u("bytes_downloaded"),
            uploads_avoided: u("uploads_avoided"),
            uploads_avoided_bytes: u("uploads_avoided_bytes"),
            uploads_overlapped: u("uploads_overlapped"),
            uploads_overlapped_bytes: u("uploads_overlapped_bytes"),
            steal_migrations: u("steal_migrations"),
            idle_frac_sum: f("idle_frac_sum"),
        }
    }
}

/// Per-configuration tweaks for [`Session::run_with`]: applied on top of a
/// machine-derived baseline so callers never assemble a raw
/// [`FrameworkConfig`] by hand.
#[derive(Clone, Debug, Default)]
pub struct ConfigOverride {
    cpu_share: Option<f64>,
    fission: Option<FissionLevel>,
    overlap: Option<u32>,
    wgs: Option<u32>,
}

impl ConfigOverride {
    pub fn new() -> ConfigOverride {
        ConfigOverride::default()
    }

    /// Pin the CPU fraction of the workload.
    pub fn cpu_share(mut self, share: f64) -> ConfigOverride {
        self.cpu_share = Some(share.clamp(0.0, 1.0));
        self
    }

    /// Everything on the GPUs.
    pub fn gpu_only(self) -> ConfigOverride {
        self.cpu_share(0.0)
    }

    /// Everything on the CPUs.
    pub fn cpu_only(self) -> ConfigOverride {
        self.cpu_share(1.0)
    }

    pub fn fission(mut self, level: FissionLevel) -> ConfigOverride {
        self.fission = Some(level);
        self
    }

    /// Overlap factor applied to every GPU.
    pub fn overlap(mut self, o: u32) -> ConfigOverride {
        self.overlap = Some(o);
        self
    }

    pub fn wgs(mut self, wgs: u32) -> ConfigOverride {
        self.wgs = Some(wgs);
        self
    }

    fn apply(&self, mut base: FrameworkConfig) -> FrameworkConfig {
        if let Some(s) = self.cpu_share {
            base.cpu_share = s;
        }
        if let Some(f) = self.fission {
            base.fission = f;
        }
        if let Some(o) = self.overlap {
            base.overlap = vec![o; base.overlap.len()];
        }
        if let Some(w) = self.wgs {
            base.wgs = w;
        }
        base
    }
}

/// A sensible machine-derived default configuration (used as the base for
/// pinned runs; the adaptive path never sees it).
fn baseline_config(machine: &Machine) -> FrameworkConfig {
    let hybrid = !machine.gpus.is_empty();
    FrameworkConfig {
        fission: FissionLevel::L2,
        overlap: if hybrid {
            vec![2; machine.gpus.len()]
        } else {
            Vec::new()
        },
        wgs: 256,
        cpu_share: if hybrid { 0.25 } else { 1.0 },
    }
}

/// Per-(SCT, workload) adaptation state: the execution monitor and the
/// adaptive binary search, persisted across requests.
struct BalanceState {
    monitor: Monitor,
    abs: AdaptiveBinarySearch,
}

/// The unified execution session. Shareable across threads: see the module
/// docs for the locking discipline.
pub struct Session<E: ExecEnv> {
    /// The backend. One execution in flight per backend; concurrent `run`
    /// calls on one session serialize here (pool sessions for parallelism).
    env: Mutex<E>,
    /// The knowledge base, shareable between sessions ([`Session::shared_kb`]).
    kb: Arc<RwLock<KnowledgeBase>>,
    tuner: TunerOpts,
    /// The accumulated execution profile (DESIGN.md §2.13): every pinned
    /// runtime knob this session runs under, including the balance
    /// threshold `maxDev` handed to new monitors (Section 3.3).
    exec: Mutex<ExecProfile>,
    states: Mutex<HashMap<String, BalanceState>>,
    stats: Mutex<SessionStats>,
    /// The installed reservation mask (DESIGN.md §2.8). While set, runs
    /// execute on a hardware subset, so their skewed slot times and
    /// derated totals must feed neither the balance monitor nor the
    /// shared knowledge base — both describe the whole machine.
    slot_mask: Mutex<Option<SlotMask>>,
}

impl Session<SimEnv> {
    /// A session over the analytic simulator for `machine`.
    pub fn simulated(machine: Machine, seed: u64) -> Session<SimEnv> {
        Session::sim(SimMachine::new(machine, seed))
    }

    /// A session over a fully customized simulated machine (load profiles,
    /// cost parameters...).
    pub fn sim(sim: SimMachine) -> Session<SimEnv> {
        Session::new(SimEnv::new(sim))
    }
}

impl<'a> Session<RealScheduler<'a>> {
    /// A session over the real PJRT runtime.
    pub fn real(
        machine: Machine,
        client: &'a RtClient,
        manifest: &'a Manifest,
    ) -> Session<RealScheduler<'a>> {
        Session::new(RealScheduler::new(machine, client, manifest))
    }
}

/// Process-wide runtime state for the native backend. [`RealScheduler`]
/// borrows its client and manifest, so the zero-setup constructors lean on
/// `'static` once-initialized instances instead of threading lifetimes
/// through every CLI call site. The client is the offline handle (the
/// native engine intercepts execution before any PJRT compile); the
/// manifest is the built-in specialization menu ported from `aot.py`.
fn native_runtime() -> Result<(&'static RtClient, &'static Manifest)> {
    static CLIENT: OnceLock<RtClient> = OnceLock::new();
    static MANIFEST: OnceLock<Manifest> = OnceLock::new();
    if CLIENT.get().is_none() {
        // Fallible init: build outside the cell, ignore a lost set race.
        let built = RtClient::offline()?;
        let _ = CLIENT.set(built);
    }
    let client = CLIENT.get().expect("client set above");
    let manifest = MANIFEST.get_or_init(crate::runtime::native::builtin_manifest);
    Ok((client, manifest))
}

impl Session<RealScheduler<'static>> {
    /// A session executing compiled native CPU kernels in-process
    /// (DESIGN.md §2.11): the scheduler's full chunk/steal/residency
    /// machinery runs over real buffers, and observed wall-clock timings
    /// feed Algorithm 1 and the knowledge base. The KB digest is
    /// native-specific, so learned profiles never cross-contaminate sim
    /// or PJRT stores.
    pub fn native(machine: Machine) -> Result<Session<RealScheduler<'static>>> {
        Session::native_with_engine(machine, Arc::new(NativeEngine::new()))
    }

    /// [`Session::native`] over an explicit engine — the parity tests and
    /// the hot-path bench pass [`NativeEngine::scalar_reference`] here to
    /// get the single-lane baseline on the identical scheduling path.
    pub fn native_with_engine(
        machine: Machine,
        engine: Arc<NativeEngine>,
    ) -> Result<Session<RealScheduler<'static>>> {
        let (client, manifest) = native_runtime()?;
        let sched = RealScheduler::new(machine, client, manifest).with_native(engine);
        Ok(Session::new(sched))
    }
}

impl<E: ExecEnv> Session<E> {
    /// A session over any execution environment.
    pub fn new(env: E) -> Session<E> {
        Session {
            env: Mutex::new(env),
            kb: Arc::new(RwLock::new(KnowledgeBase::in_memory())),
            tuner: TunerOpts::default(),
            exec: Mutex::new(ExecProfile::default()),
            states: Mutex::new(HashMap::new()),
            stats: Mutex::new(SessionStats::default()),
            slot_mask: Mutex::new(None),
        }
    }

    /// Replace the knowledge base (e.g. one warmed by a simulated session).
    pub fn with_kb(mut self, kb: KnowledgeBase) -> Session<E> {
        self.kb = Arc::new(RwLock::new(kb));
        self
    }

    /// Share another session's knowledge base: concurrent sessions pooled
    /// over one KB all see each other's profiles (see [`serve`]).
    pub fn with_shared_kb(mut self, kb: Arc<RwLock<KnowledgeBase>>) -> Session<E> {
        self.kb = kb;
        self
    }

    /// Use a JSON-backed knowledge base at `path` (created when missing).
    pub fn with_kb_path(mut self, path: &Path) -> Result<Session<E>> {
        self.kb = Arc::new(RwLock::new(KnowledgeBase::open(path)?));
        Ok(self)
    }

    /// Use a durable content-addressed KB store at `dir` (DESIGN.md §2.9),
    /// created when missing. The store is keyed to this backend's
    /// [`ExecEnv::manifest_digest`], so records it holds for other
    /// platforms load as derivation hints, never exact hits; `store()`
    /// then writes through incrementally, committed by
    /// [`Session::sync_kb`] / [`Session::save_kb`].
    pub fn with_kb_store(mut self, dir: &Path) -> Result<Session<E>> {
        let digest = self.env.lock().unwrap().manifest_digest();
        self.kb = Arc::new(RwLock::new(KnowledgeBase::open_store(dir, &digest)?));
        Ok(self)
    }

    /// Import a KB snapshot: records whose machine manifest digest matches
    /// this backend become exact (warm-start) entries, the rest derivation
    /// hints. Returns (exact entries, hints) absorbed.
    pub fn import_kb_snapshot(&self, snap: &KbSnapshot) -> (usize, usize) {
        let digest = self.env.lock().unwrap().manifest_digest();
        let mut kb = self.kb.write().unwrap();
        kb.ensure_manifest_digest(&digest);
        kb.import_snapshot(snap)
    }

    /// Flush write-through KB records to the durable store and absorb
    /// anything co-located processes flushed since (reload on epoch
    /// change). Returns records absorbed from disk; a no-op (0) without a
    /// store backing.
    pub fn sync_kb(&self) -> Result<usize> {
        self.kb.write().unwrap().sync_store()
    }

    /// Tuning options for cold-start profile builds.
    pub fn with_tuner(mut self, opts: TunerOpts) -> Session<E> {
        self.tuner = opts;
        self
    }

    /// Apply an execution profile (DESIGN.md §2.13): every pinned knob is
    /// pushed into the backend and merged into the session's stored
    /// profile (later applications overlay earlier ones; unset knobs
    /// change nothing). The single configuration entry point — the legacy
    /// `with_*`/`set_*` setters below all delegate here.
    pub fn apply_exec(&self, profile: &ExecProfile) {
        {
            let mut env = self.env.lock().unwrap();
            if let Some(n) = profile.tasks_per_slot {
                env.set_tasks_per_slot(n);
            }
            if let Some(k) = profile.prefetch_depth {
                env.set_prefetch_depth(k);
            }
            if let Some(mode) = profile.drain_mode {
                env.set_drain_mode(mode);
            }
            if let Some(on) = profile.residency {
                env.set_residency_enabled(on);
            }
        }
        self.exec.lock().unwrap().merge(profile);
    }

    /// Builder form of [`Session::apply_exec`].
    pub fn with_exec_profile(self, profile: ExecProfile) -> Session<E> {
        self.apply_exec(&profile);
        self
    }

    /// The accumulated execution profile this session runs under — what a
    /// recorded replay trace carries (DESIGN.md §2.13).
    pub fn exec_profile(&self) -> ExecProfile {
        self.exec.lock().unwrap().clone()
    }

    /// Balance threshold for the execution monitor (paper default 0.85).
    ///
    /// Deprecated: prefer [`ExecProfile::max_dev`] via
    /// [`Session::apply_exec`].
    pub fn with_max_dev(self, max_dev: f64) -> Session<E> {
        self.apply_exec(&ExecProfile::new().max_dev(max_dev));
        self
    }

    /// Stealable tasks generated per execution slot (steal slack; default
    /// 4 on backends with work queues).
    ///
    /// Deprecated: prefer [`ExecProfile::tasks_per_slot`] via
    /// [`Session::apply_exec`].
    pub fn with_tasks_per_slot(self, n: u32) -> Session<E> {
        self.set_tasks_per_slot(n);
        self
    }

    /// Runtime form of [`Session::with_tasks_per_slot`].
    ///
    /// Deprecated: prefer [`ExecProfile::tasks_per_slot`] via
    /// [`Session::apply_exec`].
    pub fn set_tasks_per_slot(&self, n: u32) {
        self.apply_exec(&ExecProfile::new().tasks_per_slot(n));
    }

    /// Prefetch lookahead depth for the dataflow drain (DESIGN.md §2.12):
    /// parked workers stage uploads for up to `k` not-yet-ready chunks
    /// under earlier chunks' compute. 0 (the default) disables prefetch;
    /// barrier drains ignore it. Results are bit-identical either way —
    /// only when uploads happen (and how they are booked) changes.
    ///
    /// Deprecated: prefer [`ExecProfile::prefetch_depth`] via
    /// [`Session::apply_exec`].
    pub fn with_prefetch_depth(self, k: u32) -> Session<E> {
        self.set_prefetch_depth(k);
        self
    }

    /// Runtime form of [`Session::with_prefetch_depth`].
    ///
    /// Deprecated: prefer [`ExecProfile::prefetch_depth`] via
    /// [`Session::apply_exec`].
    pub fn set_prefetch_depth(&self, k: u32) {
        self.apply_exec(&ExecProfile::new().prefetch_depth(k));
    }

    /// Toggle the buffer-residency layer (on by default; off is the A/B
    /// baseline for the locality benches).
    ///
    /// Deprecated: prefer [`ExecProfile::residency`] via
    /// [`Session::apply_exec`].
    pub fn set_residency_enabled(&self, on: bool) {
        self.apply_exec(&ExecProfile::new().residency(on));
    }

    /// Select the drain mode (default [`DrainMode::Dataflow`]; `Barrier`
    /// restores the per-stage drain for A/B comparisons — DESIGN.md §2.7).
    ///
    /// Deprecated: prefer [`ExecProfile::drain_mode`] via
    /// [`Session::apply_exec`].
    pub fn with_drain_mode(self, mode: DrainMode) -> Session<E> {
        self.set_drain_mode(mode);
        self
    }

    /// Runtime form of [`Session::with_drain_mode`].
    ///
    /// Deprecated: prefer [`ExecProfile::drain_mode`] via
    /// [`Session::apply_exec`].
    pub fn set_drain_mode(&self, mode: DrainMode) {
        self.apply_exec(&ExecProfile::new().drain_mode(mode));
    }

    /// Restrict (or release, with `None`) the backend to a device-space
    /// reservation (DESIGN.md §2.8): every request until the next call
    /// runs on — and steals within — the masked subset only, and neither
    /// the balance monitor nor the knowledge base learns from the masked
    /// (hardware-skewed) outcomes. The serve path installs the admitted
    /// mask around each co-scheduled request.
    pub fn set_slot_mask(&self, mask: Option<SlotMask>) {
        self.env.lock().unwrap().set_slot_mask(mask.clone());
        *self.slot_mask.lock().unwrap() = mask;
    }

    /// Unwind-safe [`Session::set_slot_mask`]`(None)`: tolerates poisoned
    /// locks so a drop guard clearing the mask during a panicking request
    /// cannot double-panic (serve's co-scheduler resets through this).
    pub(crate) fn clear_slot_mask_quiet(&self) {
        match self.env.lock() {
            Ok(mut env) => env.set_slot_mask(None),
            Err(poisoned) => poisoned.into_inner().set_slot_mask(None),
        }
        match self.slot_mask.lock() {
            Ok(mut m) => *m = None,
            Err(poisoned) => *poisoned.into_inner() = None,
        }
    }

    /// Estimated seconds to migrate the backend's device-resident data off
    /// the devices `mask` excludes (the residency term of the admission
    /// price; 0 for backends without a residency pool).
    pub fn mask_migration_secs(&self, mask: &SlotMask) -> f64 {
        self.env.lock().unwrap().mask_migration_secs(mask)
    }

    /// KB-estimated completion seconds for a computation
    /// ([`KnowledgeBase::estimate_time`]); `None` on a cold KB. Reads the
    /// knowledge base only — no counters move, no backend runs.
    pub fn kb_estimate(&self, comp: &Computation) -> Option<f64> {
        let (sct, w, _) = comp.spec().ok()?;
        self.kb.read().unwrap().estimate_time(&sct.id(), w)
    }

    // --- the seamless path ------------------------------------------------

    /// Resolve the framework configuration for a computation through the
    /// Section 3.2.3 fallback chain: KB lookup, RBF derivation, profile
    /// build. The built profile (cold start) is stored into the KB; `args`
    /// feed the tuner's probe executions on backends that run real kernels
    /// (analytic backends ignore them).
    pub fn resolve_config(
        &self,
        comp: &Computation,
        args: &RequestArgs,
    ) -> Result<(FrameworkConfig, ConfigOrigin)> {
        let (sct, w, units) = comp.spec()?;
        let id = sct.id();
        {
            let kb = self.kb.read().unwrap();
            if let Some(p) = kb.lookup(&id, w) {
                let cfg = p.config.clone();
                let warm = kb.is_imported(&id, w);
                drop(kb);
                self.bump(|s| {
                    s.kb_hits += 1;
                    if warm {
                        s.warm_hits += 1;
                    }
                });
                return Ok((cfg, ConfigOrigin::KbHit));
            }
            if let Some(cfg) = kb.derive(&id, w) {
                drop(kb);
                self.bump(|s| s.derived += 1);
                return Ok((cfg, ConfigOrigin::Derived));
            }
        }
        // Cold start: Algorithm 1 on the backend. Two threads racing the
        // same cold pair may both build; the KB's best-time store keeps the
        // better profile — wasteful but correct (documented in DESIGN.md).
        let t_build = Instant::now();
        let p = {
            let mut env = self.env.lock().unwrap();
            env.set_copy_bytes(comp.get_copy_bytes());
            env.bind_tuning_args(args);
            self.build_unmasked(&mut *env, sct, w, units)?
        };
        let build_secs = t_build.elapsed().as_secs_f64();
        let cfg = p.config.clone();
        self.kb.write().unwrap().store(p);
        self.bump(|s| {
            s.built += 1;
            s.build_secs += build_secs;
        });
        Ok((cfg, ConfigOrigin::Built))
    }

    /// Execute a computation under the KB-resolved configuration, monitor
    /// the execution, rebalance if the monitor triggers, and feed the
    /// outcome back into the knowledge base.
    pub fn run(&self, comp: &Computation, args: &RequestArgs) -> Result<SessionOutcome> {
        let (cfg, origin) = self.resolve_config(comp, args)?;
        let (sct, w, units) = comp.spec()?;
        let id = sct.id();
        let (out, launches) = {
            let mut env = self.env.lock().unwrap();
            env.set_copy_bytes(comp.get_copy_bytes());
            env.bind_tuning_args(args);
            let out = env.run_request(sct, args, units, &cfg)?;
            let launches = env.launch_count();
            (out, launches)
        };

        // Section 3.3: monitor every execution; adapt when lbt triggers.
        // The per-computation state lives behind one lock, so interleaved
        // requests from N threads feed the monitor in arrival order.
        // Masked runs (DESIGN.md §2.8) skip both the adaptation and the
        // KB feedback below: their slot times and totals describe a
        // hardware subset, and learning from them would skew the shared
        // profile for every whole-machine request that follows.
        let masked = self.slot_mask.lock().unwrap().is_some();
        let mut unbalanced = false;
        let mut rebalanced = false;
        if !masked {
            let key = format!("{id}|{}", w.id());
            let mut stored_cfg = cfg.clone();
            let max_dev = self.exec.lock().unwrap().max_dev_or_default();
            let status = {
                let mut states = self.states.lock().unwrap();
                let st = states.entry(key).or_insert_with(|| BalanceState {
                    monitor: Monitor::new(max_dev),
                    abs: AdaptiveBinarySearch::new(cfg.cpu_share),
                });
                let status = st.monitor.observe(&out.exec.slot_times);
                if status.trigger && !cfg.overlap.is_empty() {
                    stored_cfg.cpu_share =
                        st.abs.propose(out.exec.cpu_time, out.exec.gpu_time);
                    st.monitor.reset_lbt();
                    rebalanced = true;
                } else {
                    st.abs.track(cfg.cpu_share);
                }
                status
            };
            unbalanced = status.unbalanced;

            // Feed the observed outcome back into the KB: refined profiles
            // replace the stored distribution; plain runs keep the best
            // time of the configuration they actually ran under (Refined
            // entries bypass the store's best-time guard, so the min is
            // taken here).
            let mut kb = self.kb.write().unwrap();
            let existing = kb.lookup(&id, w);
            let store_origin = if rebalanced {
                ProfileOrigin::Refined
            } else {
                match origin {
                    ConfigOrigin::Built => ProfileOrigin::Built,
                    ConfigOrigin::Derived => ProfileOrigin::Derived,
                    _ => existing.map(|p| p.origin).unwrap_or(ProfileOrigin::Built),
                }
            };
            let best_time = match existing {
                Some(p) if !rebalanced && p.config == stored_cfg => {
                    out.exec.total.min(p.best_time)
                }
                _ => out.exec.total,
            };
            kb.store(Profile {
                sct_id: id,
                workload: w.clone(),
                config: stored_cfg,
                best_time,
                origin: store_origin,
            });
            // Irregular classes additionally feed the per-class cost model
            // (ROADMAP item 4): the observed whole-run time per element is
            // what the class-aware estimate path rescales for unseen sizes.
            if w.class != crate::data::workload::WorkloadClass::Regular {
                kb.observe_class(w.class, w.elems(), out.exec.total);
            }
        }
        let t = out.exec.transfers;
        let idle = out.exec.mean_idle_frac();
        self.bump(|s| {
            if unbalanced {
                s.unbalanced_runs += 1;
            }
            if rebalanced {
                s.balance_ops += 1;
            }
            s.runs += 1;
            s.bytes_uploaded += t.bytes_uploaded;
            s.bytes_downloaded += t.bytes_downloaded;
            s.uploads_avoided += t.uploads_avoided;
            s.uploads_avoided_bytes += t.uploads_avoided_bytes;
            s.uploads_overlapped += t.uploads_overlapped;
            s.uploads_overlapped_bytes += t.uploads_overlapped_bytes;
            s.steal_migrations += t.steal_migrations;
            s.idle_frac_sum += idle;
        });

        Ok(SessionOutcome {
            outputs: out.outputs,
            exec: out.exec,
            config: cfg,
            origin,
            unbalanced,
            rebalanced,
            launches,
        })
    }

    /// Execute under an explicitly pinned configuration (baseline + the
    /// override), bypassing the KB and the balancer — the escape hatch for
    /// reproducing fixed table rows and A/B comparisons.
    pub fn run_with(
        &self,
        comp: &Computation,
        args: &RequestArgs,
        ovr: ConfigOverride,
    ) -> Result<SessionOutcome> {
        let (sct, _, units) = comp.spec()?;
        let (out, cfg, launches) = {
            let mut env = self.env.lock().unwrap();
            env.set_copy_bytes(comp.get_copy_bytes());
            let cfg = ovr.apply(baseline_config(env.machine()));
            let out = env.run_request(sct, args, units, &cfg)?;
            let launches = env.launch_count();
            (out, cfg, launches)
        };
        let t = out.exec.transfers;
        let idle = out.exec.mean_idle_frac();
        self.bump(|s| {
            s.runs += 1;
            s.pinned += 1;
            s.bytes_uploaded += t.bytes_uploaded;
            s.bytes_downloaded += t.bytes_downloaded;
            s.uploads_avoided += t.uploads_avoided;
            s.uploads_avoided_bytes += t.uploads_avoided_bytes;
            s.uploads_overlapped += t.uploads_overlapped;
            s.uploads_overlapped_bytes += t.uploads_overlapped_bytes;
            s.steal_migrations += t.steal_migrations;
            s.idle_frac_sum += idle;
        });
        Ok(SessionOutcome {
            outputs: out.outputs,
            exec: out.exec,
            config: cfg,
            origin: ConfigOrigin::Pinned,
            unbalanced: false,
            rebalanced: false,
            launches,
        })
    }

    /// Run Algorithm 1 for a computation and persist the profile in the
    /// session's knowledge base.
    pub fn profile(&self, comp: &Computation) -> Result<Profile> {
        self.profile_with_args(comp, &RequestArgs::default())
    }

    /// Like [`Session::profile`], binding `args` for the tuner's probe
    /// executions (real backends need actual buffers).
    pub fn profile_with_args(
        &self,
        comp: &Computation,
        args: &RequestArgs,
    ) -> Result<Profile> {
        let (sct, w, units) = comp.spec()?;
        let t_build = Instant::now();
        let p = {
            let mut env = self.env.lock().unwrap();
            env.set_copy_bytes(comp.get_copy_bytes());
            env.bind_tuning_args(args);
            self.build_unmasked(&mut *env, sct, w, units)?
        };
        let build_secs = t_build.elapsed().as_secs_f64();
        self.kb.write().unwrap().store(p.clone());
        self.bump(|s| {
            s.built += 1;
            s.build_secs += build_secs;
        });
        Ok(p)
    }

    /// Run Algorithm 1 with any installed reservation mask lifted for the
    /// build's duration: a profile describes the *whole* machine, and a
    /// build tuned on a subset would poison the shared knowledge base for
    /// every later whole-machine request (DESIGN.md §2.8). The caller
    /// holds the env lock, so no request can slip in between lift and
    /// restore.
    fn build_unmasked(
        &self,
        env: &mut E,
        sct: &crate::sct::Sct,
        w: &crate::data::workload::Workload,
        units: u64,
    ) -> Result<Profile> {
        let mask = self.slot_mask.lock().unwrap().clone();
        if mask.is_some() {
            env.set_slot_mask(None);
        }
        let built = build_profile(env, sct, w, units, &self.tuner);
        if mask.is_some() {
            env.set_slot_mask(mask);
        }
        built
    }

    // --- accessors --------------------------------------------------------

    /// Read access to the knowledge base. Hold the guard briefly — stores
    /// from other threads block while it lives.
    pub fn kb(&self) -> RwLockReadGuard<'_, KnowledgeBase> {
        self.kb.read().unwrap()
    }

    /// Write access to the knowledge base (e.g. to pre-seed profiles).
    pub fn kb_mut(&self) -> RwLockWriteGuard<'_, KnowledgeBase> {
        self.kb.write().unwrap()
    }

    /// The shared handle to the knowledge base, for pooling sessions.
    pub fn shared_kb(&self) -> Arc<RwLock<KnowledgeBase>> {
        self.kb.clone()
    }

    /// Hand the knowledge base over (e.g. sim-warmed KB into a real
    /// session). Clones if other sessions still share it.
    pub fn into_kb(self) -> KnowledgeBase {
        match Arc::try_unwrap(self.kb) {
            Ok(lock) => lock.into_inner().unwrap(),
            Err(shared) => shared.read().unwrap().clone(),
        }
    }

    /// Persist the knowledge base (no-op for in-memory KBs): an atomic
    /// whole-file rewrite for JSON-backed KBs, an incremental flush for
    /// store-backed ones.
    pub fn save_kb(&self) -> Result<()> {
        self.kb.write().unwrap().save()
    }

    /// Exclusive access to the backend (blocks while a request runs).
    pub fn env(&self) -> MutexGuard<'_, E> {
        self.env.lock().unwrap()
    }

    pub fn machine(&self) -> Machine {
        self.env.lock().unwrap().machine().clone()
    }

    pub fn stats(&self) -> SessionStats {
        self.stats.lock().unwrap().clone()
    }

    fn bump<F: FnOnce(&mut SessionStats)>(&self, f: F) {
        f(&mut self.stats.lock().unwrap());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::workloads;
    use crate::platform::device::i7_hd7950;

    #[test]
    fn backend_parses_and_labels() {
        assert_eq!(Backend::parse("sim").unwrap(), Backend::Sim);
        assert_eq!(Backend::parse("native").unwrap(), Backend::Native);
        assert_eq!(Backend::parse("pjrt").unwrap(), Backend::Pjrt);
        assert_eq!(Backend::Native.label(), "native");
        assert!(Backend::parse("opencl").is_err());
        assert_eq!(Backend::default(), Backend::Sim);
    }

    #[test]
    fn native_session_runs_saxpy_end_to_end() {
        use crate::data::vector::VectorArg;
        use crate::platform::device::host_cpu;
        let n = 1u64 << 20;
        let x: Vec<f32> = (0..n).map(|i| (i % 251) as f32 * 0.5).collect();
        let y: Vec<f32> = (0..n).map(|i| (i % 13) as f32).collect();
        let args = RequestArgs {
            vectors: vec![
                VectorArg::partitioned_f32("x", x.clone(), 1),
                VectorArg::partitioned_f32("y", y.clone(), 1),
            ],
            scalars: vec![2.0],
        };
        let comp = Computation::from(workloads::saxpy(n));
        let s = Session::native(host_cpu()).unwrap();
        let out = s.run_with(&comp, &args, ConfigOverride::new()).unwrap();
        assert!(out.launches > 0, "native run must dispatch real launches");
        assert!(out.exec.total > 0.0, "native timing must be wall-clock");
        let got = match &out.outputs[0] {
            ArgValue::F32(v) => v,
            other => panic!("expected f32 output, got {other:?}"),
        };
        assert_eq!(got.len(), n as usize);
        // Exact f32 equality: the kernel computes a*x[i]+y[i] with the
        // same expression, and task outputs merge in unit order.
        for (i, &g) in got.iter().enumerate() {
            assert_eq!(g, 2.0f32 * x[i] + y[i], "mismatch at {i}");
        }
    }

    #[test]
    fn native_digest_separates_scalar_and_vector_profiles() {
        use crate::platform::device::host_cpu;
        let v = Session::native(host_cpu()).unwrap();
        let s = Session::native_with_engine(
            host_cpu(),
            Arc::new(NativeEngine::scalar_reference()),
        )
        .unwrap();
        let dv = v.env().manifest_digest();
        let ds = s.env().manifest_digest();
        assert_ne!(dv, ds, "scalar reference must not warm-start vector KBs");
        let sim = Session::simulated(host_cpu(), 3);
        assert_ne!(dv, sim.env().manifest_digest());
    }

    #[test]
    fn override_applies_on_machine_baseline() {
        let base = baseline_config(&i7_hd7950(2));
        assert_eq!(base.overlap.len(), 2);
        let cfg = ConfigOverride::new().gpu_only().overlap(4).apply(base);
        assert_eq!(cfg.cpu_share, 0.0);
        assert_eq!(cfg.overlap, vec![4, 4]);
    }

    #[test]
    fn pinned_run_reports_origin_and_skips_kb() {
        let comp = Computation::from(workloads::saxpy(1 << 20));
        let s = Session::simulated(i7_hd7950(1), 5);
        let out = s
            .run_with(&comp, &RequestArgs::default(), ConfigOverride::new().gpu_only())
            .unwrap();
        assert_eq!(out.origin, ConfigOrigin::Pinned);
        assert_eq!(out.config.cpu_share, 0.0);
        assert!(s.kb().is_empty());
        assert_eq!(s.stats().pinned, 1);
    }

    #[test]
    fn cpu_only_machine_never_rebalances() {
        use crate::platform::device::opteron_6272_quad;
        let comp = Computation::from(workloads::fft(16));
        let s = Session::simulated(opteron_6272_quad(), 9);
        for _ in 0..10 {
            let out = s.run(&comp, &RequestArgs::default()).unwrap();
            assert!(!out.rebalanced);
            assert_eq!(out.config.cpu_share, 1.0);
        }
        assert_eq!(s.stats().balance_ops, 0);
    }

    #[test]
    fn exec_profile_accumulates_through_setters() {
        let s = Session::simulated(i7_hd7950(1), 7)
            .with_max_dev(0.7)
            .with_tasks_per_slot(8);
        s.set_drain_mode(DrainMode::Barrier);
        // Every legacy setter routes through apply_exec, so the stored
        // profile reflects the accumulated knobs — what a replay trace
        // records for this session.
        let p = s.exec_profile();
        assert_eq!(p.max_dev, Some(0.7));
        assert_eq!(p.tasks_per_slot, Some(8));
        assert_eq!(p.drain_mode, Some(DrainMode::Barrier));
        assert_eq!(p.prefetch_depth, None);
        // A later overlay wins without clearing unrelated knobs.
        s.apply_exec(&ExecProfile::new().drain_mode(DrainMode::Dataflow));
        let p = s.exec_profile();
        assert_eq!(p.drain_mode, Some(DrainMode::Dataflow));
        assert_eq!(p.tasks_per_slot, Some(8));
    }

    #[test]
    fn session_is_shareable_across_threads() {
        // Compile-time + runtime smoke: &Session crosses thread boundaries
        // and concurrent pinned runs all complete.
        let comp = Computation::from(workloads::saxpy(1 << 20));
        let s = Session::simulated(i7_hd7950(1), 13);
        std::thread::scope(|scope| {
            for _ in 0..3 {
                let s = &s;
                let comp = &comp;
                scope.spawn(move || {
                    s.run_with(comp, &RequestArgs::default(), ConfigOverride::new())
                        .unwrap();
                });
            }
        });
        assert_eq!(s.stats().runs, 3);
    }
}
