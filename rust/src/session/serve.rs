//! The multi-request serve path: a pool of sessions sharing one knowledge
//! base drains a stream of requests under an admission cap — the first
//! building block of the ROADMAP's "heavy traffic" north star.
//!
//! [`SessionPool`] owns N [`Session`]s (one backend each — the paper's
//! one-machine contract) wired to a single shared KB, so the first cold
//! start warms every worker: whichever session builds a profile, the rest
//! resolve the same computation as KB hits. [`SessionPool::serve`] spawns
//! one scoped worker thread per session; workers pull requests off a shared
//! cursor until the stream drains, recording per-request latency for the
//! p50/p99 report.
//!
//! Analytic backends price an execution and return immediately, which
//! makes a throughput number meaningless; [`ServeOpts::pace`] inserts a
//! fixed per-request service floor (sleep) that stands in for device
//! occupancy, so requests/sec measures genuine admission-cap scaling. Real
//! backends leave `pace` at 0.
//!
//! **Co-scheduling** ([`ServeOpts::co_schedule`], DESIGN.md §2.8): instead
//! of every request implicitly owning the whole device pool, admission
//! prices each request's KB-estimated cost against every device subset
//! ([`candidate_masks`]) — derated by the subset's capacity share, plus the
//! migration cost of residency parked on excluded devices and the wait for
//! conflicting reservations already admitted — and reserves the subset
//! minimizing predicted completion. A CPU-friendly request then runs on
//! the CPU sub-devices while a GPU-heavy one owns the GPUs, and the
//! work-stealing launcher never crosses the reservation boundary.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::error::Result;
use crate::kb::KnowledgeBase;
use crate::platform::device::Machine;
use crate::runtime::exec::RequestArgs;
use crate::scheduler::{
    candidate_masks, DrainMode, ExecEnv, SlotMask, SlotReservations, VirtualTimeline,
};
use crate::session::{Computation, ConfigOrigin, Session, SessionStats};
use crate::util::stats::percentile;

/// One queued request: a computation plus its arguments.
#[derive(Clone, Debug)]
pub struct ServeRequest {
    pub comp: Computation,
    pub args: RequestArgs,
}

impl From<Computation> for ServeRequest {
    fn from(comp: Computation) -> ServeRequest {
        ServeRequest {
            comp,
            args: RequestArgs::default(),
        }
    }
}

/// Serving knobs.
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// Admission cap: how many requests may be in flight at once (bounded
    /// by the pool size).
    pub concurrency: usize,
    /// Per-request service floor in seconds (see module docs). 0 disables.
    pub pace: f64,
    /// Override the stealable-tasks-per-slot knob on every pooled session
    /// (`--tasks-per-slot`); `None` keeps the backend default.
    pub tasks_per_slot: Option<u32>,
    /// Override the drain mode on every pooled session (`--drain`);
    /// `None` keeps the backend default ([`DrainMode::Dataflow`]).
    pub drain_mode: Option<DrainMode>,
    /// Device-space co-scheduling (`--co-schedule`, DESIGN.md §2.8): admit
    /// each request onto the KB-cost-priced device subset minimizing its
    /// predicted completion, instead of time-sharing the whole pool. Off
    /// by default (the PR 2 whole-pool behavior).
    pub co_schedule: bool,
    /// Flush the durable KB store (DESIGN.md §2.9) every N completed
    /// requests, picking up segments other processes committed in the
    /// meantime. 0 (the default) syncs once at the end of the run; the
    /// knob is a no-op when the shared KB has no store backing.
    pub store_sync_every: usize,
}

impl Default for ServeOpts {
    fn default() -> ServeOpts {
        ServeOpts {
            concurrency: 1,
            pace: 0.0,
            tasks_per_slot: None,
            drain_mode: None,
            co_schedule: false,
            store_sync_every: 0,
        }
    }
}

/// One served request's record.
#[derive(Clone, Debug)]
pub struct RequestTrace {
    /// Index into the request stream.
    pub index: usize,
    /// Which pool worker served it.
    pub worker: usize,
    /// Wall seconds from admission to completion (including the pace floor).
    pub latency: f64,
    pub origin: ConfigOrigin,
    /// The execution's own completion time.
    pub exec_total: f64,
    /// The device subset the request was admitted onto (`None` without
    /// co-scheduling: the request implicitly owned the whole pool).
    pub mask: Option<SlotMask>,
}

/// Aggregate outcome of one serve run.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub completed: usize,
    pub concurrency: usize,
    pub wall_secs: f64,
    pub requests_per_sec: f64,
    pub p50_latency: f64,
    pub p99_latency: f64,
    pub mean_latency: f64,
    /// Whether this run admitted requests onto device subsets.
    pub co_scheduled: bool,
    /// Completion time of the whole stream on the [`VirtualTimeline`]
    /// model: requests booked on conflicting device subsets stack up,
    /// disjoint ones overlap. Without co-scheduling every request books
    /// the full pool, so this is the serialized sum — the A/B baseline
    /// the co-scheduling win is measured against, noise-free even on
    /// analytic backends.
    pub virtual_makespan: f64,
    /// Session counters for this serve run (pool-summed delta, so reusing
    /// a pool across serve calls still reports per-run numbers).
    pub stats: SessionStats,
    pub traces: Vec<RequestTrace>,
}

impl ServeReport {
    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{} requests in {:.3}s @ concurrency {} -> {:.1} req/s \
             (p50 {:.2}ms, p99 {:.2}ms; {} kb hits ({} warm-started), \
             {} built ({:.2}s cold-build), {} derived; \
             {:.1} MB uploaded, {} uploads avoided, {} steal migrations; \
             mean slot idle {:.1}%; {} device-time {:.3}s)",
            self.completed,
            self.wall_secs,
            self.concurrency,
            self.requests_per_sec,
            self.p50_latency * 1e3,
            self.p99_latency * 1e3,
            self.stats.kb_hits,
            self.stats.warm_hits,
            self.stats.built,
            self.stats.build_secs,
            self.stats.derived,
            self.stats.bytes_uploaded as f64 / 1e6,
            self.stats.uploads_avoided,
            self.stats.steal_migrations,
            self.stats.mean_idle_pct(),
            if self.co_scheduled {
                "co-scheduled"
            } else {
                "whole-pool"
            },
            self.virtual_makespan
        )
    }

    /// Requests per second of *device time*: the stream's size over the
    /// virtual makespan. Deterministic on analytic backends (no wall-clock
    /// noise), which is what the CI bench gate compares.
    pub fn virtual_req_per_sec(&self) -> f64 {
        if self.virtual_makespan <= 0.0 {
            0.0
        } else {
            self.completed as f64 / self.virtual_makespan
        }
    }
}

/// Width slack of the admission policy: among candidate subsets whose
/// predicted completion is within this factor of the best, the *narrowest*
/// (smallest capacity share) wins. A bounded solo slowdown buys free
/// devices for concurrent requests — EngineCL's co-execution result — and
/// a strongly CPU- or GPU-leaning request therefore leaves the other
/// device type to the rest of the stream even when the pool is idle.
///
/// The tradeoff is deliberate and bounded: on a *homogeneous* stream
/// (every request leaning the same way) the preferred subset serializes
/// the stream at up to `1/capacity` (≤ `WIDTH_SLACK`) of the whole-pool
/// per-request time while the other device idles — capacity held in
/// reserve for traffic that never comes. Streams known to be homogeneous
/// should keep `co_schedule` off (the default); under congestion the
/// wait term grows until the idle device's candidate wins and the stream
/// spills over, so the loss cannot compound unboundedly.
const WIDTH_SLACK: f64 = 1.25;

/// One admission decision (DESIGN.md §2.8).
struct Admission {
    mask: SlotMask,
    /// Estimated execution + migration seconds on the chosen subset — the
    /// wait later conflicting requests are charged while the reservation
    /// is held.
    est_secs: f64,
}

/// Drop guard clearing a session's slot mask on every exit path: a
/// panicking masked request must not leave the pooled session restricted
/// (or quarantined from learning) for whoever reuses the pool. Clears via
/// the poison-tolerant path so an unwind cannot double-panic.
struct MaskReset<'s, E: ExecEnv>(&'s Session<E>);

impl<E: ExecEnv> Drop for MaskReset<'_, E> {
    fn drop(&mut self) {
        self.0.clear_slot_mask_quiet();
    }
}

/// Price every candidate device subset for a request and pick the one
/// minimizing predicted completion: `wait` (conflicting admitted work) +
/// `base / capacity` (the KB cost estimate derated to the subset's share
/// of the tuned throughput) + `migration` (residency parked on excluded
/// devices). Ties within [`WIDTH_SLACK`] go to the narrowest subset.
fn admit<E: ExecEnv + Send>(
    session: &Session<E>,
    machine: &Machine,
    comp: &Computation,
    base_secs: f64,
    reservations: &SlotReservations,
) -> Admission {
    let cfg = comp
        .spec()
        .ok()
        .and_then(|(sct, w, _)| session.kb().derive(&sct.id(), w))
        .unwrap_or_else(|| super::baseline_config(machine));
    let base = base_secs.max(1e-9);
    let mut scored: Vec<(SlotMask, f64, f64, f64)> = Vec::new();
    for mask in candidate_masks(machine) {
        let cap = mask.capacity_frac(&cfg, machine);
        if cap <= 1e-9 {
            continue;
        }
        let exec = base / cap + session.mask_migration_secs(&mask);
        let wait = reservations.pending_secs(&mask);
        scored.push((mask, wait + exec, exec, cap));
    }
    let best = scored.iter().map(|s| s.1).fold(f64::INFINITY, f64::min);
    let (mask, _, est_secs, _) = scored
        .into_iter()
        .filter(|s| s.1 <= best * WIDTH_SLACK)
        .min_by(|a, b| a.3.partial_cmp(&b.3).unwrap())
        .expect("the full mask always has capacity 1");
    Admission { mask, est_secs }
}

/// A pool of sessions over one shared knowledge base.
pub struct SessionPool<E: ExecEnv + Send> {
    sessions: Vec<Session<E>>,
}

impl<E: ExecEnv + Send> SessionPool<E> {
    /// Build a pool of `n` sessions from a factory; every session after
    /// the first is re-wired onto the first one's knowledge base.
    pub fn build<F: FnMut(usize) -> Session<E>>(n: usize, mut mk: F) -> SessionPool<E> {
        let mut sessions: Vec<Session<E>> = Vec::with_capacity(n.max(1));
        let mut shared: Option<Arc<RwLock<KnowledgeBase>>> = None;
        for i in 0..n.max(1) {
            let s = mk(i);
            let s = match &shared {
                None => {
                    shared = Some(s.shared_kb());
                    s
                }
                Some(kb) => s.with_shared_kb(kb.clone()),
            };
            sessions.push(s);
        }
        SessionPool { sessions }
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    pub fn sessions(&self) -> &[Session<E>] {
        &self.sessions
    }

    /// The pool's shared knowledge base handle.
    pub fn shared_kb(&self) -> Arc<RwLock<KnowledgeBase>> {
        self.sessions[0].shared_kb()
    }

    /// Session counters summed over the pool (lifetime totals).
    fn summed_stats(&self) -> SessionStats {
        let mut stats = SessionStats::default();
        for s in &self.sessions {
            let st = s.stats();
            stats.runs += st.runs;
            stats.kb_hits += st.kb_hits;
            stats.warm_hits += st.warm_hits;
            stats.derived += st.derived;
            stats.built += st.built;
            stats.build_secs += st.build_secs;
            stats.pinned += st.pinned;
            stats.balance_ops += st.balance_ops;
            stats.unbalanced_runs += st.unbalanced_runs;
            stats.bytes_uploaded += st.bytes_uploaded;
            stats.bytes_downloaded += st.bytes_downloaded;
            stats.uploads_avoided += st.uploads_avoided;
            stats.steal_migrations += st.steal_migrations;
            stats.idle_frac_sum += st.idle_frac_sum;
        }
        stats
    }

    /// Drain a request stream: up to `opts.concurrency` workers (bounded by
    /// the pool size) pull requests in order. The first error cancels the
    /// remaining stream and is returned.
    pub fn serve(&self, requests: &[ServeRequest], opts: &ServeOpts) -> Result<ServeReport> {
        let workers = opts.concurrency.clamp(1, self.sessions.len());
        if let Some(n) = opts.tasks_per_slot {
            for s in &self.sessions {
                s.set_tasks_per_slot(n);
            }
        }
        if let Some(mode) = opts.drain_mode {
            for s in &self.sessions {
                s.set_drain_mode(mode);
            }
        }
        // Snapshot so the report's stats cover this run only, even when the
        // pool is reused across serve calls.
        let stats_before = self.summed_stats();
        let machine = self.sessions[0].machine();
        let full_mask = SlotMask::full(&machine);
        let reservations = SlotReservations::new();
        let timeline = VirtualTimeline::new();
        let next = AtomicUsize::new(0);
        let traces: Mutex<Vec<RequestTrace>> = Mutex::new(Vec::with_capacity(requests.len()));
        let failure: Mutex<Option<crate::error::Error>> = Mutex::new(None);

        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for (w, session) in self.sessions.iter().take(workers).enumerate() {
                let next = &next;
                let traces = &traces;
                let failure = &failure;
                let machine = &machine;
                let full_mask = &full_mask;
                let reservations = &reservations;
                let timeline = &timeline;
                let pace = opts.pace;
                let co = opts.co_schedule;
                let sync_every = opts.store_sync_every;
                scope.spawn(move || loop {
                    if failure.lock().unwrap().is_some() {
                        break;
                    }
                    let i = next.fetch_add(1, Ordering::SeqCst);
                    if i >= requests.len() {
                        break;
                    }
                    let req = &requests[i];
                    let admitted = Instant::now();
                    // Admission (DESIGN.md §2.8): price the request on every
                    // device subset and reserve the cheapest; the guard
                    // releases on every exit path, including unwinds.
                    let admission = if co {
                        match Self::admission_for(session, machine, req, traces, reservations)
                        {
                            Ok(a) => Some(a),
                            Err(e) => {
                                let mut f = failure.lock().unwrap();
                                if f.is_none() {
                                    *f = Some(e);
                                }
                                break;
                            }
                        }
                    } else {
                        None
                    };
                    let result = match &admission {
                        Some(adm) => {
                            let _guard =
                                reservations.acquire(adm.mask.clone(), adm.est_secs);
                            session.set_slot_mask(Some(adm.mask.clone()));
                            let r = {
                                let _mask_reset = MaskReset(session);
                                session.run(&req.comp, &req.args)
                            };
                            if r.is_ok() && pace > 0.0 {
                                // The pace floor stands in for device
                                // occupancy, so it holds the reservation.
                                std::thread::sleep(Duration::from_secs_f64(pace));
                            }
                            r
                        }
                        None => {
                            let r = session.run(&req.comp, &req.args);
                            if r.is_ok() && pace > 0.0 {
                                std::thread::sleep(Duration::from_secs_f64(pace));
                            }
                            r
                        }
                    };
                    match result {
                        Ok(out) => {
                            let mask = admission.map(|a| a.mask);
                            timeline.book(
                                mask.as_ref().unwrap_or(full_mask),
                                out.exec.total,
                            );
                            let done = {
                                let mut tr = traces.lock().unwrap();
                                tr.push(RequestTrace {
                                    index: i,
                                    worker: w,
                                    latency: admitted.elapsed().as_secs_f64(),
                                    origin: out.origin,
                                    exec_total: out.exec.total,
                                    mask,
                                });
                                tr.len()
                            };
                            // Periodic durability: commit staged profiles
                            // and absorb foreign segments mid-run, so a
                            // crash loses at most `sync_every` requests'
                            // learning (DESIGN.md §2.9).
                            if sync_every > 0 && done % sync_every == 0 {
                                if let Err(e) = session.sync_kb() {
                                    let mut f = failure.lock().unwrap();
                                    if f.is_none() {
                                        *f = Some(e);
                                    }
                                    break;
                                }
                            }
                        }
                        Err(e) => {
                            let mut f = failure.lock().unwrap();
                            if f.is_none() {
                                *f = Some(e);
                            }
                            break;
                        }
                    }
                });
            }
        });
        let wall_secs = t0.elapsed().as_secs_f64().max(1e-9);

        if let Some(e) = failure.into_inner().unwrap() {
            return Err(e);
        }
        // Final durability point: whatever the stream learned is committed
        // before the report is handed back (no-op without store backing;
        // the KB is shared, so any one session flushes for the pool).
        self.sessions[0].sync_kb()?;
        let mut traces = traces.into_inner().unwrap();
        traces.sort_by_key(|t| t.index);
        let latencies: Vec<f64> = traces.iter().map(|t| t.latency).collect();
        let mean_latency = if latencies.is_empty() {
            0.0
        } else {
            latencies.iter().sum::<f64>() / latencies.len() as f64
        };
        let after = self.summed_stats();
        let stats = SessionStats {
            runs: after.runs - stats_before.runs,
            kb_hits: after.kb_hits - stats_before.kb_hits,
            warm_hits: after.warm_hits - stats_before.warm_hits,
            derived: after.derived - stats_before.derived,
            built: after.built - stats_before.built,
            build_secs: after.build_secs - stats_before.build_secs,
            pinned: after.pinned - stats_before.pinned,
            balance_ops: after.balance_ops - stats_before.balance_ops,
            unbalanced_runs: after.unbalanced_runs - stats_before.unbalanced_runs,
            bytes_uploaded: after.bytes_uploaded - stats_before.bytes_uploaded,
            bytes_downloaded: after.bytes_downloaded - stats_before.bytes_downloaded,
            uploads_avoided: after.uploads_avoided - stats_before.uploads_avoided,
            steal_migrations: after.steal_migrations - stats_before.steal_migrations,
            idle_frac_sum: after.idle_frac_sum - stats_before.idle_frac_sum,
        };
        Ok(ServeReport {
            completed: traces.len(),
            concurrency: workers,
            wall_secs,
            requests_per_sec: traces.len() as f64 / wall_secs,
            // Percentiles index into duration-sorted samples — never the
            // completion-ordered trace (`percentile` sorts a copy, so a
            // fast request finishing last cannot leak into p99; the
            // known-distribution unit test below pins this invariant).
            p50_latency: percentile(&latencies, 50.0),
            p99_latency: percentile(&latencies, 99.0),
            mean_latency,
            co_scheduled: opts.co_schedule,
            virtual_makespan: timeline.makespan(),
            stats,
            traces,
        })
    }

    /// The co-scheduling admission pipeline for one request: KB cost
    /// estimate (resolving the configuration first on a cold KB, so the
    /// profile build runs on the *whole* machine — a reservation mask must
    /// never leak into a stored profile), falling back to the mean
    /// observed execution time of this serve run, then the subset pricing
    /// of [`admit`]. A cold request resolved here is re-resolved inside
    /// [`Session::run`] as a KB hit, so co-scheduled cold starts book
    /// `built + 1` *and* `kb_hits + 1` — compare hit-rates across modes
    /// accordingly.
    fn admission_for(
        session: &Session<E>,
        machine: &Machine,
        req: &ServeRequest,
        traces: &Mutex<Vec<RequestTrace>>,
        reservations: &SlotReservations,
    ) -> Result<Admission> {
        let base = match session.kb_estimate(&req.comp) {
            Some(t) => Some(t),
            None => {
                session.resolve_config(&req.comp, &req.args)?;
                session.kb_estimate(&req.comp)
            }
        };
        let base = base.unwrap_or_else(|| {
            let tr = traces.lock().unwrap();
            if tr.is_empty() {
                1e-3
            } else {
                tr.iter().map(|t| t.exec_total).sum::<f64>() / tr.len() as f64
            }
        });
        Ok(admit(session, machine, &req.comp, base, reservations))
    }
}

/// Serve a request stream over a pool of simulated sessions for `machine`
/// (one per admitted request), sharing one knowledge base.
pub fn serve_simulated(
    machine: &Machine,
    seed: u64,
    requests: &[ServeRequest],
    opts: &ServeOpts,
) -> Result<ServeReport> {
    let pool = SessionPool::build(opts.concurrency.max(1), |i| {
        Session::simulated(machine.clone(), seed + i as u64)
    });
    pool.serve(requests, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::workloads;
    use crate::kb::mk_profile;
    use crate::platform::cpu::FissionLevel;
    use crate::platform::device::i7_hd7950;
    use crate::scheduler::SimEnv;

    fn requests(n: usize) -> Vec<ServeRequest> {
        (0..n)
            .map(|_| ServeRequest::from(Computation::from(workloads::saxpy(1 << 20))))
            .collect()
    }

    #[test]
    fn pool_shares_one_kb_across_sessions() {
        let pool = SessionPool::build(3, |i| Session::simulated(i7_hd7950(1), 40 + i as u64));
        let reqs = requests(6);
        let report = pool
            .serve(
                &reqs,
                &ServeOpts {
                    concurrency: 3,
                    ..Default::default()
                },
            )
            .unwrap();
        assert_eq!(report.completed, 6);
        // One cold start warms the whole pool: exactly one build (plus any
        // same-instant racers), and the shared KB holds one profile.
        assert_eq!(pool.shared_kb().read().unwrap().len(), 1);
        assert!(report.stats.kb_hits + report.stats.derived >= 3);
        // Without co-scheduling every request books the whole pool: the
        // virtual makespan is the serialized sum of execution times.
        assert!(!report.co_scheduled);
        let sum: f64 = report.traces.iter().map(|t| t.exec_total).sum();
        assert!((report.virtual_makespan - sum).abs() <= 1e-9 * sum.max(1.0));
        assert!(report.traces.iter().all(|t| t.mask.is_none()));
    }

    #[test]
    fn serve_reports_latency_percentiles() {
        let reqs = requests(8);
        let report = serve_simulated(
            &i7_hd7950(1),
            7,
            &reqs,
            &ServeOpts {
                concurrency: 2,
                pace: 0.002,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(report.completed, 8);
        assert!(report.requests_per_sec > 0.0);
        assert!(report.p50_latency >= 0.002);
        assert!(report.p99_latency >= report.p50_latency);
        // Every request is accounted for exactly once, in stream order.
        let idx: Vec<usize> = report.traces.iter().map(|t| t.index).collect();
        assert_eq!(idx, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn percentiles_index_duration_sorted_samples() {
        // A known distribution handed over in *reverse completion order*:
        // the percentiles must come from the sorted durations, so p50 of
        // 1..=101 is exactly 51 and p99 exactly 100 — not whatever landed
        // at those completion indices.
        let completion_order: Vec<f64> = (1..=101).rev().map(|i| i as f64).collect();
        let mut by_duration = completion_order.clone();
        by_duration.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!((percentile(&by_duration, 50.0) - 51.0).abs() < 1e-12);
        assert!((percentile(&by_duration, 99.0) - 100.0).abs() < 1e-12);
        // And the serve path reports exactly these sorted-index values.
        let reqs = requests(3);
        let report = serve_simulated(&i7_hd7950(1), 3, &reqs, &ServeOpts::default()).unwrap();
        let mut lat: Vec<f64> = report.traces.iter().map(|t| t.latency).collect();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(report.p50_latency.to_bits(), percentile(&lat, 50.0).to_bits());
        assert_eq!(report.p99_latency.to_bits(), percentile(&lat, 99.0).to_bits());
    }

    #[test]
    fn concurrency_is_capped_by_pool_size() {
        let pool = SessionPool::build(2, |i| Session::simulated(i7_hd7950(1), i as u64));
        let report = pool
            .serve(
                &requests(4),
                &ServeOpts {
                    concurrency: 16,
                    ..Default::default()
                },
            )
            .unwrap();
        assert_eq!(report.concurrency, 2);
        assert_eq!(report.completed, 4);
    }

    /// A session over `machine` whose KB already holds a profile pinning
    /// `cpu_share` for `comp` — the admission sees a tuned split without
    /// running Algorithm 1.
    fn seeded_session(comp: &Computation, cpu_share: f64, best: f64) -> Session<SimEnv> {
        let s = Session::simulated(i7_hd7950(1), 21);
        let (sct, w, _) = comp.spec().unwrap();
        s.kb_mut().store(mk_profile(
            &sct.id(),
            w.clone(),
            FissionLevel::L2,
            vec![4],
            cpu_share,
            best,
        ));
        s
    }

    #[test]
    fn admission_sends_leaning_requests_to_their_device() {
        let machine = i7_hd7950(1);
        let cpu_comp = Computation::from(workloads::saxpy(1 << 20));
        let gpu_comp = Computation::from(workloads::saxpy(1 << 21));
        let reservations = SlotReservations::new();
        // CPU-leaning (tuned split 90% CPU): the CPU subset is within the
        // width slack of the full pool and narrower, so it wins.
        let s = seeded_session(&cpu_comp, 0.9, 1.0);
        let a = admit(&s, &machine, &cpu_comp, 1.0, &reservations);
        assert_eq!(a.mask, SlotMask::cpu_only(&machine), "got {}", a.mask);
        // GPU-leaning: the GPU subset wins symmetrically.
        let s = seeded_session(&gpu_comp, 0.1, 1.0);
        let a = admit(&s, &machine, &gpu_comp, 1.0, &reservations);
        assert_eq!(a.mask, SlotMask::single_gpu(&machine, 0), "got {}", a.mask);
        // A balanced request keeps the whole pool: halving the hardware
        // would double it, far past the slack.
        let s = seeded_session(&cpu_comp, 0.5, 1.0);
        let a = admit(&s, &machine, &cpu_comp, 1.0, &reservations);
        assert_eq!(a.mask, SlotMask::full(&machine), "got {}", a.mask);
    }

    #[test]
    fn admission_waits_steer_around_held_devices() {
        let machine = i7_hd7950(1);
        let comp = Computation::from(workloads::saxpy(1 << 20));
        let s = seeded_session(&comp, 0.1, 1.0); // GPU-leaning
        let reservations = SlotReservations::new();
        // GPU held for a long time: even a GPU-leaning request is better
        // off on the free CPU than queued behind 100 s of GPU work.
        let _gpu = reservations
            .try_acquire(SlotMask::all_gpus(&machine), 100.0)
            .unwrap();
        let a = admit(&s, &machine, &comp, 1.0, &reservations);
        assert_eq!(a.mask, SlotMask::cpu_only(&machine), "got {}", a.mask);
    }

    #[test]
    fn co_scheduled_serve_records_masks_and_overlapping_makespan() {
        let machine = i7_hd7950(1);
        let cpu_comp = Computation::from(workloads::saxpy(1 << 20));
        let gpu_comp = Computation::from(workloads::saxpy(1 << 21));
        let pool = SessionPool::build(2, |i| Session::simulated(machine.clone(), 60 + i as u64));
        for comp in [(&cpu_comp, 0.9), (&gpu_comp, 0.1)] {
            let (sct, w, _) = comp.0.spec().unwrap();
            pool.shared_kb().write().unwrap().store(mk_profile(
                &sct.id(),
                w.clone(),
                FissionLevel::L2,
                vec![4],
                comp.1,
                1e-3,
            ));
        }
        let reqs = vec![
            ServeRequest::from(cpu_comp),
            ServeRequest::from(gpu_comp),
        ];
        let report = pool
            .serve(
                &reqs,
                &ServeOpts {
                    concurrency: 2,
                    co_schedule: true,
                    ..Default::default()
                },
            )
            .unwrap();
        assert_eq!(report.completed, 2);
        assert!(report.co_scheduled);
        assert!(report.traces.iter().all(|t| t.mask.is_some()));
        // Disjoint subsets overlap on the virtual timeline: the combined
        // makespan is below the serialized sum.
        let sum: f64 = report.traces.iter().map(|t| t.exec_total).sum();
        assert!(
            report.virtual_makespan < sum,
            "makespan {} must undercut the serialized sum {}",
            report.virtual_makespan,
            sum
        );
        assert!(report.virtual_req_per_sec() > 0.0);
        // The pool is reusable afterwards: no mask leaks past the request.
        let again = pool.serve(&requests(2), &ServeOpts::default()).unwrap();
        assert_eq!(again.completed, 2);
    }
}
